"""Dev smoke: every family, reduced config, fwd + prefill + decode on CPU."""
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import registry
from repro.models import transformer as T

for name, cfg in registry().items():
    r = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(r, key)
    b, s = 2, 16
    if r.frontend != "none":
        inputs = jax.random.normal(key, (b, s, r.d_model), jnp.float32)
    else:
        inputs = jax.random.randint(key, (b, s), 0, r.vocab_size)
    logits, aux = jax.jit(lambda p, i: T.forward_train(r, p, i))(params, inputs)
    assert logits.shape == (b, s, r.vocab_size), (name, logits.shape)
    assert np.isfinite(np.asarray(logits)).all(), name

    cache_len = 32
    lg2, cache = jax.jit(lambda p, i: T.prefill(r, p, i, cache_len))(params, inputs)
    assert lg2.shape == (b, 1, r.vocab_size)
    tok = jnp.zeros((b, 1), jnp.int32)
    lg3, cache = jax.jit(lambda p, c, t: T.decode_step(r, p, c, t, jnp.int32(s)))(
        params, cache, tok)
    assert lg3.shape == (b, 1, r.vocab_size)
    assert np.isfinite(np.asarray(lg3)).all(), name
    n_p = sum(np.prod(x.shape) for x in jax.tree.leaves(params))
    print(f"{name:24s} [{cfg.family:6s}] ok  reduced_params={n_p:,}  "
          f"full_params~{cfg.param_count()/1e9:.1f}B active~{cfg.active_param_count()/1e9:.1f}B")
print("ALL FAMILIES OK")
