"""Quick dev smoke for the DMO core."""
import numpy as np
from repro.core.graph import Graph, Op, conv_out_dim
from repro.core.overlap import (safe_overlap_trace, safe_overlap_algorithmic,
                                safe_overlap_analytic)
from repro.core.planner import plan_naive, plan_dmo, best_plan
from repro.core.arena import verify_plan


def mk_conv(ih, iw, ic, oc, k, s, padding="same", kind="conv2d", mult=1):
    g = Graph("t")
    x = g.tensor("x", (ih, iw, ic), 4, "input")
    oh = conv_out_dim(ih, k, s, padding)
    ow = conv_out_dim(iw, k, s, padding)
    od = oc if kind == "conv2d" else ic * mult
    params = dict(kernel=(k, k), stride=(s, s), padding=padding)
    if kind == "depthwise_conv2d":
        params["multiplier"] = mult
    out = g.op(kind, [x], (oh, ow, od), params, out_kind="output")
    return g, g.ops[0]


# --- Table I / II reproduction: dwconv 112x112x96 -> 56x56x96 s2 k3 ---------
g, op = mk_conv(112, 112, 96, None, 3, 2, "same", "depthwise_conv2d")
alg = safe_overlap_algorithmic(op)
ana = safe_overlap_analytic(op)
print("Table II dwconv: algorithmic", alg, "(paper: 1204224)  analytic", ana,
      "(paper: 1193376)")

# --- trace vs algorithmic on small ops --------------------------------------
for kind, args in [
    ("conv2d", dict(ih=12, iw=10, ic=3, oc=8, k=3, s=2)),
    ("conv2d", dict(ih=9, iw=9, ic=4, oc=4, k=3, s=1, padding="valid")),
    ("depthwise_conv2d", dict(ih=12, iw=10, ic=3, oc=None, k=3, s=2, mult=2)),
    ("pool", dict(ih=8, iw=8, ic=4, oc=None, k=2, s=2)),
]:
    kw = dict(args)
    kind2 = kind
    g, op = mk_conv(kw.pop("ih"), kw.pop("iw"), kw.pop("ic"), kw.pop("oc"),
                    kw.pop("k"), kw.pop("s"), kw.pop("padding", "same"),
                    kind2, kw.pop("mult", 1))
    t, a, an = (safe_overlap_trace(op), safe_overlap_algorithmic(op),
                safe_overlap_analytic(op))
    print(f"{kind:18s} trace={t} alg={a} analytic={an}  (analytic<=alg<=?)")
    assert t == a, (t, a)
    assert an is None or an <= a + 1e-9, (an, a)

# --- plan + numeric verification on a small sequential net ------------------
g = Graph("mini")
x = g.tensor("x", (12, 12, 3), 4, "input")
h = g.op("conv2d", [x], (6, 6, 8), dict(kernel=(3, 3), stride=(2, 2), padding="same"))
h = g.op("elementwise", [h], h.shape, dict(fn="relu"))
h = g.op("depthwise_conv2d", [h], (6, 6, 8), dict(kernel=(3, 3), stride=(1, 1), padding="same"))
h = g.op("conv2d", [h], (6, 6, 16), dict(kernel=(1, 1), stride=(1, 1), padding="same"))
h = g.op("pool", [h], (3, 3, 16), dict(kernel=(2, 2), stride=(2, 2), padding="valid", mode="avg"))
h = g.op("reshape", [h], (144,), name="flat")
h = g.op("fully_connected", [h], (10,))
h = g.op("softmax", [h], (10,), out_kind="output")
g.validate()

p0 = plan_naive(g)
p1 = plan_dmo(g)
print("naive peak:", p0.peak_bytes, " dmo peak:", p1.peak_bytes)
p0.validate(); p1.validate()
verify_plan(g, p0)
verify_plan(g, p1)
print("numeric verification passed (naive + dmo)")
assert p1.peak_bytes < p0.peak_bytes
print("OK")
