"""CI smoke: the flagship fused band-chain program traces with
``interpret=False``.

CPU runners cannot MLIR-lower a TPU ``pallas_call`` (Mosaic refuses off-TPU),
but abstract tracing still validates everything the interpreter does not:
grid/block specs, scratch shapes, operand dtypes and the donated-arena
aliasing of every launch. This catches fused-kernel regressions that only
bite under real compilation — without needing a TPU in CI.

Asserts the acceptance shape on the way: exactly one fused spec covering the
whole 16-band + concat chain, one ``pallas_call`` equation per lowered spec
(the 17-launch region collapsed to 1).

Usage::

    PYTHONPATH=src python scripts/fused_smoke.py
"""
from __future__ import annotations


def main() -> None:
    import jax
    import jax.numpy as jnp
    from repro.core import exec as X
    from repro.core import zoo
    from repro.core.exec.pallas_backend import PallasExecutor
    from repro.core.pipeline import compile as compile_graph
    from repro.kernels import arena_ops

    cp = compile_graph(zoo.TABLE3_MODELS["mobilenet_v1_0.25_128_8bit"][0]())
    graph, plan = cp.graph, cp.plan
    bp = cp.legalised()
    assert bp is not None, "flagship must legalise for blocks"

    weights = X.synth_weights(graph)
    quant = X.calibrate(graph, 0, weights) if X.needs_quant(graph) else None
    specs = PallasExecutor(layout="blocks",
                           interpret=True).lower_blocks(bp, quant)
    fused = [s for s in specs if s.kind == "fused"]
    assert len(fused) == 1, f"expected 1 fused chain, got {len(fused)}"
    assert len(fused[0].stages) >= 16, \
        f"flagship chain too short: {len(fused[0].stages)} stages"

    wflat = []
    for op in plan.order:
        if op.kind in arena_ops.WEIGHTED_KINDS:
            if quant is not None and id(op) in quant.weights_q:
                wflat.append(jnp.asarray(
                    quant.weights_q[id(op)]["filter"], jnp.int8))
            else:
                wflat.append(jnp.asarray(
                    weights[id(op)]["filter"], jnp.float32))

    arena = jnp.zeros((bp.total_rows, bp.arena_rowlen),
                      jnp.int8 if bp.dtype_bytes == 1 else jnp.float32)
    fn = arena_ops.lower_program(specs, interpret=False)
    jaxpr = jax.make_jaxpr(fn)(arena, *wflat)
    n_calls = str(jaxpr).count("pallas_call")
    assert n_calls == len(specs), (n_calls, len(specs))
    print(f"fused compiled-lowering smoke OK: {n_calls} pallas_call "
          f"launches for {len(specs)} specs "
          f"(chain of {len(fused[0].stages)} ops -> 1), interpret=False")


if __name__ == "__main__":
    main()
