"""Diff two ``BENCH_*.json`` artifacts and fail on perf regressions.

Compares the structural per-model metrics (arena peaks, blocked rows,
packed-layout padding overheads, streaming window rows/bytes, pallas
launch counts) of two
``benchmarks.run --json`` artifacts over their *common* keys and exits
non-zero when any metric regresses by more than the threshold (default 5%).
Structural metrics are machine-independent, so the gate is deterministic;
wall-clock metrics (``exec_us_per_call``, ``compile_s``, ``wall_s``) are
noisy across runners and only checked when ``--timing`` is passed.

``--series`` instead tabulates one metric's trajectory across *all* the
committed artifacts (default: every ``BENCH_*.json`` next to the newest
one, sorted by PR number) — the per-model peak history of the whole PR
stack in one table.

Usage::

    PYTHONPATH=src python scripts/bench_diff.py BENCH_pr6.json BENCH_pr7.json
    PYTHONPATH=src python scripts/bench_diff.py old.json new.json \
        --threshold 2 --timing
    PYTHONPATH=src python scripts/bench_diff.py --series
    PYTHONPATH=src python scripts/bench_diff.py --series --metric blocked_kb

Exit status: 0 = no regressions, 1 = at least one metric regressed
(``--series`` is informational and always exits 0).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

#: Structural per-model metrics: (metric, better) where ``better`` is the
#: direction of improvement. Keys absent from either artifact are skipped,
#: so new fields never break diffs against older artifacts.
MODEL_METRICS = {
    "dmo_kb": "lower",                 # planned arena peak
    "blocked_kb": "lower",             # legalised padded arena
    "blocked_rows": "lower",
    "window_rows": "lower",            # streaming VMEM-resident rows
    "window_resident_bytes": "lower",
    "launches": "lower",               # pallas_call count (fused chains = 1)
    "saving_pct": "higher",
    "baseline_kb": "equal",            # graph-derived: any drift is a bug
    "fixed_dmo_kb": "lower",           # best fixed-order plan (pre order-search)
    "padding_overhead_pct": "lower",   # shipped layout's tiling tax over dmo_kb
    "packed_peak_kb": "lower",         # padded peak of the shipped layout
}

#: Wall-clock metrics, compared only under ``--timing``.
TIMING_MODEL_METRICS = {"compile_s": "lower", "wall_s": "lower",
                        "order_search_s": "lower"}


def _pct(old: float, new: float) -> float:
    return 100.0 * (new - old) / old if old else 0.0


def _judge(better: str, old: float, new: float, threshold: float):
    """-> (is_regression, is_improvement) for one metric pair."""
    delta = _pct(old, new)
    if better == "equal":
        return abs(delta) > threshold, False
    if better == "higher":
        delta = -delta
    return delta > threshold, delta < 0


def diff(old: dict, new: dict, threshold: float = 5.0,
         timing: bool = False, skip: tuple = ()) -> tuple:
    """-> (regressions, improvements): lists of printable lines."""
    regressions, improvements = [], []

    def compare(scope: str, metrics: dict, olds: dict, news: dict) -> None:
        for metric, better in sorted(metrics.items()):
            if metric in skip or metric not in olds or metric not in news:
                continue
            o, n = olds[metric], news[metric]
            if not isinstance(o, (int, float)) or isinstance(o, bool):
                continue
            bad, good = _judge(better, float(o), float(n), threshold)
            line = f"{scope}.{metric}: {o} -> {n} ({_pct(o, n):+.1f}%)"
            if bad:
                regressions.append(line)
            elif good:
                improvements.append(line)

    model_metrics = dict(MODEL_METRICS)
    if timing:
        model_metrics.update(TIMING_MODEL_METRICS)
    for name in sorted(set(old.get("models", {})) & set(new.get("models", {}))):
        compare(f"models.{name}", model_metrics,
                old["models"][name], new["models"][name])

    if timing:
        o_us, n_us = old.get("exec_us_per_call", {}), \
            new.get("exec_us_per_call", {})
        compare("exec_us_per_call", {k: "lower" for k in o_us},
                o_us, n_us)

    return regressions, improvements


def _series_key(path: str):
    """Sort artifacts by embedded PR number (BENCH_pr7.json -> 7), falling
    back to lexical order for non-conforming names."""
    m = re.search(r"pr(\d+)", os.path.basename(path))
    return (0, int(m.group(1))) if m else (1, os.path.basename(path))


def series(paths, metric: str = "dmo_kb") -> list:
    """-> printable table lines: ``metric`` per model across artifacts."""
    arts = []
    for p in sorted(paths, key=_series_key):
        with open(p) as f:
            data = json.load(f)
        label = re.sub(r"^BENCH_|\.json$", "", os.path.basename(p))
        arts.append((label, data.get("models", {})))
    names = sorted({n for _, models in arts for n in models})
    widths = [max([len("model")] + [len(n) for n in names])] + [
        max(len(label), 8) for label, _ in arts]
    rows = [["model"] + [label for label, _ in arts]]
    for n in names:
        row = [n]
        for _, models in arts:
            if n not in models:
                # the model itself predates (or was dropped from) this
                # artifact — distinct from a model that exists but lacks
                # the metric
                row.append("(absent)")
                continue
            v = models[n].get(metric)
            # older artifacts may predate the metric or carry it as a
            # non-numeric field (e.g. packing="legacy") — print "-"
            numeric = isinstance(v, (int, float)) and not isinstance(v, bool)
            row.append(f"{v:g}" if numeric else "-")
        rows.append(row)
    lines = ["  ".join(c.ljust(w) if i == 0 else c.rjust(w)
                       for i, (c, w) in enumerate(zip(row, widths)))
             for row in rows]
    lines.append(f"# metric: {metric}, {len(arts)} artifacts, "
                 f"{len(names)} models")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_*.json artifacts, fail on regressions")
    ap.add_argument("paths", nargs="*", metavar="ARTIFACT",
                    help="two artifacts (old new) to diff, or any number "
                         "under --series (default: ./BENCH_*.json)")
    ap.add_argument("--threshold", type=float, default=5.0,
                    help="regression tolerance in percent (default 5)")
    ap.add_argument("--timing", action="store_true",
                    help="also gate wall-clock metrics (noisy across "
                         "machines; off by default)")
    ap.add_argument("--skip", action="append", default=[], metavar="METRIC",
                    help="metric name to exclude (repeatable) — for "
                         "intentional, documented trade-offs")
    ap.add_argument("--series", action="store_true",
                    help="tabulate --metric across all given artifacts "
                         "(or ./BENCH_*.json) instead of diffing two")
    ap.add_argument("--metric", default="dmo_kb",
                    help="per-model metric for --series (default dmo_kb)")
    args = ap.parse_args(argv)

    if args.series:
        paths = args.paths or sorted(glob.glob("BENCH_*.json"))
        if not paths:
            ap.error("--series: no BENCH_*.json artifacts found")
        for line in series(paths, args.metric):
            print(line)
        return 0

    if len(args.paths) != 2:
        ap.error("expected exactly two artifacts: OLD NEW (or use --series)")
    with open(args.paths[0]) as f:
        old = json.load(f)
    with open(args.paths[1]) as f:
        new = json.load(f)

    regressions, improvements = diff(old, new, args.threshold, args.timing,
                                     tuple(args.skip))

    for line in improvements:
        print(f"improved   {line}")
    for line in regressions:
        print(f"REGRESSED  {line}")
    common = len(set(old.get("models", {})) & set(new.get("models", {})))
    print(f"# {common} common models, {len(improvements)} improved, "
          f"{len(regressions)} regressed (threshold {args.threshold}%)")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
