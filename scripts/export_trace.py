"""Export one arena execution as a chrome://tracing JSON file.

Runs the compiled plan op-by-op on the numpy arena interpreter (the
reference execution-order model) and writes:

- one ``"X"`` duration span per op (name, kind, per-op wall time, the op's
  arena byte range — and, when the plan legalises, its streaming live
  window ``[lo, hi)`` in arena rows);
- ``"C"`` counter tracks: ``arena_live_bytes`` (bytes of the byte arena
  occupied by tensors live at each step — the planner's occupancy curve)
  and ``window_rows`` (each op's streaming VMEM-resident rows from
  :meth:`~repro.core.planner.BlockPlan.window_schedule`).

Open the file at ``chrome://tracing`` (or https://ui.perfetto.dev).

Usage::

    PYTHONPATH=src python scripts/export_trace.py            # reduced model
    PYTHONPATH=src python scripts/export_trace.py \
        --model mobilenet_v1_0.25_128_8bit --out trace.json
"""
from __future__ import annotations

import argparse
import json
import time


def _build(name: str):
    from repro.core import zoo
    if name in zoo.TABLE3_MODELS:
        return zoo.TABLE3_MODELS[name][0]()
    if name == "mobilenet_v1_0.25_32_8bit":
        return zoo.mobilenet_v1(0.25, 32, 1)
    if name == "mobilenet_v1_0.25_32_f32":
        return zoo.mobilenet_v1(0.25, 32, 4)
    raise SystemExit(f"unknown model {name!r}: pick a TABLE3_MODELS name, "
                     "'mobilenet_v1_0.25_32_8bit' or "
                     "'mobilenet_v1_0.25_32_f32'")


def trace_events(cp) -> list:
    """Chrome-tracing events for one op-by-op arena execution of ``cp``
    (a :class:`~repro.core.pipeline.CompiledPlan`)."""
    from repro.core import exec as X
    from repro.core.exec.numpy_backend import ArenaExec

    plan, graph = cp.plan, cp.graph
    weights = X.synth_weights(graph)
    quant = X.calibrate(graph, 0, weights) if X.needs_quant(graph) else None
    inputs = (X.quant_inputs(graph, quant) if quant is not None
              else X.random_inputs(graph))
    ex = ArenaExec(graph, plan, inputs, weights=weights, quant=quant)

    scopes = graph.scopes(plan.order)
    windows = {}
    bp = cp.legalised()
    if bp is not None:
        windows = {w.op_name: w for w in bp.window_schedule().windows}

    events, t0 = [], time.perf_counter()
    for step, op in enumerate(plan.order):
        ts = (time.perf_counter() - t0) * 1e6
        ex.execute(op)
        dur = (time.perf_counter() - t0) * 1e6 - ts
        args = {"kind": op.kind, "step": step}
        s = op.output.storage()
        if s in plan.offsets:
            args["arena_bytes"] = [plan.offsets[s],
                                   plan.offsets[s] + s.nbytes]
        w = windows.get(op.name)
        if w is not None:
            args["window_rows"] = [w.lo, w.hi]
            args["resident_rows"] = w.resident_rows
        events.append({"name": op.name, "cat": op.kind, "ph": "X",
                       "ts": round(ts, 3), "dur": round(max(dur, 0.001), 3),
                       "pid": 1, "tid": 1, "args": args})
        live = sum(t.nbytes for t, (s0, e0) in scopes.items()
                   if s0 <= step <= e0)
        events.append({"name": "arena_live_bytes", "ph": "C",
                       "ts": round(ts, 3), "pid": 1,
                       "args": {"bytes": int(live)}})
        if w is not None:
            events.append({"name": "window_rows", "ph": "C",
                           "ts": round(ts, 3), "pid": 1,
                           "args": {"rows": int(w.resident_rows)}})
    return events


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="export an arena execution as chrome://tracing JSON")
    ap.add_argument("--model", default="mobilenet_v1_0.25_32_8bit")
    ap.add_argument("--out", default="trace.json")
    args = ap.parse_args(argv)

    from repro.core.pipeline import compile as compile_graph
    cp = compile_graph(_build(args.model))
    events = trace_events(cp)
    with open(args.out, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms",
                   "otherData": {"model": args.model,
                                 "peak_bytes": cp.peak_bytes}}, f)
        f.write("\n")
    print(f"wrote {args.out}: {len(events)} events over "
          f"{len(cp.plan.order)} ops")


if __name__ == "__main__":
    main()
