"""Export one arena execution as a chrome://tracing JSON file.

Runs the compiled plan on the selected execution route and writes:

- one ``"X"`` duration span per *launch* (per op on the numpy route, per
  lowered spec — i.e. per ``pallas_call`` — on the pallas routes, so a
  fused band chain shows as ONE span with its stage count), with the
  launch's arena byte/row range and, when the plan legalises, its streaming
  live window ``[lo, hi)`` in arena rows;
- ``"C"`` counter tracks: ``arena_live_bytes`` (numpy route: bytes of the
  byte arena occupied by tensors live at each step — the planner's
  occupancy curve), ``arena_padded_bytes`` (the same liveness costed in
  the legalised row-blocked layout — whole padded arena rows per tensor,
  so the gap between the two curves IS the lane-padding tax the packed
  layouts shrink), ``window_rows`` (each op's streaming VMEM-resident
  rows), and ``pallas_launches`` (pallas routes: cumulative launch count).

Routes:

- ``numpy``     — op-by-op on the numpy arena interpreter (reference);
- ``flat``      — the flat byte Pallas program (interpret mode);
- ``blocked``   — the row-blocked typed Pallas program;
- ``streaming`` — the double-buffered streaming Pallas program;
- ``fused``     — alias of ``blocked`` that *requires* the winning graph to
  carry fused band chains (errors out otherwise), for eyeballing the
  one-launch-per-chain collapse;
- ``serve``     — a closed-loop :class:`~repro.serve.PlanServer` run:
  request-level spans (queue wait -> batch assembly -> execute), one
  trace track per request, so the deadline-batching behaviour is visible
  request by request.

Open the file at ``chrome://tracing`` (or https://ui.perfetto.dev).

Usage::

    PYTHONPATH=src python scripts/export_trace.py            # reduced model
    PYTHONPATH=src python scripts/export_trace.py \
        --model mobilenet_v1_0.25_128_8bit --route fused --out trace.json
"""
from __future__ import annotations

import argparse
import json
import time

ROUTES = ("numpy", "flat", "blocked", "streaming", "fused", "serve")


def _build(name: str):
    from repro.core import zoo
    if name in zoo.TABLE3_MODELS:
        return zoo.TABLE3_MODELS[name][0]()
    if name == "mobilenet_v1_0.25_32_8bit":
        return zoo.mobilenet_v1(0.25, 32, 1)
    if name == "mobilenet_v1_0.25_32_f32":
        return zoo.mobilenet_v1(0.25, 32, 4)
    raise SystemExit(f"unknown model {name!r}: pick a TABLE3_MODELS name, "
                     "'mobilenet_v1_0.25_32_8bit' or "
                     "'mobilenet_v1_0.25_32_f32'")


def _autoparams(graph):
    from repro.core import exec as X
    weights = X.synth_weights(graph)
    quant = X.calibrate(graph, 0, weights) if X.needs_quant(graph) else None
    inputs = (X.quant_inputs(graph, quant) if quant is not None
              else X.random_inputs(graph))
    return weights, quant, inputs


def trace_events(cp) -> list:
    """Chrome-tracing events for one op-by-op arena execution of ``cp``
    (a :class:`~repro.core.pipeline.CompiledPlan`) on the numpy route."""
    from repro.core.exec.numpy_backend import ArenaExec

    plan, graph = cp.plan, cp.graph
    weights, quant, inputs = _autoparams(graph)
    ex = ArenaExec(graph, plan, inputs, weights=weights, quant=quant)

    scopes = graph.scopes(plan.order)
    windows = {}
    bp = cp.legalised()
    if bp is not None:
        windows = {w.op_name: w for w in bp.window_schedule().windows}

    events, t0 = [], time.perf_counter()
    for step, op in enumerate(plan.order):
        ts = (time.perf_counter() - t0) * 1e6
        ex.execute(op)
        dur = (time.perf_counter() - t0) * 1e6 - ts
        args = {"kind": op.kind, "step": step}
        s = op.output.storage()
        if s in plan.offsets:
            args["arena_bytes"] = [plan.offsets[s],
                                   plan.offsets[s] + s.nbytes]
        w = windows.get(op.name)
        if w is not None:
            args["window_rows"] = [w.lo, w.hi]
            args["resident_rows"] = w.resident_rows
        events.append({"name": op.name, "cat": op.kind, "ph": "X",
                       "ts": round(ts, 3), "dur": round(max(dur, 0.001), 3),
                       "pid": 1, "tid": 1, "args": args})
        live = sum(t.nbytes for t, (s0, e0) in scopes.items()
                   if s0 <= step <= e0)
        events.append({"name": "arena_live_bytes", "ph": "C",
                       "ts": round(ts, 3), "pid": 1,
                       "args": {"bytes": int(live)}})
        if bp is not None:
            # what the same liveness costs in the legalised (row-blocked,
            # possibly packed) layout: whole padded arena rows per tensor
            padded = sum(bp.layouts[t].rows * bp.row_bytes
                         for t, (s0, e0) in scopes.items()
                         if s0 <= step <= e0 and t in bp.layouts)
            events.append({"name": "arena_padded_bytes", "ph": "C",
                           "ts": round(ts, 3), "pid": 1,
                           "args": {"bytes": int(padded)}})
        if w is not None:
            events.append({"name": "window_rows", "ph": "C",
                           "ts": round(ts, 3), "pid": 1,
                           "args": {"rows": int(w.resident_rows)}})
    return events


def _launch_names(order) -> list:
    """One display name per lowered spec, mirroring the backend's lowering
    order: reshapes dropped, a fused chain collapsed to its chain name at
    the first member's position."""
    names, emitted = [], set()
    for op in order:
        if op.kind == "reshape":
            continue
        cname = op.params.get("fuse_chain")
        if cname is None:
            names.append(op.name)
        elif cname not in emitted:
            emitted.add(cname)
            names.append(cname)
    return names


def trace_pallas_events(cp, route: str) -> list:
    """Chrome-tracing events for one launch-by-launch pallas execution of
    ``cp`` — each span is one ``pallas_call`` (a fused chain = one span)."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core import exec as X
    from repro.core.exec.pallas_backend import PallasExecutor
    from repro.kernels import arena_ops

    plan, graph = cp.plan, cp.graph
    weights, quant, inputs = _autoparams(graph)
    bp = cp.legalised()
    windows = {}

    if route == "flat":
        be = PallasExecutor(layout="flat", interpret=True)
        specs = be.lower(plan, quant)
        arena = np.zeros(plan.peak_bytes, np.uint8)
        for t in graph.tensors:
            if t.kind == "input":
                s, off = t.storage(), plan.offsets[t.storage()]
                v = np.asarray(inputs[t.name],
                               X.arena_dtype(s.dtype_bytes)).reshape(-1)
                arena[off:off + s.nbytes] = v.view(np.uint8)
    else:
        if bp is None:
            raise SystemExit(
                f"--route {route} needs a legalisable plan and "
                f"{graph.name!r} does not legalise for blocks")
        if route == "fused" and not any(
                "fuse_chain" in op.params for op in bp.order):
            raise SystemExit(
                f"--route fused: {graph.name!r} carries no fused band "
                "chains (compile picked an unfused variant)")
        if route == "streaming":
            be = PallasExecutor(mode="streaming", interpret=True)
            specs = be.lower_stream(bp, quant)
            windows = {w.op_name: w for w in bp.window_schedule().windows}
        else:
            be = PallasExecutor(layout="blocks", interpret=True)
            specs = be.lower_blocks(bp, quant)
        arena = PallasExecutor._seed_block_arena(bp, graph, inputs)

    wflat = []
    for op in plan.order:
        if op.kind in arena_ops.WEIGHTED_KINDS:
            if quant is not None and id(op) in quant.weights_q:
                wflat.append(jnp.asarray(quant.weights_q[id(op)]["filter"],
                                         jnp.int8))
            else:
                wflat.append(jnp.asarray(weights[id(op)]["filter"],
                                         jnp.float32))

    names = _launch_names(plan.order)
    assert len(names) == len(specs), (len(names), len(specs))

    events, t0 = [], time.perf_counter()
    buf, wi = jnp.asarray(arena), 0
    for step, (name, spec) in enumerate(zip(names, specs)):
        nw = arena_ops.spec_weight_count(spec)
        ws = tuple(wflat[wi:wi + nw])
        wi += nw
        ts = (time.perf_counter() - t0) * 1e6
        buf = arena_ops.apply_op(buf, spec, ws, interpret=True)
        buf.block_until_ready()
        dur = (time.perf_counter() - t0) * 1e6 - ts
        args = {"kind": spec.kind, "step": step, "route": route}
        if spec.kind == "fused":
            args["stages"] = len(spec.stages)
            args["scratch_rows"] = spec.scratch_rows
        if spec.rowlen:
            args["arena_rows"] = [spec.out_off,
                                  spec.out_off + (spec.out_rows[0]
                                                  if spec.out_rows else 0)]
        else:
            args["arena_bytes"] = [spec.out_off, spec.out_off]
        w = windows.get(name)
        if w is not None:
            args["window_rows"] = [w.lo, w.hi]
            args["resident_rows"] = w.resident_rows
        events.append({"name": name, "cat": spec.kind, "ph": "X",
                       "ts": round(ts, 3), "dur": round(max(dur, 0.001), 3),
                       "pid": 1, "tid": 1, "args": args})
        events.append({"name": "pallas_launches", "ph": "C",
                       "ts": round(ts, 3), "pid": 1,
                       "args": {"launches": step + 1}})
        if w is not None:
            events.append({"name": "window_rows", "ph": "C",
                           "ts": round(ts, 3), "pid": 1,
                           "args": {"rows": int(w.resident_rows)}})
    return events


def trace_serve_events(graph, n_requests: int = 64) -> list:
    """Chrome-tracing events for a closed-loop PlanServer run: each request
    is one trace track (tid = request id) carrying its queue-wait, batch-
    assembly and execute spans, plus a queue-depth counter per flush."""
    import numpy as np
    from repro.serve import PlanServer

    server = PlanServer(graph)
    rng = np.random.default_rng(1)
    shapes = {t.name: tuple(t.shape)
              for t in graph.tensors if t.kind == "input"}
    for _ in range(n_requests):
        server.submit({nm: rng.standard_normal(sh).astype(np.float32)
                       for nm, sh in shapes.items()})
        server.step()
    server.drain()

    events = []
    for s in server.spans():
        ts = s["t_submit"] * 1e6
        for phase in ("queue_wait", "assemble", "execute"):
            dur = s[f"{phase}_s"] * 1e6
            events.append({
                "name": phase, "cat": "serve", "ph": "X",
                "ts": round(ts, 3), "dur": round(max(dur, 0.001), 3),
                "pid": 1, "tid": s["rid"],
                "args": {"rid": s["rid"], "batch": s["batch"]}})
            ts += dur
    st = server.stats()
    events.append({"name": "serve_stats", "ph": "C", "ts": 0.0, "pid": 1,
                   "args": {"throughput_inf_s": st["throughput_inf_s"] or 0}})
    return events


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="export an arena execution as chrome://tracing JSON")
    ap.add_argument("--model", default="mobilenet_v1_0.25_32_8bit")
    ap.add_argument("--route", default="numpy", choices=ROUTES,
                    help="execution route to trace (default: numpy)")
    ap.add_argument("--requests", type=int, default=64,
                    help="request count for --route serve (default 64)")
    ap.add_argument("--out", default="trace.json")
    args = ap.parse_args(argv)

    from repro.core.pipeline import compile as compile_graph
    cp = compile_graph(_build(args.model))
    if args.route == "numpy":
        events = trace_events(cp)
    elif args.route == "serve":
        events = trace_serve_events(cp.original, args.requests)
    spans = sum(1 for e in events if e["ph"] == "X")
    with open(args.out, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms",
                   "otherData": {"model": args.model, "route": args.route,
                                 "peak_bytes": cp.peak_bytes}}, f)
        f.write("\n")
    print(f"wrote {args.out}: {len(events)} events, {spans} launches "
          f"over {len(cp.plan.order)} ops ({args.route} route)")


if __name__ == "__main__":
    main()
