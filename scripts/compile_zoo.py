"""Fan a zoo x batches compile grid across worker processes.

Thin CLI over :func:`repro.core.pipeline.compile_many`: the workers share
the content-addressed disk plan-cache (atomic writes, so concurrent
compiles of one key race benignly), which is what the CI ``serving`` step
exercises — a second run over the same grid must be served from the disk
entries the first run's workers wrote.

A real script file, not an inline heredoc, because multiprocessing's spawn
start method re-imports ``__main__`` in every worker: stdin-fed scripts
cannot spawn, and module-level side effects would re-execute per worker
(all env setup stays under ``main()``).

Usage::

    PYTHONPATH=src python scripts/compile_zoo.py --workers 2 --batches 1 2
    PYTHONPATH=src python scripts/compile_zoo.py \
        --models mobilenet_v1_0.25_128_8bit --batches 1 2 4 8 --expect-disk-hits
"""
from __future__ import annotations

import argparse
import json
import sys

#: Reduced executable builds: cheap enough for a CI grid, real enough to
#: exercise split/fuse winners at every batch.
DEFAULT_MODELS = ("mobilenet_v1_0.25_32_8bit", "mobilenet_v1_0.25_32_f32")


def _build(name: str):
    from repro.core import zoo
    if name in zoo.TABLE3_MODELS:
        return zoo.TABLE3_MODELS[name][0]()
    if name == "mobilenet_v1_0.25_32_8bit":
        return zoo.mobilenet_v1(0.25, 32, 1)
    if name == "mobilenet_v1_0.25_32_f32":
        return zoo.mobilenet_v1(0.25, 32, 4)
    raise SystemExit(f"unknown model {name!r}: pick a TABLE3_MODELS name, "
                     "'mobilenet_v1_0.25_32_8bit' or "
                     "'mobilenet_v1_0.25_32_f32'")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="compile a zoo x batches grid across worker processes")
    ap.add_argument("--models", nargs="+", default=list(DEFAULT_MODELS),
                    metavar="NAME")
    ap.add_argument("--batches", nargs="+", type=int, default=[1, 2, 4, 8])
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--expect-disk-hits", action="store_true",
                    help="fail unless every job was served from the disk "
                         "plan-cache (run the same grid twice: the second "
                         "run proves cross-process sharing)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the per-job summaries as JSON")
    args = ap.parse_args(argv)

    from repro.core.pipeline import compile_many
    graphs = [_build(n) for n in args.models]
    res = compile_many(graphs, batches=args.batches, workers=args.workers)

    for r in res:
        print(f"{r['graph']} b={r['batch']}: peak={r['peak_bytes']} "
              f"({r['saving_pct']}% vs {r['baseline_bytes']}) "
              f"disk_hits={r['disk_hits']} wall={r['wall_s']}s")
    hits = sum(r["disk_hits"] for r in res)
    print(f"# {len(res)} jobs over {args.workers} workers, "
          f"{hits} disk-cache hits")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1, sort_keys=True)
            f.write("\n")

    if args.expect_disk_hits and hits < len(res):
        print(f"# FAIL: expected {len(res)} disk-cache hits, got {hits}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
