"""End-to-end training driver: a ~100M-parameter qwen2.5-family model on the
synthetic bigram corpus for a few hundred steps.

    PYTHONPATH=src python examples/train_100m.py              # ~25M, fast
    PYTHONPATH=src python examples/train_100m.py --size 100m  # full 100M

Demonstrates: data pipeline -> packed batches -> donated train_step (DMO's
in-place state update) -> checkpointing -> resume.
"""
import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.optim.adamw import OptConfig
from repro.train import steps as TS

SIZES = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)  ~ param count
    "25m": (4, 384, 6, 2, 1024, 8192),
    "100m": (8, 640, 10, 2, 2048, 16384),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=list(SIZES), default="25m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    L, d, h, kv, ff, v = SIZES[args.size]
    cfg = dataclasses.replace(
        get_arch("qwen2.5-3b"), name=f"qwen2.5-{args.size}", num_layers=L,
        d_model=d, num_heads=h, num_kv_heads=kv, head_dim=64, d_ff=ff,
        vocab_size=v, dtype="float32")
    opt = OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    state = TS.init_state(cfg, jax.random.PRNGKey(0), opt)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(state["params"]))
    print(f"model: {cfg.name}  params={n / 1e6:.1f}M  "
          f"tokens/step={args.batch * args.seq}")

    data = SyntheticCorpus(DataConfig(cfg.vocab_size, args.seq, args.batch))
    batches = data.packed_batches()
    step_fn = jax.jit(
        lambda st, b: TS.train_step(cfg, opt, st, b, remat=False),
        donate_argnums=(0,))

    t0, losses = time.time(), []
    for i in range(args.steps):
        b = {k: jnp.asarray(x) for k, x in next(batches).items()}
        state, m = step_fn(state, b)
        losses.append(float(m["loss"]))
        if i % 25 == 0 or i == args.steps - 1:
            tok_s = (i + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {i:4d}  loss={losses[-1]:7.4f}  "
                  f"lr={float(m['lr']):.2e}  tok/s={tok_s:,.0f}", flush=True)
    p = store.save(args.ckpt_dir, state, step=args.steps)
    print(f"first-10 mean loss {np.mean(losses[:10]):.3f} -> "
          f"last-10 mean {np.mean(losses[-10:]):.3f}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss must decrease"
    print(f"checkpoint: {p}")


if __name__ == "__main__":
    main()
