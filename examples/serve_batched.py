"""Batched serving with donated KV caches (reduced configs of three
families: dense GQA, MLA, attention-free RWKV).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as T
from repro.serve.engine import Engine, ServeConfig

for arch in ("yi-6b", "minicpm3-4b", "rwkv6-1.6b"):
    cfg = get_arch(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(cache_len=96, max_new_tokens=24))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (8, 32)).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts)
    dt = time.time() - t0
    kinds = {"gqa": "KV ring cache", "mla": "compressed-latent cache",
             "none": "O(1) recurrent state"}
    print(f"{arch:14s} [{kinds[cfg.attention]:24s}] "
          f"generated {out.shape[0]}x{out.shape[1]} tokens in {dt:5.1f}s "
          f"-> {out[0, :10].tolist()}...")
print("\nall caches are donated every step: the serving-side realisation of "
      "the paper's in-place (O_s=|out|) overlap.")
