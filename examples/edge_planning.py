"""The paper's deployability argument, §IV: on an STM32F103 (96 KB SRAM,
768 KB flash) the smallest MobileNet only fits WITH diagonal memory
optimisation. One pipeline compile per model gives both the baseline and the
DMO plan.

    PYTHONPATH=src python examples/edge_planning.py
"""
from repro.core import zoo
from repro.core.pipeline import compile as compile_graph

SRAM_KB = 96          # STM32F103xF
FLASH_KB = 768

print(f"target: STM32F103 — SRAM {SRAM_KB} KB, flash {FLASH_KB} KB\n")
print(f"{'model':30s} {'weights':>9s} {'orig':>8s} {'DMO':>8s}  deployable")
for name in ("mobilenet_v1_0.25_128_8bit", "mobilenet_v1_1.0_224_8bit"):
    build, _, _ = zoo.TABLE3_MODELS[name]
    g = build()
    # weights: 8-bit params of convs/fc (counted from graph shapes)
    weights = 0
    for op in g.ops:
        if op.kind == "conv2d":
            kh, kw = op.params["kernel"]
            weights += kh * kw * op.inputs[0].shape[-1] * op.output.shape[-1]
        elif op.kind == "depthwise_conv2d":
            kh, kw = op.params["kernel"]
            weights += kh * kw * op.output.shape[-1]
        elif op.kind == "fully_connected":
            weights += op.inputs[0].elems * op.output.elems
    cp = compile_graph(g, method="algorithmic", budget_s="auto")
    orig, opt = cp.baseline_bytes, cp.peak_bytes
    # leave 4 KB of SRAM for stack + runtime (a 96 KB arena on a 96 KB part
    # leaves nothing — the paper's point)
    budget = (SRAM_KB - 4) * 1024
    dep_orig = orig <= budget and weights <= FLASH_KB * 1024
    dep_dmo = opt <= budget and weights <= FLASH_KB * 1024
    verdict = ("only with DMO" if dep_dmo and not dep_orig else
               "yes" if dep_dmo else "no")
    print(f"{name:30s} {weights / 1024:7.0f}KB {orig / 1024:7.0f}KB "
          f"{opt / 1024:7.0f}KB  {verdict}")

print("\n(paper §IV: v1 0.25 128 8-bit needs 96 KB originally — exactly all "
      "of the SRAM, leaving nothing for stack/runtime; DMO's 64 KB makes it "
      "deployable. Weights: 623 KB of the 768 KB flash.)")

# ---------------------------------------------------------------------------
# And the plan is not just a layout — it runs. Since the dtype-aware
# executor subsystem the 8-bit edge build itself executes: int8 activations
# in one flat byte arena, int32 accumulation, per-tensor requantisation
# (calibrated from a float reference run) — on both backends. Since the
# banded-O_s layer the winning variant is the SPLIT graph (row bands with
# explicit per-band pads), so the arena that runs is a composed
# split+overlap peak (the table above adds the ILS search on top).
# ---------------------------------------------------------------------------
print("\nexecuting the planned arena (the paper's 8-bit build itself):")
ecp = compile_graph(zoo.mobilenet_v1(0.25, 128, 1), backend="pallas")
bands = sum(1 for op in ecp.graph.ops if "row_range" in op.params)
for backend in ("numpy", "pallas"):
    outs = ecp.execute(backend=backend)
    dtypes = ", ".join(sorted(str(v.dtype) for v in outs.values()))
    print(f"  backend={backend:6s} ran {len(ecp.plan.order)} ops "
          f"({bands} split bands) in one "
          f"{ecp.peak_bytes / 1024:.1f} KB int8 byte arena "
          f"({ecp.saving_pct:.1f}% below the {ecp.baseline_bytes / 1024:.1f}"
          f" KB baseline); outputs: {', '.join(sorted(outs))} ({dtypes})")
