"""Quickstart: the DMO core API in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.graph import Graph
from repro.core.overlap import (safe_overlap_algorithmic,
                                safe_overlap_analytic, safe_overlap_trace)
from repro.core.pipeline import compile as compile_graph
from repro.core import zoo

# ---------------------------------------------------------------------------
# 1. Safe overlap O_s, three ways (paper §III)
# ---------------------------------------------------------------------------
g = Graph("demo")
x = g.tensor("x", (112, 112, 96), 4, "input")
g.op("depthwise_conv2d", [x], (56, 56, 96),
     dict(kernel=(3, 3), stride=(2, 2), padding="same"), name="dw")
op = g.ops[0]
print("Table I depthwise conv, O_s in bytes:")
print("  algorithmic (exact):     ", safe_overlap_algorithmic(op), "(paper: 1204224)")
print("  analytic (lower bound):  ", safe_overlap_analytic(op), "(paper: 1193376)")

small = Graph("small")
xs = small.tensor("x", (14, 14, 8), 4, "input")
small.op("depthwise_conv2d", [xs], (7, 7, 8),
         dict(kernel=(3, 3), stride=(2, 2), padding="same"))
print("  bottom-up trace (small op):", safe_overlap_trace(small.ops[0]))

# ---------------------------------------------------------------------------
# 2. The whole paper in five lines: compile() chains op removal, op
#    splitting, serialisation orders, DMO planning and verification, caches
#    the result by graph signature, and reports against the non-overlapping
#    baseline (paper §II + §IV, Table III).
# ---------------------------------------------------------------------------
print("\nMobileNet v1 0.25 128 (8-bit) — the paper's flagship edge model:")
model = zoo.mobilenet_v1(0.25, 128, 1)
plan = compile_graph(model, budget_s="auto")     # autoscaled ILS (NP-hard)
print(f"  original arena: {plan.baseline_bytes / 1024:.0f} KB (paper: 96)")
print(f"  DMO arena:      {plan.peak_bytes / 1024:.0f} KB (paper: 64)")
print(f"  saving:         {plan.saving_pct:.1f}%  verified={plan.verified}")

again = compile_graph(zoo.mobilenet_v1(0.25, 128, 1), budget_s="auto")
print(f"  re-compile of the same graph: cache_hit={again.cache_hit} "
      f"({again.compile_s * 1e3:.2f} ms)")

# ---------------------------------------------------------------------------
# 3. Execute INSIDE the planned arena. compile(backend="pallas") verifies
#    three tiers — constraints, bit-exact numpy arena execution, and the
#    pallas kernel sequence (one flat donated buffer) cross-checked against
#    the numpy backend — and .execute() then runs on the chosen backend.
# ---------------------------------------------------------------------------
mini = Graph("mini")
h = mini.tensor("x", (12, 12, 3), 4, "input")
h = mini.op("conv2d", [h], (6, 6, 8),
            dict(kernel=(3, 3), stride=(2, 2), padding="same"))
h = mini.op("depthwise_conv2d", [h], (6, 6, 8),
            dict(kernel=(3, 3), stride=(1, 1), padding="same"))
h = mini.op("conv2d", [h], (6, 6, 16),
            dict(kernel=(1, 1), stride=(1, 1), padding="same"))
mini.op("softmax", [mini.op("fully_connected",
                            [mini.op("reshape", [h], (h.elems,))], (10,))],
        (10,), out_kind="output")
compiled = compile_graph(mini, verify="numeric", backend="pallas")
assert compiled.verified == "numeric+pallas"     # raises on any clobber
print("\nmini-net: arena execution bit-exact vs private buffers, and the "
      "pallas lowering matches the numpy backend ✓")
for be in ("numpy", "pallas"):
    outs = compiled.execute(backend=be)
    print(f"  executed on backend={be:6s} inside one "
          f"{compiled.peak_bytes}-byte arena "
          f"(peak {compiled.peak_bytes / 1024:.1f} KB, "
          f"outputs: {', '.join(sorted(outs))})")
print(compiled.report())
