"""Continuous batching: 6 requests with different prompt lengths share 3
slots of one donated KV cache; finished slots are recycled mid-flight.

    PYTHONPATH=src python examples/continuous_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as T
from repro.serve.continuous import ContinuousConfig, ContinuousEngine, Request

cfg = get_arch("qwen2.5-3b").reduced()
params = T.init_params(cfg, jax.random.PRNGKey(0))
eng = ContinuousEngine(cfg, params, ContinuousConfig(slots=3, cache_len=128))

rng = np.random.default_rng(0)
reqs = []
for i in range(6):
    plen = int(rng.integers(6, 40))
    reqs.append(Request(i, rng.integers(1, cfg.vocab_size, plen)
                        .astype(np.int32), max_new_tokens=8 + i))
    eng.submit(reqs[-1])

t0 = time.time()
steps = 0
while any(not r.done for r in reqs) and steps < 200:
    eng.step()
    steps += 1
dt = time.time() - t0

print(f"6 ragged requests through 3 slots in {steps} engine steps ({dt:.1f}s)")
for r in reqs:
    print(f"  req{r.rid}: prompt={len(r.tokens):2d} tok -> "
          f"{len(r.out)} generated {r.out[:6]}...")
print("\nslots are recycled in place — the scheduler-level face of the "
      "paper's storage-reuse discipline.")
