"""Benchmark: paper §II.C operation removal (concat elision) on SqueezeNet.

Branch outputs become views into the aggregated tensor, so the double copy
disappears. SqueezeNet's global peak is conv1-bound (our graph), so the
removal shows up in the fire-module region footprint; removal composes with
DMO inside the compile pipeline exactly as §II.C claims — compare a compile
with the removal pass toggled off against the default chain.
"""
from __future__ import annotations

import time

from repro.core.pipeline import compile as compile_graph
from repro.core.removal import remove_concats
from repro.core.zoo import squeezenet


def _fire_live(g):
    scopes = g.scopes()
    worst = 0
    for i, op in enumerate(g.ops):
        if "fire" in op.name:
            worst = max(worst, sum(t.nbytes for t, (a, b) in scopes.items()
                                   if a <= i <= b))
    return worst


def run(csv_rows):
    t0 = time.perf_counter()
    g = squeezenet()
    a, b = _fire_live(g), _fire_live(remove_concats(g))
    # split="off" on both sides so the delta is attributable to removal
    no_removal = compile_graph(
        g, method="algorithmic", split="off",
        passes=("baseline", "serialise", "plan", "verify"))
    with_removal = compile_graph(g, method="algorithmic", split="off")
    us = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("removal/squeezenet_fire_region", us,
                     f"{a / 1024:.0f}->{b / 1024:.0f}KB "
                     f"({100 * (1 - b / a):.0f}% of the concat-dominated "
                     f"region)"))
    csv_rows.append(("removal/squeezenet_peak_with_dmo", us,
                     f"orig={no_removal.baseline_bytes / 1024:.0f}KB "
                     f"dmo={no_removal.peak_bytes / 1024:.0f}KB "
                     f"removal+dmo={with_removal.peak_bytes / 1024:.0f}KB "
                     f"(peak is conv1-bound; techniques compose)"))
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
