"""Benchmark: paper §II.C operation removal (concat elision) on SqueezeNet.

Branch outputs become views into the aggregated tensor, so the double copy
disappears. SqueezeNet's global peak is conv1-bound (our graph), so the
removal shows up in the fire-module region footprint; removal composes with
DMO exactly as §II.C claims.
"""
from __future__ import annotations

import time

from repro.core.planner import plan_dmo, plan_original
from repro.core.removal import remove_concats
from repro.core.zoo import squeezenet


def _fire_live(g):
    scopes = g.scopes()
    worst = 0
    for i, op in enumerate(g.ops):
        if "fire" in op.name:
            worst = max(worst, sum(t.nbytes for t, (a, b) in scopes.items()
                                   if a <= i <= b))
    return worst


def run(csv_rows):
    t0 = time.perf_counter()
    g = squeezenet()
    g2 = remove_concats(g)
    a, b = _fire_live(g), _fire_live(g2)
    p0 = plan_original(g).peak_bytes
    p1 = plan_dmo(g2, method="algorithmic").peak_bytes
    us = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("removal/squeezenet_fire_region", us,
                     f"{a / 1024:.0f}->{b / 1024:.0f}KB "
                     f"({100 * (1 - b / a):.0f}% of the concat-dominated "
                     f"region)"))
    csv_rows.append(("removal/squeezenet_peak_with_dmo", us,
                     f"orig={p0 / 1024:.0f}KB removal+dmo={p1 / 1024:.0f}KB "
                     f"(peak is conv1-bound; techniques compose)"))
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
