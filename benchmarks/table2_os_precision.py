"""Benchmark: paper Table I/II — precision of the analytic O_s estimator.

Reproduces the exact numbers of the paper:
  * Table I depthwise conv (112,112,96)->(56,56,96), k3 s2, f32:
      algorithmic (exact) O_s = 1 204 224 B, analytic = 1 193 376 B (-0.18 %)
and reports exact-vs-estimate for the peak-defining ops of the three Table II
networks, plus a sweep over every conv/dw/pool op of MobileNet v1+v2 showing
the estimator is a lower bound everywhere (worst-case error reported).
"""
from __future__ import annotations

import time

from repro.core import zoo
from repro.core.graph import Graph
from repro.core.overlap import safe_overlap_algorithmic, safe_overlap_analytic


def table1_op() -> Graph:
    g = Graph("table1_dwconv")
    x = g.tensor("x", (112, 112, 96), 4, "input")
    g.op("depthwise_conv2d", [x], (56, 56, 96),
         dict(kernel=(3, 3), stride=(2, 2), padding="same", multiplier=1),
         name="dw", out_kind="output")
    return g


def run(csv_rows):
    t0 = time.perf_counter()
    op = table1_op().ops[0]
    exact = safe_overlap_algorithmic(op)
    est = safe_overlap_analytic(op)
    # the paper quotes the error relative to the model's ORIGINAL peak
    # (MobileNet v2 1.0 224: 5880 KB), not to O_s itself
    err = 100.0 * (exact - est) / (5880 * 1024)
    us = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("table2/dwconv_112_96_exact", us,
                     f"{exact} (paper 1204224)"))
    csv_rows.append(("table2/dwconv_112_96_estimate", us,
                     f"{est} (paper 1193376) err={err:.2f}% (paper 0.18%)"))
    assert exact == 1204224 and est == 1193376

    # sweep every overlappable op of the sequential models
    worst = (0.0, "")
    n_ops = 0
    for model in ("mobilenet_v1_1.0_224", "mobilenet_v2_1.0_224",
                  "inception_resnet_v2"):
        g = zoo.TABLE3_MODELS[model][0]()
        for o in g.ops:
            if o.kind not in ("conv2d", "depthwise_conv2d", "pool"):
                continue
            t0 = time.perf_counter()
            ex = safe_overlap_algorithmic(o)
            es = safe_overlap_analytic(o)
            n_ops += 1
            assert es is not None and es <= ex, (model, o.name, es, ex)
            if ex > 0:
                e = 100.0 * (ex - es) / max(ex, 1)
                if e > worst[0]:
                    worst = (e, f"{model}/{o.name}")
    csv_rows.append(("table2/sweep_lower_bound_ok", 0.0,
                     f"{n_ops} ops, worst underestimate {worst[0]:.2f}% @ {worst[1]}"))
    return csv_rows


if __name__ == "__main__":
    rows = run([])
    for r in rows:
        print(",".join(str(x) for x in r))
