"""Benchmark: DMO on the assigned architectures' block activation arenas
(one decoder block, batch 1 x seq 128, bf16) — the paper's technique carried
to the transformer substrate, driven through the unified compile pipeline
(the second run of any arch is a plan-cache hit)."""
from __future__ import annotations

import time

from repro.configs import registry
from repro.core.activation_planner import compile_block


def run(csv_rows):
    for name, cfg in registry().items():
        t0 = time.perf_counter()
        cp = compile_block(cfg, batch=1, seq=128)
        us = (time.perf_counter() - t0) * 1e6
        csv_rows.append((
            f"activation/{name}", us,
            f"orig={cp.baseline_bytes / 1024:.0f}KB "
            f"dmo={cp.peak_bytes / 1024:.0f}KB "
            f"saving={cp.saving_pct:.1f}% verified={cp.verified}"))
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
