"""Benchmark: DMO on the assigned architectures' block activation arenas
(one decoder block, batch 1 x seq 128, bf16) — the paper's technique carried
to the transformer substrate."""
from __future__ import annotations

import time

from repro.configs import registry
from repro.core.activation_planner import plan_block


def run(csv_rows):
    for name, cfg in registry().items():
        t0 = time.perf_counter()
        orig, dmo = plan_block(cfg, batch=1, seq=128)
        us = (time.perf_counter() - t0) * 1e6
        sav = 100 * (1 - dmo.peak_bytes / orig.peak_bytes)
        csv_rows.append((
            f"activation/{name}", us,
            f"orig={orig.peak_bytes / 1024:.0f}KB dmo={dmo.peak_bytes / 1024:.0f}KB "
            f"saving={sav:.1f}%"))
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
