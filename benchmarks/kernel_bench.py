"""Benchmark: Pallas kernel wall-time (interpret mode on CPU — correctness
costs, not TPU perf) + arena footprint savings of the DMO dwconv kernel,
plus the generalised executor backends: the same planned arena run through
the numpy row-interpreter and the pallas arena-ops kernel sequence."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exec as X
from repro.core.graph import Graph
from repro.core.planner import plan_dmo
from repro.kernels import ops, ref


def _time(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def _exec_graph() -> Graph:
    """conv2d -> depthwise -> pool -> fully_connected: the four acceptance
    op kinds through one shared arena."""
    g = Graph("kb_exec")
    h = g.tensor("x", (32, 32, 8), 4, "input")
    h = g.op("conv2d", [h], (16, 16, 16),
             dict(kernel=(3, 3), stride=(2, 2), padding="same"))
    h = g.op("depthwise_conv2d", [h], (16, 16, 16),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    h = g.op("pool", [h], (8, 8, 16),
             dict(kernel=(2, 2), stride=(2, 2), padding="valid", mode="avg"))
    g.op("fully_connected", [g.op("reshape", [h], (h.elems,))], (10,),
         out_kind="output")
    g.validate()
    return g


def run(csv_rows):
    r = np.random.default_rng(0)
    x = jnp.asarray(r.standard_normal((32, 32, 8)), jnp.float32)
    w = jnp.asarray(r.standard_normal((3, 3, 8)), jnp.float32)
    us = _time(lambda a, b: ops.dmo_dwconv2d(a, b, stride=1, pad=1), x, w)
    arena, two = ops.dmo_dwconv2d_footprint(32, 32, 8, 3, 1, 1)
    csv_rows.append(("kernels/dmo_dwconv_32x32x8", us,
                     f"arena={arena}B two-buffer={two}B "
                     f"saving={100 * (1 - arena / two):.0f}%"))

    # executor backends over the same DMO plan (one flat arena, 4 op kinds),
    # plus the streaming route (ANY-space arena, live windows in VMEM)
    g = _exec_graph()
    plan = plan_dmo(g)
    inputs = X.random_inputs(g)
    weights = X.synth_weights(g)
    backends = (
        ("numpy", lambda: X.get_backend("numpy")),
        ("pallas", lambda: X.get_backend("pallas")),
        ("pallas_stream", lambda: X.get_backend("pallas", mode="streaming",
                                                interpret=True)),
    )
    from repro.core.planner import legalise_for_blocks
    ws = legalise_for_blocks(plan).window_schedule()
    for backend, mk in backends:
        be = mk()
        us = _time(lambda: be.execute(plan, inputs, weights))
        detail = f"arena={plan.peak_bytes}B ops={len(plan.order)}"
        if backend == "pallas_stream":
            detail += (f" window={ws.max_window_rows}/{ws.total_rows}rows"
                       f" resident={ws.max_resident_bytes}B")
        csv_rows.append((f"kernels/arena_exec_{backend}_32x32x8", us, detail))

    # flagship fused band chain: the split-band region (one pallas_call per
    # band op at PR 5) collapses to ONE launch, halos resident in VMEM
    from repro.core import zoo
    from repro.core.exec.pallas_backend import PallasExecutor
    from repro.core.pipeline import compile as compile_graph
    cp = compile_graph(zoo.TABLE3_MODELS["mobilenet_v1_0.25_128_8bit"][0]())
    bp = cp.legalised()
    specs = PallasExecutor(layout="blocks", interpret=True).lower_blocks(bp)
    fused = [s for s in specs if s.kind == "fused"]
    region_ops = sum(len(s.stages) for s in fused)
    be = X.get_backend("pallas", layout="blocks")
    us = _time(lambda: be.execute(cp))
    csv_rows.append((
        "kernels/fused_chain_mobilenet_v1_0.25_128_8bit", us,
        f"launches={len(specs)} region={region_ops}->{len(fused)} "
        f"peak={cp.peak_bytes}B scratch_rows="
        f"{max((s.scratch_rows for s in fused), default=0)}"))

    q = jnp.asarray(r.standard_normal((256, 4, 64)), jnp.float32)
    k = jnp.asarray(r.standard_normal((256, 4, 64)), jnp.float32)
    us = _time(lambda a, b: ops.flash_attention(a, b, b), q, k)
    err = float(jnp.max(jnp.abs(ops.flash_attention(q, k, k)
                                - ref.attention(q, k, k))))
    csv_rows.append(("kernels/flash_attention_256x4x64", us,
                     f"max_err_vs_oracle={err:.2e}"))

    xx = jnp.asarray(r.standard_normal((512, 128)), jnp.float32)
    g2 = jnp.asarray(r.standard_normal((128,)), jnp.float32)
    us = _time(lambda a, b: ops.rmsnorm_residual(a, b, a), xx, g2)
    csv_rows.append(("kernels/inplace_rmsnorm_512x128", us, "aliased in/out"))
    return csv_rows


if __name__ == "__main__":
    for row in run([]):
        print(",".join(str(x) for x in row))
