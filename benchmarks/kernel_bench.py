"""Benchmark: Pallas kernel wall-time (interpret mode on CPU — correctness
costs, not TPU perf) + arena footprint savings of the DMO dwconv kernel."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run(csv_rows):
    r = np.random.default_rng(0)
    x = jnp.asarray(r.standard_normal((32, 32, 8)), jnp.float32)
    w = jnp.asarray(r.standard_normal((3, 3, 8)), jnp.float32)
    us = _time(lambda a, b: ops.dmo_dwconv2d(a, b, stride=1, pad=1), x, w)
    arena, two = ops.dmo_dwconv2d_footprint(32, 32, 8, 3, 1, 1)
    csv_rows.append(("kernels/dmo_dwconv_32x32x8", us,
                     f"arena={arena}B two-buffer={two}B "
                     f"saving={100 * (1 - arena / two):.0f}%"))

    q = jnp.asarray(r.standard_normal((256, 4, 64)), jnp.float32)
    k = jnp.asarray(r.standard_normal((256, 4, 64)), jnp.float32)
    us = _time(lambda a, b: ops.flash_attention(a, b, b), q, k)
    err = float(jnp.max(jnp.abs(ops.flash_attention(q, k, k)
                                - ref.attention(q, k, k))))
    csv_rows.append(("kernels/flash_attention_256x4x64", us,
                     f"max_err_vs_oracle={err:.2e}"))

    xx = jnp.asarray(r.standard_normal((512, 128)), jnp.float32)
    g = jnp.asarray(r.standard_normal((128,)), jnp.float32)
    us = _time(lambda a, b: ops.rmsnorm_residual(a, b, a), xx, g)
    csv_rows.append(("kernels/inplace_rmsnorm_512x128", us, "aliased in/out"))
    return csv_rows


if __name__ == "__main__":
    for row in run([]):
        print(",".join(str(x) for x in row))
