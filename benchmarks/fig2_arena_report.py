"""Benchmark: Fig. 1/2 analogue — arena layout report for the example model
(MobileNet v1 0.25 128 8-bit): buffer offsets/scopes before and after DMO,
plus an ASCII rendering of the diagonal packing."""
from __future__ import annotations

import time

from repro.core import zoo
from repro.core.planner import plan_original, plan_search


def ascii_arena(plan, width: int = 72) -> str:
    scopes = plan.graph.scopes(plan.order)
    peak = plan.peak_bytes
    lines = []
    for t in sorted(plan.offsets, key=lambda t: scopes[t][0]):
        off, size = plan.offsets[t], t.nbytes
        a = int(off / peak * width)
        b = max(a + 1, int((off + size) / peak * width))
        s, e = scopes[t]
        lines.append(" " * a + "#" * (b - a) + " " * (width - b)
                     + f"| {t.name[:18]:18s} [{s:>2},{e:>2}]")
    return "\n".join(lines)


def run(csv_rows):
    t0 = time.perf_counter()
    g = zoo.mobilenet_v1(0.25, 128, 1)
    p0 = plan_original(g)
    p1 = plan_search(g, method="algorithmic", budget_s=10.0)
    us = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("fig2/arena_original_kb", us, f"{p0.peak_bytes / 1024:.0f}"))
    csv_rows.append(("fig2/arena_dmo_kb", us, f"{p1.peak_bytes / 1024:.0f}"))
    return csv_rows


if __name__ == "__main__":
    g = zoo.mobilenet_v1(0.25, 128, 1)
    p0 = plan_original(g)
    p1 = plan_search(g, method="algorithmic", budget_s=10.0)
    print(f"== original ({p0.peak_bytes / 1024:.0f} KB, strategy {p0.strategy})")
    print(ascii_arena(p0))
    print(f"\n== DMO ({p1.peak_bytes / 1024:.0f} KB, strategy {p1.strategy})")
    print(ascii_arena(p1))
