"""Benchmark: Fig. 1/2 analogue — arena layout report for the example model
(MobileNet v1 0.25 128 8-bit): buffer offsets/scopes before and after DMO,
plus an ASCII rendering of the diagonal packing. Both plans come from one
:func:`repro.core.pipeline.compile` call."""
from __future__ import annotations

import time

from repro.core import zoo
from repro.core.pipeline import compile as compile_graph


def ascii_arena(plan, width: int = 72) -> str:
    scopes = plan.graph.scopes(plan.order)
    peak = plan.peak_bytes
    lines = []
    for t in sorted(plan.offsets, key=lambda t: scopes[t][0]):
        off, size = plan.offsets[t], t.nbytes
        a = int(off / peak * width)
        b = max(a + 1, int((off + size) / peak * width))
        s, e = scopes[t]
        lines.append(" " * a + "#" * (b - a) + " " * (width - b)
                     + f"| {t.name[:18]:18s} [{s:>2},{e:>2}]")
    return "\n".join(lines)


def _compile():
    return compile_graph(zoo.mobilenet_v1(0.25, 128, 1),
                         method="algorithmic", budget_s=10.0)


def run(csv_rows):
    t0 = time.perf_counter()
    cp = _compile()
    us = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("fig2/arena_original_kb", us,
                     f"{cp.baseline_bytes / 1024:.0f}"))
    csv_rows.append(("fig2/arena_dmo_kb", us, f"{cp.peak_bytes / 1024:.0f}"))
    return csv_rows


if __name__ == "__main__":
    cp = _compile()
    print(f"== original ({cp.baseline_bytes / 1024:.0f} KB, "
          f"strategy {cp.baseline.strategy})")
    print(ascii_arena(cp.baseline))
    print(f"\n== DMO ({cp.peak_bytes / 1024:.0f} KB, "
          f"strategy {cp.plan.strategy})")
    print(ascii_arena(cp.plan))
    print()
    print(cp.report().split("\n# plan")[0])
