"""Benchmark: Fig. 1/2 analogue — arena layout report for the example model
(MobileNet v1 0.25 128 8-bit): buffer offsets/scopes before and after DMO,
plus an ASCII rendering of the diagonal packing. Both plans come from one
:func:`repro.core.pipeline.compile` call.

Since the executor backend layer landed, the report also answers the paper's
implicit runtime question — does executing inside the overlapped arena cost
throughput? Reduced-resolution builds of the same architecture are executed
on both backends (numpy row-interpreter, pallas interpret-mode kernels), on
the DMO plan *and* on the non-overlapping baseline plan, so the CSV carries
layout savings and execution overhead side by side — in **both dtype
tiers**: the f32 build and, since the dtype-aware executor subsystem, the
int8 build running the quantised tier (int32 accumulation + requantisation)
inside its byte arena.

Since the row-blocked layout layer, the pallas executions run *both* arena
programs — the flat byte arena and the row-blocked (tiled) program compiled
mode uses — and the example model's legalised peak rides next to the
byte-granular one, so the tiling padding the (8, 128)/(32, 128) VMEM tiles
cost is visible per dtype tier."""
from __future__ import annotations

import time

from repro.core import exec as X
from repro.core import planner as P
from repro.core import zoo
from repro.core.pipeline import compile as compile_graph


def ascii_arena(plan, width: int = 72) -> str:
    scopes = plan.graph.scopes(plan.order)
    peak = plan.peak_bytes
    lines = []
    for t in sorted(plan.offsets, key=lambda t: scopes[t][0]):
        off, size = plan.offsets[t], t.nbytes
        a = int(off / peak * width)
        b = max(a + 1, int((off + size) / peak * width))
        s, e = scopes[t]
        lines.append(" " * a + "#" * (b - a) + " " * (width - b)
                     + f"| {t.name[:18]:18s} [{s:>2},{e:>2}]")
    return "\n".join(lines)


def _compile():
    return compile_graph(zoo.mobilenet_v1(0.25, 128, 1),
                         method="algorithmic", budget_s="auto")


#: Reduced-res builds of the flagship — executable by both backends in both
#: dtype tiers (f32 reference tier, int8 quantised tier).
_EXEC_MODELS = {
    "f32": lambda: zoo.mobilenet_v1(0.25, 64, 4),
    "i8": lambda: zoo.mobilenet_v1(0.25, 64, 1),
}


def _time_exec(be, plan, inputs, weights, quant, n=3):
    be.execute(plan, inputs, weights, quant=quant)  # warm (jit for pallas)
    t0 = time.perf_counter()
    for _ in range(n):
        be.execute(plan, inputs, weights, quant=quant)
    return (time.perf_counter() - t0) / n * 1e6


#: Executor configurations timed per tier: the numpy row interpreter and
#: ALL THREE pallas arena programs (flat byte, row-blocked/compiled-mode,
#: and the streaming live-window route).
_EXEC_BACKENDS = {
    "numpy": lambda: X.get_backend("numpy"),
    "pallas_flat": lambda: X.get_backend("pallas", layout="flat"),
    "pallas_blocks": lambda: X.get_backend("pallas", layout="blocks"),
    "pallas_stream": lambda: X.get_backend("pallas", mode="streaming",
                                           interpret=True),
}


def run(csv_rows):
    t0 = time.perf_counter()
    cp = _compile()
    us = (time.perf_counter() - t0) * 1e6
    # a warm plan cache turns us_per_call into load time — disclose per row
    tag = f"cache={'hit' if cp.cache_hit else 'miss'}"
    csv_rows.append(("fig2/arena_original_kb", us,
                     f"{cp.baseline_bytes / 1024:.0f} {tag}"))
    csv_rows.append(("fig2/arena_dmo_kb", us,
                     f"{cp.peak_bytes / 1024:.0f} "
                     f"dtypes={cp.plan.dtype_peaks_report()} {tag}"))
    bp = cp.legalised()
    if bp is not None:
        csv_rows.append((
            "fig2/arena_dmo_blocked_kb", us,
            f"{bp.padded_peak_bytes / 1024:.0f} "
            f"pad=+{bp.padding_overhead_pct:.1f}% "
            f"tile={bp.tiling[0]}x{bp.tiling[1]} {tag}"))
        ws = bp.window_schedule()
        csv_rows.append((
            "fig2/arena_dmo_window_rows", us,
            f"{ws.max_window_rows} of={ws.total_rows} "
            f"resident={ws.max_resident_bytes}B {tag}"))

    # executor backends: DMO plan vs non-overlapping baseline plan, per tier
    for tier, build in _EXEC_MODELS.items():
        ecp = compile_graph(build(), split="off",
                            passes=("baseline", "serialise", "plan", "verify"))
        weights = X.synth_weights(ecp.graph)
        quant = (X.calibrate(ecp.graph, 0, weights)
                 if X.needs_quant(ecp.graph) else None)
        inputs = (X.quant_inputs(ecp.graph, quant) if quant is not None
                  else X.random_inputs(ecp.graph))
        blocked = P.legalise_for_blocks(ecp.plan)
        for backend, mk in _EXEC_BACKENDS.items():
            be = mk()
            dmo_us = _time_exec(be, ecp.plan, inputs, weights, quant)
            base_us = _time_exec(be, ecp.baseline, inputs, weights, quant)
            over = 100.0 * (dmo_us / base_us - 1.0)
            if backend == "pallas_blocks":
                arena = blocked.padded_peak_bytes
            elif backend == "pallas_stream":
                arena = blocked.window_schedule().max_resident_bytes
            else:
                arena = ecp.peak_bytes
            csv_rows.append((
                f"fig2/exec_{tier}_{backend}_dmo", dmo_us,
                f"arena={arena}B baseline_us={base_us:.0f} "
                f"dmo_overhead={over:+.1f}%"))
    return csv_rows


if __name__ == "__main__":
    cp = _compile()
    print(f"== original ({cp.baseline_bytes / 1024:.0f} KB, "
          f"strategy {cp.baseline.strategy})")
    print(ascii_arena(cp.baseline))
    print(f"\n== DMO ({cp.peak_bytes / 1024:.0f} KB, "
          f"strategy {cp.plan.strategy})")
    print(ascii_arena(cp.plan))
    print()
    print(cp.report().split("\n# plan")[0])
    print()
    for row in run([])[2:]:
        print(",".join(str(x) for x in row))
