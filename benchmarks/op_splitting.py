"""Benchmark: paper §II.A operation splitting, automated.

The paper splits MobileNet v1 0.25 128's (conv, dwconv) pair by hand
(96 -> 66 KB, 6144 recomputed elements) and calls automation future work.
The manual pair reproduces the paper's numbers; the automated route runs
through the compile pipeline with the split pass forced on (input buffer
external to the arena, per the paper's example convention).
"""
from __future__ import annotations

import time

from repro.core import zoo
from repro.core.pipeline import compile as compile_graph
from repro.core.planner import plan_original
from repro.core.splitting import split_pair


def run(csv_rows):
    t0 = time.perf_counter()
    g = zoo.mobilenet_v1(0.25, 128, 1, external_input=True)
    base = plan_original(g).peak_bytes
    mg, rc = split_pair(g, 2, 4)
    mg.validate()
    mpeak = plan_original(mg).peak_bytes
    cp = compile_graph(g, method="algorithmic", split="on",
                       passes=("baseline", "split", "serialise", "plan",
                               "verify"))
    us = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("split/mobilenet_manual_pair_x4", us,
                     f"{base / 1024:.0f}->{mpeak / 1024:.0f}KB (paper 96->66) "
                     f"recompute={rc} elems (paper 6144; TF-SAME halo convention)"))
    csv_rows.append(("split/mobilenet_auto", us,
                     f"{cp.baseline_bytes / 1024:.0f}->"
                     f"{cp.peak_bytes / 1024:.0f}KB "
                     f"recompute={cp.recompute_elems} winner={cp.winner}"))
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
