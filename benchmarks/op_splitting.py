"""Benchmark: paper §II.A operation splitting, automated.

The paper splits MobileNet v1 0.25 128's (conv, dwconv) pair by hand
(96 -> 66 KB, 6144 recomputed elements) and calls automation future work;
``repro.core.splitting.auto_split`` performs it automatically. (Input buffer
external to the arena, per the paper's example convention.)
"""
from __future__ import annotations

import time

from repro.core import zoo
from repro.core.planner import plan_original
from repro.core.splitting import auto_split, split_pair


def run(csv_rows):
    t0 = time.perf_counter()
    g = zoo.mobilenet_v1(0.25, 128, 1, external_input=True)
    base = plan_original(g).peak_bytes
    manual = split_pair(g, 2, 4)
    mg, rc = manual
    mg.validate()
    mpeak = plan_original(mg).peak_bytes
    ag, arc, log = auto_split(g)
    apeak = plan_original(ag).peak_bytes
    us = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("split/mobilenet_manual_pair_x4", us,
                     f"{base / 1024:.0f}->{mpeak / 1024:.0f}KB (paper 96->66) "
                     f"recompute={rc} elems (paper 6144; TF-SAME halo convention)"))
    csv_rows.append(("split/mobilenet_auto", us,
                     f"{base / 1024:.0f}->{apeak / 1024:.0f}KB "
                     f"recompute={arc} steps={len(log)}"))
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
