"""Benchmark: paper §II.A operation splitting, automated and overlap-aware.

The paper splits MobileNet v1 0.25 128's (conv, dwconv) pair by hand
(96 -> 66 KB, 6144 recomputed elements) and calls automation future work.
Three rows:

- the manual pair, planned both ways: the paper's conservative route
  (``O_s = 0`` across every split op) next to the banded-O_s relaxation —
  the composition of splitting (§II.A) and diagonal overlap (§III) the
  paper leaves open;
- the automated route through the compile pipeline with the split pass
  forced on (input buffer external to the arena, per the paper's example
  convention) — auto_split now evaluates candidates with the DMO planner;
- an executed split: a reduced-resolution build whose auto-split graph
  passes the executor gate, runs on BOTH arena backends, and is
  parity-checked against its *unsplit* reference (band ops share the
  source op's weights/calibration, so the outputs must agree).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import exec as X
from repro.core import zoo
from repro.core.arena import run_reference
from repro.core.pipeline import compile as compile_graph
from repro.core.planner import plan_dmo, plan_original
from repro.core.splitting import split_pair


def _exec_parity_row(csv_rows):
    """Compile a reduced-resolution build with splitting on, execute the
    split-band graph on both backends, and diff against the unsplit
    reference."""
    t0 = time.perf_counter()
    g = zoo.mobilenet_v1(0.25, 64, 1)
    cp = compile_graph(g, method="algorithmic", split="on")
    reason = X.executability(cp.graph)
    if cp.winner not in ("split", "fuse") or reason is not None:
        us = (time.perf_counter() - t0) * 1e6
        csv_rows.append(("split/exec_parity", us,
                         f"skipped (winner={cp.winner} reason={reason})"))
        return
    weights = X.synth_weights(cp.graph)
    quant = (X.calibrate(cp.graph, 0, weights)
             if X.needs_quant(cp.graph) else None)
    inputs = (X.quant_inputs(cp.graph, quant) if quant is not None
              else X.random_inputs(cp.graph))
    # the unsplit reference: same inputs/weights by name/provenance
    w0 = X.synth_weights(g)
    q0 = X.calibrate(g, 0, w0) if X.needs_quant(g) else None
    in0 = X.quant_inputs(g, q0) if q0 is not None else X.random_inputs(g)
    ref0 = run_reference(g, in0, weights=w0, quant=q0)
    parity = []
    for backend in ("numpy", "pallas"):
        got = cp.execute(inputs, weights, backend=backend, quant=quant)
        if quant is not None:
            worst = max(int(np.abs(got[k].astype(np.int64)
                                   - ref0[k].astype(np.int64)).max())
                        for k in ref0)
            parity.append(f"{backend}<= {worst}LSB")
        else:
            worst = max(float(np.abs(got[k] - ref0[k]).max()) for k in ref0)
            parity.append(f"{backend}<= {worst:.1e}")
    us = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("split/exec_parity", us,
                     f"{cp.graph.name}: {cp.baseline_bytes / 1024:.0f}->"
                     f"{cp.peak_bytes / 1024:.0f}KB vs-unsplit-ref "
                     f"{' '.join(parity)}"))


def run(csv_rows):
    t0 = time.perf_counter()
    g = zoo.mobilenet_v1(0.25, 128, 1, external_input=True)
    base = plan_original(g).peak_bytes
    mg, rc = split_pair(g, 2, 4)
    mg.validate()
    conservative = plan_original(mg).peak_bytes   # O_s = 0 across the bands
    relaxed = plan_dmo(mg, method="algorithmic").peak_bytes  # banded O_s
    us = (time.perf_counter() - t0) * 1e6
    csv_rows.append((
        "split/mobilenet_manual_pair_x4", us,
        f"{base / 1024:.0f}->{conservative / 1024:.0f}KB (paper 96->66) "
        f"+overlap={relaxed / 1024:.0f}KB "
        f"recompute={rc} elems (paper 6144; TF-SAME halo convention)"))
    t0 = time.perf_counter()
    cp = compile_graph(g, method="algorithmic", split="on",
                       passes=("baseline", "split", "serialise", "plan",
                               "verify"))
    us = (time.perf_counter() - t0) * 1e6
    csv_rows.append(("split/mobilenet_auto", us,
                     f"{cp.baseline_bytes / 1024:.0f}->"
                     f"{cp.peak_bytes / 1024:.0f}KB "
                     f"recompute={cp.recompute_elems} winner={cp.winner}"))
    _exec_parity_row(csv_rows)
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
