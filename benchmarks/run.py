"""Benchmark harness: one module per paper table/figure + framework extras.
Prints ``name,us_per_call,derived`` CSV rows.

``--json [PATH]`` additionally writes a structured artifact (default
``BENCH_pr10.json``): per-model plan peaks (fixed-order vs joint
execution-order x overlap search, plus the order-search wall time),
blocked/window rows, the shipped layout's packing (packed peak, padding
overhead, the legacy layout's cost for comparison), pallas launch counts
(fused band chains collapse to one), compile time, the memory-vs-batch
trade curve (``peak_vs_batch``), exec throughput per backend×dtype, and
the serving demo's sustained inferences/sec (``serve_throughput``) — so
the perf trajectory is machine-readable instead of living in prose. ``--sweep off`` skips the CSV sweep when only
the artifact is wanted. ``scripts/bench_diff.py`` diffs two artifacts and
fails on regressions (the CI perf gate).

Benchmark reruns start warm: the compile plan cache persists to disk
(content-addressed by graph signature under ``$REPRO_DMO_CACHE_DIR``,
default ``~/.cache/repro-dmo``) — set ``REPRO_DMO_DISK_CACHE=0`` to force
cold planning. The sweep reports the cache's memory and disk hit/miss
counters when it finishes."""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _json_payload(rows):
    """The ``--json`` artifact: plan-level stats for every Table III model
    (peaks, blocked rows, streaming window rows, compile time) plus exec
    throughput per backend×dtype on reduced executable builds."""
    from repro.core import exec as X
    from repro.core import zoo
    from repro.core.pipeline import cache_info, compile as compile_graph

    models = {}
    for name, (build, paper_orig, paper_opt) in zoo.TABLE3_MODELS.items():
        t0 = time.perf_counter()
        cp = compile_graph(build(), profile="paper", method="algorithmic",
                           budget_s="auto")
        wall_s = time.perf_counter() - t0
        entry = {
            "baseline_kb": round(cp.baseline_bytes / 1024, 1),
            "dmo_kb": round(cp.peak_bytes / 1024, 1),
            "paper_kb": [paper_orig, paper_opt],
            "saving_pct": round(cp.saving_pct, 1),
            "compile_s": round(cp.compile_s, 3),
            "wall_s": round(wall_s, 3),
            "cache_hit": cp.cache_hit,
        }
        entry["winner"] = cp.winner
        if cp.order_stats:
            entry["fixed_dmo_kb"] = round(
                cp.order_stats["fixed_peak"] / 1024, 1)
            entry["order_search_s"] = round(cp.order_stats["wall_s"], 3)
            entry["order_changed"] = bool(cp.order_stats["order_changed"])
        bp = cp.legalised()
        if bp is not None:
            ws = bp.window_schedule()
            entry.update({
                "blocked_rows": bp.total_rows,
                "blocked_kb": round(bp.padded_peak_bytes / 1024, 1),
                "packed_peak_kb": round(bp.padded_peak_bytes / 1024, 1),
                "padding_overhead_pct": round(bp.padding_overhead_pct, 1),
                "legacy_blocked_kb": round(
                    (bp.legacy_padded_bytes or bp.padded_peak_bytes)
                    / 1024, 1),
                "packing": bp.packing,
                "window_rows": ws.max_window_rows,
                "window_pct": round(
                    100.0 * ws.max_window_rows / ws.total_rows, 1),
                "window_resident_bytes": ws.max_resident_bytes,
            })
            if X.executability(cp.graph) is None:
                from repro.core.exec.pallas_backend import PallasExecutor
                specs = PallasExecutor(layout="blocks",
                                       interpret=True).lower_blocks(bp)
                fused = [s for s in specs if s.kind == "fused"]
                entry.update({
                    "launches": len(specs),
                    "graph_ops": sum(1 for op in bp.order
                                     if op.kind != "reshape"),
                    "fused_chains": len(fused),
                    "fused_region_ops": sum(len(s.stages) for s in fused),
                    "fused_scratch_rows": max(
                        (s.scratch_rows for s in fused), default=0),
                })
        # memory-vs-batch trade curve: the rows a PlanServer routes on
        # (deterministic default compile kwargs — no search budget — so
        # the batched sweep stays cheap and cache-stable)
        from repro.core.pipeline import peak_vs_batch
        entry["peak_vs_batch"] = [
            {k: r[k] for k in ("batch", "peak_bytes", "per_image_bytes",
                               "peak_ratio_vs_b1")}
            for r in peak_vs_batch(build(), batches=(1, 2, 4, 8))]
        models[name] = entry

    exec_us = {}
    builds = {"f32": lambda: zoo.mobilenet_v1(0.25, 32, 4),
              "i8": lambda: zoo.mobilenet_v1(0.25, 32, 1)}
    backends = {
        "numpy": lambda: X.get_backend("numpy"),
        "pallas_flat": lambda: X.get_backend("pallas", layout="flat"),
        "pallas_blocks": lambda: X.get_backend("pallas", layout="blocks"),
        "pallas_stream": lambda: X.get_backend("pallas", mode="streaming",
                                               interpret=True),
    }
    for tier, build in builds.items():
        cp = compile_graph(build(), split="off")
        g = cp.graph
        weights = X.synth_weights(g)
        quant = X.calibrate(g, 0, weights) if X.needs_quant(g) else None
        inputs = (X.quant_inputs(g, quant) if quant is not None
                  else X.random_inputs(g))
        for bname, mk in backends.items():
            be = mk()
            be.execute(cp.plan, inputs, weights, quant=quant)  # warm jit
            t0 = time.perf_counter()
            n = 3
            for _ in range(n):
                be.execute(cp.plan, inputs, weights, quant=quant)
            exec_us[f"{tier}/{bname}"] = round(
                (time.perf_counter() - t0) / n * 1e6, 1)

    # serving demo: sustained inferences/sec on the 8-bit reduced flagship
    # through the deadline-batching PlanServer (batch variants 1..8)
    from repro.serve import throughput_demo
    serve = throughput_demo(zoo.mobilenet_v1(0.25, 32, 1), n_requests=512)

    return {
        "schema": "repro-dmo-bench-v4",
        "models": models,
        "exec_us_per_call": exec_us,
        "serve_throughput": serve,
        "sweep_rows": [[n, round(us, 1), d] for n, us, d in rows],
        "plan_cache": cache_info(),
    }


def main(argv=None) -> None:
    os.environ.setdefault("REPRO_DMO_DISK_CACHE", "1")
    ap = argparse.ArgumentParser(
        prog="benchmarks.run", description="DMO benchmark sweep")
    ap.add_argument("--json", nargs="?", const="BENCH_pr10.json",
                    default=None, metavar="PATH",
                    help="also write the structured benchmark artifact "
                         "(default path: BENCH_pr10.json)")
    ap.add_argument("--sweep", choices=("on", "off"), default="on",
                    help="run the full CSV sweep ('off' keeps --json cheap "
                         "on a warm plan cache)")
    args = ap.parse_args(argv)

    rows = []
    if args.sweep == "on":
        from benchmarks import (arch_activation_plans, fig2_arena_report,
                                kernel_bench, op_removal, op_splitting,
                                roofline_report, table2_os_precision,
                                table3_memory_savings)
        mods = [
            ("table2 (O_s precision)", table2_os_precision),
            ("table3 (memory savings)", table3_memory_savings),
            ("fig2 (arena report)", fig2_arena_report),
            ("op splitting (§II.A)", op_splitting),
            ("op removal (§II.C)", op_removal),
            ("activation plans", arch_activation_plans),
            ("kernels", kernel_bench),
            ("roofline", roofline_report),
        ]
        for name, mod in mods:
            print(f"# --- {name}", file=sys.stderr, flush=True)
            mod.run(rows)
        print("name,us_per_call,derived")
        for n, us, d in rows:
            print(f"{n},{us:.1f},{d}")

    if args.json:
        payload = _json_payload(rows)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)

    from repro.core.pipeline import cache_info
    info = cache_info()
    print(f"# plan cache: mem {info['hits']} hit / {info['misses']} miss, "
          f"disk {info['disk_hits']} hit / {info['disk_misses']} miss "
          f"({info['size']} entries in memory, dir {info['disk_dir']})",
          file=sys.stderr)


if __name__ == "__main__":
    main()
