"""Benchmark harness: one module per paper table/figure + framework extras.
Prints ``name,us_per_call,derived`` CSV rows.

Benchmark reruns start warm: the compile plan cache persists to disk
(content-addressed by graph signature under ``$REPRO_DMO_CACHE_DIR``,
default ``~/.cache/repro-dmo``) — set ``REPRO_DMO_DISK_CACHE=0`` to force
cold planning."""
from __future__ import annotations

import os
import sys


def main() -> None:
    os.environ.setdefault("REPRO_DMO_DISK_CACHE", "1")
    from benchmarks import (arch_activation_plans, fig2_arena_report,
                            kernel_bench, op_removal, op_splitting,
                            roofline_report, table2_os_precision,
                            table3_memory_savings)
    rows = []
    mods = [
        ("table2 (O_s precision)", table2_os_precision),
        ("table3 (memory savings)", table3_memory_savings),
        ("fig2 (arena report)", fig2_arena_report),
        ("op splitting (§II.A)", op_splitting),
        ("op removal (§II.C)", op_removal),
        ("activation plans", arch_activation_plans),
        ("kernels", kernel_bench),
        ("roofline", roofline_report),
    ]
    for name, mod in mods:
        print(f"# --- {name}", file=sys.stderr, flush=True)
        mod.run(rows)
    print("name,us_per_call,derived")
    for n, us, d in rows:
        print(f"{n},{us:.1f},{d}")
    from repro.core.pipeline import cache_info
    print(f"# plan cache: {cache_info()}", file=sys.stderr)


if __name__ == "__main__":
    main()
