"""Benchmark: paper Table III — peak arena memory, original vs DMO.

For each of the eleven models, one :func:`repro.core.pipeline.compile` call
produces the best non-overlapping baseline ("Original"), the paper-faithful
DMO plan (exact algorithmic O_s, paper op-kind profile, removal/splitting/
serialisation passes) refined by the ILS search, and the verification pass —
the old per-model plan/compare boilerplate lives in the pipeline now. A
second compile with the extended overlap profile gives the beyond-paper
column.

Paper numbers are cited inline; structural deltas for the complex connected
models (whose exact TFLite graph serialisations the paper does not specify)
are discussed in EXPERIMENTS.md.
"""
from __future__ import annotations

import time

from repro.core import zoo
from repro.core.pipeline import auto_budget_s, compile as compile_graph


def run(csv_rows, search: bool = True):
    # the ILS budget autoscales with op/tensor count inside the plan pass
    # (pipeline.auto_budget_s) — no more hand-set per-model budgets here;
    # the beyond-paper column keeps its historical half budget
    for name, (build, paper_orig, paper_opt) in zoo.TABLE3_MODELS.items():
        t0 = time.perf_counter()
        g = build()
        cp = compile_graph(g, profile="paper", method="algorithmic",
                           budget_s="auto" if search else 0.0)
        if search:
            ext_cp = compile_graph(build(), profile="extended",
                                   method="algorithmic",
                                   budget_s=auto_budget_s(g) / 2)
            ext = min(ext_cp.peak_bytes, cp.peak_bytes)
        else:
            ext = cp.peak_bytes
        us = (time.perf_counter() - t0) * 1e6
        orig_kb = cp.baseline_bytes / 1024
        opt_kb = cp.peak_bytes / 1024
        psav = (100.0 * (1 - paper_opt / paper_orig)) if paper_orig else 0.0
        csv_rows.append((
            f"table3/{name}", us,
            f"orig={orig_kb:.0f}KB(paper {paper_orig}) "
            f"dmo={opt_kb:.0f}KB(paper {paper_opt}) "
            f"saving={cp.saving_pct:.1f}%(paper {psav:.1f}%) "
            f"beyond={ext / 1024:.0f}KB "
            # a warm plan cache (disk tier) turns us_per_call into load time,
            # not planning time — disclose it per row
            f"cache={'hit' if cp.cache_hit else 'miss'}"))
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
