"""Benchmark: paper Table III — peak arena memory, original vs DMO.

For each of the eleven models, one :func:`repro.core.pipeline.compile` call
produces the best non-overlapping baseline ("Original"), the paper-faithful
DMO plan (exact algorithmic O_s, paper op-kind profile, removal/splitting/
serialisation passes) refined by the ILS search, and the verification pass —
the old per-model plan/compare boilerplate lives in the pipeline now. A
second compile with the extended overlap profile gives the beyond-paper
column.

Since the dtype-aware executor layer, each row also reports the arena peak
*per dtype* and an execution status: the paper's flagship 8-bit rows (where
Table III's headline savings are measured) are compiled for both executor
backends, run inside their overlapped byte arena, and parity-checked against
the quantised private-buffer reference — "executed", not "planned-only".
``REPRO_DMO_EXEC_ELEMS`` caps how large a model the row-by-row executors
attempt (default 8M arena elements, which covers both 8-bit rows).

Since the row-blocked layout layer, each row additionally reports the
*legalised* (row-blocked) arena peak next to the byte-granular one: what a
compiled-mode (tiled VMEM) execution actually allocates. Since packed
row-blocked layouts the column states the packed overhead next to what the
legacy one-image-row-per-arena-row layout would have cost, and only the
packed overhead is held to the report's stated per-model bound
(:func:`padding_bound_pct`; rows exceeding it print OVER-BOUND).

Since the joint execution-order x overlap search, each row also carries an
``order=`` column: the joint-search peak and its delta vs the best
fixed-order DMO plan (rows where the shipped peak exceeds the fixed peak —
impossible unless the never-regress fallback breaks — print
ORDER-REGRESSED).

Paper numbers are cited inline; structural deltas for the complex connected
models (whose exact TFLite graph serialisations the paper does not specify)
are discussed in EXPERIMENTS.md.
"""
from __future__ import annotations

import os
import time

from repro.core import exec as X
from repro.core import planner as P
from repro.core import zoo
from repro.core.arena import run_reference
from repro.core.pipeline import auto_budget_s, compile as compile_graph

#: Executor size cap (total arena elements) for the execution-status column.
_EXEC_ELEMS = int(os.environ.get("REPRO_DMO_EXEC_ELEMS", 8_000_000))

#: Stated per-model bound on the row-blocked tiling padding (+% over the
#: byte-granular DMO peak), for the PACKED layout the legaliser now ships
#: (`packing="auto"`: multiple narrow image rows per lane-tiled arena row,
#: wide rows spanning several arena rows, per-model arena rowlen swept for
#: the lowest padded peak). The legacy one-image-row-per-arena-row layout
#: cost +105%..+715% (split-band winners up to +437%); packing cuts the
#: measured winner-plan overheads to +7%..+51% zoo-wide (flagship 8-bit
#: MobileNet: +295% legacy -> +48% packed on the split winner, +140% ->
#: +20% on the unsplit DMO plan). Bounds are the measured packed overheads
#: with ~30-60% plan-variability headroom; only the *packed* layout is
#: held to them — rows exceeding the bound print OVER-BOUND here and fail
#: tests/test_block_layouts.py.
_PAD_BOUND_PCT = {
    "mobilenet_v1_1.0_224": 70.0,
    "mobilenet_v1_1.0_224_8bit": 70.0,
    "mobilenet_v1_0.25_128_8bit": 80.0,
    "mobilenet_v1_0.25_224": 80.0,
    "mobilenet_v2_0.35_224": 75.0,
    "mobilenet_v2_1.0_224": 60.0,
    "inception_resnet_v2": 65.0,
    "nasnet_mobile": 55.0,
}
_PAD_BOUND_DEFAULT_PCT = 75.0


def padding_bound_pct(name: str) -> float:
    """The report's stated padding-overhead bound for a Table III row."""
    return _PAD_BOUND_PCT.get(name, _PAD_BOUND_DEFAULT_PCT)


def _blocked_status(name: str, cp, g) -> str:
    """Row-blocked (legalised) peak next to the byte-granular peak. Falls
    back to a fresh input-graph DMO plan when the winning variant is not
    legalisable (aggregated concat-removal views)."""
    bp = cp.legalised()
    if bp is None:
        try:
            bp = P.legalise_for_blocks(P.plan_dmo(g))
        except ValueError as e:
            return f"blocked=n/a({e})"
    bound = padding_bound_pct(name)
    pad = bp.padding_overhead_pct
    flag = "" if pad <= bound else " OVER-BOUND"
    legacy = (f"legacy +{bp.legacy_padding_overhead_pct:.1f}%, "
              if bp.packing == "packed" else "legacy layout, ")
    return (f"blocked={bp.padded_peak_bytes / 1024:.0f}KB "
            f"pad=+{pad:.1f}%({legacy}bound {bound:.0f}%){flag}")


def _order_status(cp) -> str:
    """Joint execution-order x overlap search column: the joint peak and its
    delta vs the best *fixed-order* DMO plan. The never-regress fallback in
    the plan pass guarantees the shipped peak is <= the fixed peak; a row
    violating that prints ORDER-REGRESSED (loud, OVER-BOUND style), because
    it can only mean the fallback broke."""
    st = cp.order_stats
    if not st:
        return "order=off"
    fixed, joint = st["fixed_peak"], st["peak"]
    dpct = 100.0 * (joint - fixed) / fixed if fixed else 0.0
    flag = "" if cp.peak_bytes <= fixed else " ORDER-REGRESSED"
    reord = ",reordered" if st.get("order_changed") else ""
    return (f"order={joint / 1024:.0f}KB({dpct:+.1f}% vs fixed "
            f"{fixed / 1024:.0f}KB{reord}){flag}")


def _execute_status(name, build) -> str:
    """Execute the model's DMO plan on both arena backends and parity-check
    against the quantised reference. Only the paper's 8-bit rows run here —
    f32 execution timings live in fig2_arena_report / kernel_bench."""
    if name not in zoo.TABLE3_8BIT_MODELS:
        return "planned-only(f32: timed in fig2/kernel_bench)"
    g = build()
    reason = X.executability(g)
    if reason is not None:
        return f"planned-only({reason})"
    elems = sum(t.elems for t in g.arena_tensors())
    if elems > _EXEC_ELEMS:
        return f"planned-only({elems} elems > REPRO_DMO_EXEC_ELEMS)"
    # split bands are executable since the banded-O_s layer (explicit
    # band pads); only aggregated concat-removal views stay planned-only,
    # which is why the pass list has no "remove_concats". No "verify"
    # pass either: the explicit parity check below against the quantised
    # reference covers both backends without paying for the pipeline's
    # own reference + execution round.
    cp = compile_graph(g, profile="paper", method="algorithmic",
                       passes=("baseline", "split", "serialise", "plan"),
                       backend="pallas")
    reason = X.executability(cp.graph)
    if reason is not None:
        return f"planned-only({reason})"
    weights = X.synth_weights(cp.graph)
    quant = X.calibrate(cp.graph, 0, weights)
    inputs = X.quant_inputs(cp.graph, quant)
    ref = run_reference(cp.graph, inputs, cp.plan.order, weights=weights,
                        quant=quant)
    times = []
    for backend in ("numpy", "pallas"):
        t0 = time.perf_counter()
        got = cp.execute(inputs, weights, backend=backend, quant=quant)
        times.append(f"{backend}={((time.perf_counter() - t0) * 1e3):.0f}ms")
        X.compare_outputs(ref, got, exact=(backend == "numpy"),
                          label=f"table3 {cp.graph.name} {backend}")
    bands = sum(1 for op in cp.graph.ops if "row_range" in op.params)
    return (f"executed({'/'.join(times)} "
            f"exec_saving={cp.saving_pct:.1f}% parity=ok"
            + (f" split_bands={bands}" if bands else "") + ")")


def run(csv_rows, search: bool = True):
    # the ILS budget autoscales with op/tensor count inside the plan pass
    # (pipeline.auto_budget_s) — no more hand-set per-model budgets here;
    # the beyond-paper column keeps its historical half budget
    for name, (build, paper_orig, paper_opt) in zoo.TABLE3_MODELS.items():
        t0 = time.perf_counter()
        g = build()
        cp = compile_graph(g, profile="paper", method="algorithmic",
                           budget_s="auto" if search else 0.0)
        if search:
            ext_cp = compile_graph(build(), profile="extended",
                                   method="algorithmic",
                                   budget_s=auto_budget_s(g) / 2)
            ext = min(ext_cp.peak_bytes, cp.peak_bytes)
        else:
            ext = cp.peak_bytes
        us = (time.perf_counter() - t0) * 1e6  # planning time only
        status = _execute_status(name, build)
        blocked = _blocked_status(name, cp, g)
        orig_kb = cp.baseline_bytes / 1024
        opt_kb = cp.peak_bytes / 1024
        psav = (100.0 * (1 - paper_opt / paper_orig)) if paper_orig else 0.0
        csv_rows.append((
            f"table3/{name}", us,
            f"orig={orig_kb:.0f}KB(paper {paper_orig}) "
            f"dmo={opt_kb:.0f}KB(paper {paper_opt}) "
            f"saving={cp.saving_pct:.1f}%(paper {psav:.1f}%) "
            f"beyond={ext / 1024:.0f}KB "
            f"{_order_status(cp)} "
            f"dtypes={cp.plan.dtype_peaks_report()} "
            f"{blocked} "
            f"exec={status} "
            # a warm plan cache (disk tier) turns us_per_call into load time,
            # not planning time — disclose it per row
            f"cache={'hit' if cp.cache_hit else 'miss'}"))
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
