"""Benchmark: paper Table III — peak arena memory, original vs DMO.

For each of the eleven models: the best non-overlapping baseline
("Original"), the paper-faithful DMO plan (overlap only for the op kinds the
paper derives O_s for, exact algorithmic O_s), and the beyond-paper plan
(ILS search + extended overlap profile incl. concat/pad). Every plan is
validated against the no-clobber constraint checker.

Paper numbers are cited inline; structural deltas for the complex connected
models (whose exact TFLite graph serialisations the paper does not specify)
are discussed in EXPERIMENTS.md.
"""
from __future__ import annotations

import time

from repro.core import zoo
from repro.core.planner import plan_original, plan_dmo, plan_search

#: ILS budget (seconds) per model, scaled down for the big connected graphs.
_SEARCH_BUDGET = {"default": 12.0, "nasnet_mobile": 6.0, "densenet_121": 8.0,
                  "inception_resnet_v2": 8.0}


def run(csv_rows, search: bool = True):
    for name, (build, paper_orig, paper_opt) in zoo.TABLE3_MODELS.items():
        t0 = time.perf_counter()
        g = build()
        p0 = plan_original(g)
        p1 = plan_dmo(g, method="algorithmic", profile="paper")
        best = p1
        if search:
            budget = _SEARCH_BUDGET.get(name, _SEARCH_BUDGET["default"])
            p2 = plan_search(g, method="algorithmic", profile="paper",
                             budget_s=budget)
            if p2.peak_bytes < best.peak_bytes:
                best = p2
            p3 = plan_search(g, method="algorithmic", profile="extended",
                             budget_s=budget / 2)
            ext = min(p3.peak_bytes, best.peak_bytes)
        else:
            ext = best.peak_bytes
        for p in (p0, best):
            p.validate()
        us = (time.perf_counter() - t0) * 1e6
        orig_kb = p0.peak_bytes / 1024
        opt_kb = best.peak_bytes / 1024
        sav = 100.0 * (1 - opt_kb / orig_kb)
        psav = (100.0 * (1 - paper_opt / paper_orig)) if paper_orig else 0.0
        csv_rows.append((
            f"table3/{name}", us,
            f"orig={orig_kb:.0f}KB(paper {paper_orig}) "
            f"dmo={opt_kb:.0f}KB(paper {paper_opt}) "
            f"saving={sav:.1f}%(paper {psav:.1f}%) beyond={ext / 1024:.0f}KB"))
    return csv_rows


if __name__ == "__main__":
    for r in run([]):
        print(",".join(str(x) for x in r))
