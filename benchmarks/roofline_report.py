"""Benchmark: the roofline table, rendered from the dry-run results
(experiments/dryrun_results*.jsonl — produced by repro.launch.dryrun)."""
from __future__ import annotations

import json
import os

FILES = ("experiments/dryrun_results.jsonl",
         "experiments/dryrun_results_multipod.jsonl")


def load(files=FILES):
    recs = {}
    for f in files:
        if not os.path.exists(f):
            continue
        for line in open(f):
            r = json.loads(line)
            if r.get("ok"):
                recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def run(csv_rows):
    recs = load()
    for (arch, shape, mesh), r in sorted(recs.items()):
        csv_rows.append((
            f"roofline/{arch}/{shape}/{mesh}", r.get("compile_s", 0) * 1e6,
            f"comp={r['t_compute_s'] * 1e3:.1f}ms mem={r['t_memory_s'] * 1e3:.1f}ms "
            f"coll={r['t_collective_s'] * 1e3:.1f}ms bneck={r['bottleneck']} "
            f"useful={r['useful_flops_ratio']:.2f}"))
    if not recs:
        csv_rows.append(("roofline/missing", 0.0,
                         "run: python -m repro.launch.dryrun --all"))
    return csv_rows


if __name__ == "__main__":
    for row in run([]):
        print(",".join(str(x) for x in row))
