"""Trip-count-aware cost extraction from optimised HLO text.

``Compiled.cost_analysis()`` counts every while-loop body ONCE — for a
94-layer scan that under-counts FLOPs by ~94×. This parser walks the HLO
computation graph, multiplies loop bodies by their ``known_trip_count``, and
accounts:

- **flops**: 2 × |result| × |contracting dims| for every ``dot`` (dots are
  >99 % of model FLOPs in these architectures);
- **bytes**: operands + result of every top-level op (fusion internals are
  free — they live in registers/VMEM; dots inside fusions still count flops);
- **collectives**: result bytes per collective kind.

Costs are per device (the module is one SPMD partition's program).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

#: ops whose operand/result bytes do not represent HBM traffic. Besides the
#: no-op bookkeeping ops, plain elementwise/broadcast ops are excluded: the
#: TPU backend fuses them into neighbouring kernels (the CPU backend leaves
#: many at top level, which would overstate HBM traffic ~40x). Bytes are
#: counted for dots, fusions, copies, slices/updates, reduces, collectives —
#: the ops that necessarily move HBM data on TPU.
_FREE_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # fused-on-TPU elementwise / shape ops
    "add", "subtract", "multiply", "divide", "negate", "abs", "sign",
    "select", "compare", "convert", "and", "or", "not", "xor",
    "exponential", "exponential-minus-one", "tanh", "log", "log-plus-one",
    "sqrt", "rsqrt", "cbrt", "power", "maximum", "minimum", "clamp",
    "broadcast", "reshape", "floor", "ceil", "round-nearest-afz", "is-finite",
    "cosine", "sine", "logistic", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "remainder",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_info(type_str: str) -> Tuple[int, Tuple[int, ...]]:
    """bytes, dims of a (possibly tuple) type string."""
    total, dims = 0, ()
    for m in _SHAPE_RE.finditer(type_str):
        dt, ds = m.group(1), m.group(2)
        n = 1
        for d in ds.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
        dims = tuple(int(d) for d in ds.split(",") if d)
    return total, dims


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Dict[str, float]] = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in _COLLECTIVES}

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k in self.coll:
            self.coll[k] += o.coll[k]
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")


def _scan_type(s: str, i: int) -> int:
    """Return end index of the type string starting at s[i] (handles nested
    tuple types like ((s32[], bf16[2,3]{1,0}), f32[4]))."""
    if s[i] == "(":
        depth = 0
        while i < len(s):
            if s[i] == "(":
                depth += 1
            elif s[i] == ")":
                depth -= 1
                if depth == 0:
                    return i + 1
            i += 1
        return i
    m = re.match(r"\w+\[[\d,]*\](?:\{[^}]*\})?\S*", s[i:])
    return i + (m.end() if m else 0)


def _split_operands(s: str) -> List[str]:
    """Top-level comma split of the operand segment."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


def parse_computations(text: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        st = line.strip()
        hm = _HEADER_RE.match(st)
        if hm and st.endswith("{"):
            cur = hm.group(1)
            comps[cur] = []
            if st.startswith("ENTRY"):
                entry = cur
            continue
        if st.startswith("}"):
            continue
        nm = _NAME_RE.match(st)
        if nm and cur is not None:
            name = nm.group(1)
            tend = _scan_type(st, nm.end())
            if tend <= nm.end():
                continue
            type_str = st[nm.end():tend]
            om = _OPCODE_RE.match(st[tend:])
            if not om:
                continue
            opcode = om.group(1)
            rest = st[tend + om.end():]
            # operand segment: balance parens from here
            depth, i = 1, 0
            while i < len(rest) and depth:
                if rest[i] in "([{":
                    depth += 1
                elif rest[i] in ")]}":
                    depth -= 1
                i += 1
            operands = _split_operands(rest[:i - 1])
            attrs = rest[i:]
            comps[cur].append(Op(name, type_str, opcode, operands, attrs))
    comps["__entry__"] = comps.get(entry, [])
    if entry:
        comps.setdefault(entry, [])
        comps["__entry_name__"] = entry  # type: ignore
    return comps


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    rbytes, rdims = _shape_info(op.type_str)
    del rbytes
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    lhs = op.operands[0] if op.operands else ""
    if "[" in lhs:        # inline-typed operand: "f32[128,128]{1,0} %name"
        _, ldims = _shape_info(lhs)
    else:
        _, ldims = _shape_info(symtab.get(lhs.lstrip("%"), ""))
    k = 1
    for c in cdims:
        if c < len(ldims):
            k *= ldims[c]
    n = 1
    for d in rdims:
        n *= d
    return 2.0 * n * k


def _trip_count(op: Op) -> float:
    m = re.search(r'known_trip_count[^0-9]*(\d+)', op.attrs)
    return float(m.group(1)) if m else 1.0


def _called(op: Op) -> List[Tuple[str, float]]:
    """(computation name, multiplier) pairs invoked by this op."""
    out = []
    if op.opcode == "while":
        t = _trip_count(op)
        for key in ("body", "condition"):
            m = re.search(key + r"=%([\w.\-]+)", op.attrs)
            if m:
                out.append((m.group(1), t))
    elif op.opcode in ("fusion", "call", "async-start"):
        for key in ("calls", "to_apply", "called_computation"):
            m = re.search(key + r"=%([\w.\-]+)", op.attrs)
            if m:
                out.append((m.group(1), 1.0))
    elif op.opcode == "conditional":
        m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
        if m:
            names = [x.strip().lstrip("%") for x in m.group(1).split(",")]
            # conservative: every branch once (usually tiny)
            out += [(n, 1.0) for n in names]
        for key in ("true_computation", "false_computation"):
            m = re.search(key + r"=%([\w.\-]+)", op.attrs)
            if m:
                out.append((m.group(1), 1.0))
    return out


def module_cost(text: str) -> Cost:
    comps = parse_computations(text)
    entry = comps.pop("__entry_name__", None)  # type: ignore
    comps.pop("__entry__", None)
    memo: Dict[str, Cost] = {}

    def cost_of(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()  # cycle guard
        total = Cost()
        ops = comps.get(name, [])
        symtab = {o.name: o.type_str for o in ops}
        for op in ops:
            if op.opcode == "dot":
                total.flops += _dot_flops(op, symtab)
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                b, _ = _shape_info(op.type_str)
                total.coll[base] += b
            if op.opcode not in _FREE_BYTES:
                b, _ = _shape_info(op.type_str)
                if op.opcode in ("dynamic-slice", "slice", "gather"):
                    # reads only the addressed window, writes the result
                    total.bytes += 2 * b
                elif op.opcode in ("dynamic-update-slice", "scatter"):
                    # in-place: traffic = the update operand (read + write)
                    ub = 0
                    if len(op.operands) > 1:
                        ref = op.operands[1].lstrip("%")
                        if ref in symtab:
                            ub, _ = _shape_info(symtab[ref])
                    total.bytes += 2 * (ub or b)
                else:
                    ob = 0
                    for o in op.operands:
                        ref = o.lstrip("%")
                        if ref in symtab:
                            x, _ = _shape_info(symtab[ref])
                            ob += x
                        elif "[" in o:  # inline-typed operand
                            x, _ = _shape_info(o)
                            ob += x
                    total.bytes += b + ob
            for cname, mult in _called(op):
                total += cost_of(cname).scaled(mult)
        memo[name] = total
        return total

    if entry is None:
        # fall back: the computation that nothing else calls
        called = set()
        for ops in comps.values():
            for op in ops:
                called.update(n for n, _ in _called(op))
        roots = [n for n in comps if n not in called]
        entry = roots[-1] if roots else next(iter(comps))
    return cost_of(entry)
