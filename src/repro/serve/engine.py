"""KV-cache serving engine: batched prefill + decode with donated caches.

The decode step donates the cache pytree — the serving-side realisation of
the paper's in-place (O_s = |out|) overlap: the KV ring buffer, SSM states
and token-shift states are updated in their own storage every step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ArchConfig


@dataclasses.dataclass
class ServeConfig:
    cache_len: int = 2048
    window: int = 0            # sliding window for the sub-quadratic variant
    temperature: float = 0.0   # 0 = greedy
    max_new_tokens: int = 32


def make_prefill(cfg: ArchConfig, scfg: ServeConfig, in_shardings=None,
                 out_shardings=None):
    fn = functools.partial(T.prefill, cfg, cache_len=scfg.cache_len,
                           window=scfg.window)
    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
        kw["out_shardings"] = out_shardings
    return jax.jit(fn, **kw)


def make_decode(cfg: ArchConfig, scfg: ServeConfig, in_shardings=None,
                out_shardings=None):
    def step(params, cache, tokens, pos):
        return T.decode_step(cfg, params, cache, tokens, pos,
                             window=scfg.window)
    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
        kw["out_shardings"] = out_shardings
    return jax.jit(step, donate_argnums=(1,), **kw)  # cache updated in place


class Engine:
    """Minimal batched engine: same-length prompts, synchronous decode."""

    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig):
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self._prefill = make_prefill(cfg, scfg)
        self._decode = make_decode(cfg, scfg)

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits[:, -1] / self.scfg.temperature).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, seed: int = 0) -> np.ndarray:
        """prompts: (B, S) int32 (or (B,S,d) embeddings for stub frontends).
        Returns (B, max_new_tokens) int32."""
        b = prompts.shape[0]
        s = prompts.shape[1]
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        key = jax.random.PRNGKey(seed)
        toks = []
        tok = self._sample(logits, key)
        pos = jnp.int32(s)
        for i in range(self.scfg.max_new_tokens):
            toks.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache, tok[:, None], pos)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
            pos = pos + 1
        return np.stack(toks, axis=1)
