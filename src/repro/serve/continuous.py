"""Continuous-batching serving engine (vLLM-style slot scheduling).

A fixed pool of ``slots`` shares one donated KV ring cache; requests with
different prompt lengths run in the same decode step via per-slot position
vectors (ragged decode). When a request finishes (EOS / max tokens) its slot
is immediately recycled for the next queued request — no batch barrier.

Slot recycling reuses cache storage in place — the serving-scheduler face of
the paper's reuse discipline: storage whose value is dead (a finished
request's cache) is overwritten by the next value without reallocation.

Prefill runs per-request (simple); decode is one jitted, donated step for
the whole pool. Works for every decoder family (the cache pytree is
family-agnostic); prompts must be token ids.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ArchConfig


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                  # prompt (prompt_len,)
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ContinuousConfig:
    slots: int = 4
    cache_len: int = 256
    window: int = 0


class ContinuousEngine:
    def __init__(self, cfg: ArchConfig, params, ccfg: ContinuousConfig):
        self.cfg, self.params, self.ccfg = cfg, params, ccfg
        self.cache = T.init_cache(cfg, ccfg.slots, ccfg.cache_len)
        self.pos = np.zeros(ccfg.slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * ccfg.slots
        self.queue: List[Request] = []
        self.last_tok = np.zeros(ccfg.slots, np.int32)
        self._decode = jax.jit(
            lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos,
                                               window=ccfg.window),
            donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, toks: T.prefill(cfg, p, toks, ccfg.cache_len,
                                      window=ccfg.window),
            static_argnums=())

    # -- scheduling ----------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.ccfg.slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            logits, cache1 = self._prefill(self.params,
                                           jnp.asarray(req.tokens[None]))
            # copy the request's prefilled cache into slot s
            self.cache = jax.tree.map(
                lambda pool, one: pool.at[:, s].set(one[:, 0]),
                self.cache, cache1)
            self.slot_req[s] = req
            self.pos[s] = len(req.tokens)
            self.last_tok[s] = int(jnp.argmax(logits[0, -1]))
            req.out.append(int(self.last_tok[s]))

    def _retire(self) -> None:
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            if (len(req.out) >= req.max_new_tokens
                    or (req.eos_id is not None and req.out
                        and req.out[-1] == req.eos_id)):
                req.done = True
                self.slot_req[s] = None     # slot storage recycled in place
                self.pos[s] = 0

    # -- one engine step ------------------------------------------------
    def step(self) -> int:
        """Admit, decode one token for every active slot, retire. Returns
        the number of active requests after the step."""
        self._retire()
        self._admit()
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_tok[:, None]),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for s in active:
            self.pos[s] += 1
            self.last_tok[s] = nxt[s]
            self.slot_req[s].out.append(int(nxt[s]))
        self._retire()
        return sum(r is not None for r in self.slot_req)

    def run(self, max_steps: int = 1000) -> None:
        for _ in range(max_steps):
            active = self.step()
            if active == 0 and not self.queue:
                break
