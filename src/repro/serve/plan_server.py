"""Plan-routed serving runtime: batch-aware compiled plans behind a queue.

:class:`PlanServer` closes the loop between the compile pipeline's batched
plans (PR10: ``compile(graph, batch=b)``) and a request-serving front end.
At construction it compiles one plan *variant per batch size* and keeps the
variants whose arena peak fits the configured budget — the deployment-side
reading of the paper's arena discipline: the device has one fixed SRAM
arena, and the largest batch the arena admits is a *planning* question, not
a runtime guess. Queued requests are batched up to a deadline and routed to
the largest admitted variant; the server reports plan-cache hit rates,
per-batch arena peaks and request-level timing spans
(``scripts/export_trace.py --route serve`` renders them).

Execution uses :class:`FastExec`, a vectorised batched functional executor
sharing the per-op semantics of :mod:`repro.core.exec.ops`: the int8 tier
accumulates in float64 (every partial sum here is an integer far below
2**53, so the BLAS accumulation is *exactly* the reference int32
accumulation) and requantises through the identical float32 formula — int8
serving outputs match the arena backends to <= 1 LSB. The arena executors
stay the ground truth for *memory* behaviour; FastExec is the host-side
throughput engine the demo loop measures inferences/sec on.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import exec as X
from repro.core.exec.ops import (acc_multiplier, dequantise, op_quant, pads,
                                 quantise, requantise, rescale_q)
from repro.core.graph import Graph, Op


# ---------------------------------------------------------------------------
# FastExec: vectorised batched functional execution
# ---------------------------------------------------------------------------


def _conv_batched(op: Op, x: np.ndarray, filt: np.ndarray, q) -> np.ndarray:
    """conv2d / depthwise_conv2d over a batched (B, H, W, C) input: one
    accumulation per filter tap, taps in the reference's (fy, fx) order, each
    tap a BLAS matmul over the channel axis — the same per-tap shapes
    :func:`repro.core.exec.ops.conv_row` runs, just all rows at once."""
    B, ih, iw, ic = x.shape
    oh, ow = op.output.shape[-3], op.output.shape[-2]
    kh, kw = op.params["kernel"]
    sh, sw = op.params.get("stride", (1, 1))
    dh, dw = op.params.get("dilation", (1, 1))
    ph, pw = pads(op)
    kc = op.params.get("multiplier", 1)
    oc = op.output.shape[-1] if op.kind == "conv2d" else ic * kc
    if q is not None:
        # float64 keeps every int32 partial sum exact (|acc| << 2**53), so
        # the BLAS path reproduces the reference int32 accumulation bit for
        # bit before the shared float32 requantisation
        xf = x.astype(np.float64) - q.ins[0].zero_point
        wf = filt.astype(np.float64)
    else:
        xf = x.astype(np.float32)
        wf = filt
    pb = max(0, (oh - 1) * sh - ph + (kh - 1) * dh - (ih - 1))
    pr = max(0, (ow - 1) * sw - pw + (kw - 1) * dw - (iw - 1))
    xp = np.pad(xf, ((0, 0), (ph, pb), (pw, pr), (0, 0)))
    acc = np.zeros((B, oh, ow, oc), xf.dtype)
    for fy in range(kh):
        for fx in range(kw):
            sl = xp[:, fy * dh:fy * dh + (oh - 1) * sh + 1:sh,
                    fx * dw:fx * dw + (ow - 1) * sw + 1:sw, :]
            w = wf[fy, fx]
            if op.kind == "conv2d":
                acc += sl @ w
            else:
                acc += (sl[..., :, None] * w).reshape(B, oh, ow, oc)
    if q is not None:
        return requantise(acc, acc_multiplier(op, q), q.out.zero_point)
    return acc


def _pool_batched(op: Op, x: np.ndarray, q) -> np.ndarray:
    B, ih, iw, c = x.shape
    oh, ow = op.output.shape[-3], op.output.shape[-2]
    kh, kw = op.params["kernel"]
    sh, sw = op.params.get("stride", (1, 1))
    ph, pw = pads(op)
    mode = op.params.get("mode", "avg")
    xf = x.astype(np.float64 if q is not None else np.float32)
    pb = max(0, (oh - 1) * sh - ph + kh - ih)
    pr = max(0, (ow - 1) * sw - pw + kw - iw)
    padval = -np.inf if mode == "max" else 0.0
    xp = np.pad(xf, ((0, 0), (ph, pb), (pw, pr), (0, 0)),
                constant_values=padval)
    ones = np.pad(np.ones((B, ih, iw, 1), np.float32),
                  ((0, 0), (ph, pb), (pw, pr), (0, 0)))
    if mode == "max":
        acc = np.full((B, oh, ow, c), -np.inf, xf.dtype)
    else:
        acc = np.zeros((B, oh, ow, c), xf.dtype)
    cnt = np.zeros((B, oh, ow, 1), np.float32)
    for fy in range(kh):
        for fx in range(kw):
            sl = xp[:, fy:fy + (oh - 1) * sh + 1:sh,
                    fx:fx + (ow - 1) * sw + 1:sw, :]
            if mode == "max":
                acc = np.maximum(acc, sl)
            else:
                acc += sl
                cnt += ones[:, fy:fy + (oh - 1) * sh + 1:sh,
                            fx:fx + (ow - 1) * sw + 1:sw, :]
    if q is not None:
        x_zp, mult = q.ins[0].zero_point, acc_multiplier(op, q)
        if mode == "avg":
            val = acc.astype(np.float32) / np.maximum(cnt, 1.0) - x_zp
        else:
            val = acc - x_zp
        return requantise(val, mult, q.out.zero_point)
    if mode == "avg":
        acc = acc / np.maximum(cnt, 1.0)
    return acc.astype(np.float32)


class FastExec:
    """Vectorised batched functional executor of one graph. Values carry an
    explicit leading batch axis (B >= 1); weights / calibration are the
    deterministic per-seed synthesis every arena backend shares, so outputs
    are directly comparable to the numpy/pallas backends."""

    def __init__(self, graph: Graph, seed: int = 0, weights=None, quant=None):
        self.graph = graph
        reason = X.executability(graph)
        if reason is not None:
            raise ValueError(f"FastExec cannot execute {graph.name!r}: "
                             f"{reason}")
        self.weights = weights if weights is not None \
            else X.synth_weights(graph, seed)
        if quant is None and X.needs_quant(graph):
            quant = X.calibrate(graph, seed, self.weights)
        self.quant = quant

    def _filter(self, op: Op, q):
        if q is not None and id(op) in self.quant.weights_q:
            return self.quant.weights_q[id(op)]["filter"]
        return self.weights[id(op)].get("filter")

    def run(self, inputs: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Execute on batched inputs ``{name: (B,) + shape}`` (the per-image
        shape is auto-lifted to B=1). Float values fed to int8 input tensors
        are quantised at the calibrated params. Returns batched outputs."""
        g = self.graph
        vals: Dict[Any, np.ndarray] = {}
        B = 1
        for t in g.tensors:
            if t.kind != "input":
                continue
            v = np.asarray(inputs[t.name])
            if v.ndim == len(t.shape):
                v = v[None]
            if t.dtype_bytes == 1 and v.dtype != np.int8:
                v = quantise(v.astype(np.float32),
                             self.quant.tensors[t.name])
            vals[t.storage()] = v
            B = v.shape[0]
        for op in g.ops:
            vals[op.output.storage()] = self._eval(op, vals, B)
        return {t.name: vals[t.storage()]
                for t in g.tensors if t.kind == "output"}

    def _eval(self, op: Op, vals, B: int) -> np.ndarray:
        xs = [vals[t.storage()] for t in op.inputs
              if t.storage().kind != "weight"]
        if op.kind == "reshape":
            return xs[0].reshape((B,) + tuple(op.output.shape))
        q = op_quant(op, self.quant)
        k = op.kind
        if k in ("conv2d", "depthwise_conv2d"):
            return _conv_batched(op, xs[0], self._filter(op, q), q)
        if k == "pool":
            return _pool_batched(op, xs[0], q)
        if k == "elementwise":
            fn = X.ELEMENTWISE[op.params.get("fn", "relu")]
            if q is not None:
                xs = [dequantise(x, qp) for x, qp in zip(xs, q.ins)]
            xs = list(xs)
            if len(xs) == 2 and xs[1].shape != xs[0].shape:
                pad = (1,) * (xs[0].ndim - xs[1].ndim)
                xs[1] = np.broadcast_to(
                    xs[1].reshape((B,) + pad + xs[1].shape[1:]), xs[0].shape)
            y = fn(*xs).astype(np.float32)
            return quantise(y, q.out) if q is not None else y
        if k == "softmax":
            x = dequantise(xs[0], q.ins[0]) if q is not None else xs[0]
            e = np.exp(x - x.max(axis=-1, keepdims=True))
            y = (e / e.sum(axis=-1, keepdims=True)).astype(np.float32)
            return quantise(y, q.out) if q is not None else y
        if k == "fully_connected":
            filt = self._filter(op, q)
            x = xs[0].reshape(-1, op.inputs[0].shape[-1])
            oshape = (B,) + tuple(op.output.shape)
            if q is not None:
                acc = (x.astype(np.float64) - q.ins[0].zero_point) \
                    @ filt.astype(np.float64)
                return requantise(acc, acc_multiplier(op, q),
                                  q.out.zero_point).reshape(oshape)
            return (x @ filt).reshape(oshape).astype(np.float32)
        if k == "matmul":
            a = xs[0].reshape((B, -1) + (op.inputs[0].shape[-1],))
            b = xs[1].reshape((B,) + tuple(op.inputs[1].shape))
            oshape = (B,) + tuple(op.output.shape)
            if q is not None:
                acc = (a.astype(np.float64) - q.ins[0].zero_point) \
                    @ (b.astype(np.float64) - q.ins[1].zero_point)
                return requantise(acc, acc_multiplier(op, q),
                                  q.out.zero_point).reshape(oshape)
            return (a @ b).reshape(oshape).astype(np.float32)
        if k == "concat":
            axis = op.params.get("axis", -1)
            if axis >= 0:
                axis += 1  # leading batch axis
            if q is not None:
                xs = [rescale_q(x, qp, q.out) for x, qp in zip(xs, q.ins)]
            return np.concatenate(list(xs), axis=axis)
        if k == "pad":
            pad = [(0, 0)] + [tuple(p) for p in op.params["paddings"]]
            if q is not None:
                padded = np.pad(xs[0], pad,
                                constant_values=q.ins[0].zero_point)
                return rescale_q(padded, q.ins[0], q.out)
            return np.pad(xs[0], pad)
        if k == "mean":
            x = xs[0]
            axes = tuple(a + 1 for a in
                         op.params.get("axes", range(x.ndim - 2)))
            oshape = (B,) + tuple(op.output.shape)
            if q is not None:
                cnt = 1
                for ax in axes:
                    cnt *= x.shape[ax]
                acc = x.astype(np.float64).sum(axis=axes)
                val = acc.astype(np.float32) / np.float32(cnt) \
                    - q.ins[0].zero_point
                return requantise(val, acc_multiplier(op, q),
                                  q.out.zero_point).reshape(oshape)
            return x.mean(axis=axes).reshape(oshape).astype(np.float32)
        raise NotImplementedError(f"FastExec: {k}")


# ---------------------------------------------------------------------------
# PlanServer: deadline batching over compiled batch variants
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeRequest:
    """One queued inference request plus its timing spans (seconds on the
    server's monotonic clock): submit -> batch assembly -> execute."""
    rid: int
    inputs: Dict[str, np.ndarray]         # per-image inputs, keyed by name
    t_submit: float
    t_batch: float = 0.0                  # popped from queue (assembly start)
    t_exec0: float = 0.0
    t_done: float = 0.0
    batch: int = 0                        # variant the request rode in
    output: Optional[Dict[str, np.ndarray]] = None


class PlanServer:
    """Route queued requests onto the largest compiled batch variant that
    fits the arena budget.

    One ``compile(graph, batch=b)`` per ``b`` in ``batches``; variants whose
    arena ``peak_bytes`` exceed ``arena_budget`` are dropped (the device
    could not hold their arena). Requests queue until either enough are
    waiting to fill the largest admitted variant or the oldest request's
    ``max_delay_s`` deadline expires; each flush runs the largest variant
    that the queue can fill (padding up to the smallest variant only when
    forced to drain a short tail).
    """

    def __init__(self, graph: Graph, *, arena_budget: Optional[int] = None,
                 batches: Sequence[int] = (1, 2, 4, 8),
                 max_delay_s: float = 0.002, seed: int = 0,
                 **compile_kwargs):
        from repro.core.pipeline import cache_info, compile as compile_graph
        self.graph = graph
        self.arena_budget = arena_budget
        self.max_delay_s = max_delay_s
        before = cache_info()
        self.variants = {}
        self.rejected: Dict[int, int] = {}    # b -> peak that broke budget
        for b in sorted(set(int(b) for b in batches)):
            cp = compile_graph(graph, batch=b, **compile_kwargs)
            if arena_budget is None or cp.peak_bytes <= arena_budget:
                self.variants[b] = cp
            else:
                self.rejected[b] = cp.peak_bytes
        if not self.variants:
            raise ValueError(
                f"arena budget {arena_budget} admits no batch variant of "
                f"{graph.name!r} (smallest peak: "
                f"{min(self.rejected.values())} bytes)")
        after = cache_info()
        self._cache_delta = {k: after[k] - before[k]
                             for k in ("hits", "misses",
                                       "disk_hits", "disk_misses")}
        self._exec = FastExec(graph, seed=seed)
        self.queue: deque = deque()
        self.done: List[ServeRequest] = []
        self.batches_run: Dict[int, int] = {b: 0 for b in self.variants}
        self._next_rid = 0
        self._t0: Optional[float] = None
        self._t_last: float = 0.0

    # -- queue ---------------------------------------------------------
    def submit(self, inputs: Dict[str, np.ndarray]) -> int:
        now = time.perf_counter()
        if self._t0 is None:
            self._t0 = now
        req = ServeRequest(self._next_rid, inputs, now)
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    def _pick_batch(self, force: bool) -> Optional[int]:
        if not self.queue:
            return None
        bs = sorted(self.variants)
        if len(self.queue) >= bs[-1]:
            return bs[-1]
        age = time.perf_counter() - self.queue[0].t_submit
        if not force and age < self.max_delay_s:
            return None                  # deadline not hit: keep batching
        fit = [b for b in bs if b <= len(self.queue)]
        return fit[-1] if fit else bs[0]  # pad up to the smallest variant

    # -- execution -----------------------------------------------------
    def step(self, force: bool = False) -> int:
        """Flush at most one batch; returns the number of requests served."""
        b = self._pick_batch(force)
        if b is None:
            return 0
        now = time.perf_counter()
        reqs = [self.queue.popleft()
                for _ in range(min(b, len(self.queue)))]
        for r in reqs:
            r.t_batch, r.batch = now, b
        stacked = {
            t.name: np.stack(
                [np.asarray(reqs[min(i, len(reqs) - 1)].inputs[t.name])
                 for i in range(b)])   # tail shorter than b: pad by repeat
            for t in self.graph.tensors if t.kind == "input"}
        t_exec0 = time.perf_counter()
        outs = self._exec.run(stacked)
        t_done = time.perf_counter()
        for i, r in enumerate(reqs):
            r.t_exec0, r.t_done = t_exec0, t_done
            r.output = {k: v[i] for k, v in outs.items()}
        self.done.extend(reqs)
        self.batches_run[b] += 1
        self._t_last = t_done
        return len(reqs)

    def drain(self) -> int:
        """Serve everything queued (forcing deadline flushes); returns the
        number of requests served."""
        n = 0
        while self.queue:
            n += self.step(force=True)
        return n

    # -- reporting -----------------------------------------------------
    def spans(self) -> List[Dict[str, Any]]:
        """Request-level timing spans (seconds relative to the first
        submit): queue wait, batch assembly, execute."""
        t0 = self._t0 or 0.0
        return [{"rid": r.rid, "batch": r.batch,
                 "t_submit": r.t_submit - t0,
                 "queue_wait_s": r.t_batch - r.t_submit,
                 "assemble_s": r.t_exec0 - r.t_batch,
                 "execute_s": r.t_done - r.t_exec0}
                for r in self.done]

    def stats(self) -> Dict[str, Any]:
        n = len(self.done)
        waits = [r.t_batch - r.t_submit for r in self.done]
        total = self._cache_delta["hits"] + self._cache_delta["misses"]
        wall = (self._t_last - self._t0) if (self._t0 and n) else 0.0
        return {
            "model": self.graph.name,
            "arena_budget": self.arena_budget,
            "batches": sorted(self.variants),
            "rejected_batches": dict(self.rejected),
            "per_batch_peak_bytes": {b: cp.peak_bytes
                                     for b, cp in self.variants.items()},
            "batches_run": dict(self.batches_run),
            "requests_served": n,
            "queued": len(self.queue),
            "plan_cache": {**self._cache_delta,
                           "hit_rate": round(
                               self._cache_delta["hits"] / total, 3)
                           if total else None},
            "mean_queue_wait_ms": round(1e3 * sum(waits) / n, 3) if n else 0,
            "throughput_inf_s": round(n / wall, 1) if wall > 0 else None,
        }


def throughput_demo(graph: Graph, *, n_requests: int = 256,
                    arena_budget: Optional[int] = None,
                    batches: Sequence[int] = (1, 2, 4, 8),
                    seed: int = 0, **compile_kwargs) -> Dict[str, Any]:
    """Closed-loop serving demo: submit ``n_requests`` synthetic requests,
    drain the server, return its stats (throughput in inferences/sec,
    per-batch arena peaks, cache hit rate). The benchmark harness embeds
    the result in the ``--json`` artifact."""
    server = PlanServer(graph, arena_budget=arena_budget, batches=batches,
                        seed=seed, **compile_kwargs)
    rng = np.random.default_rng(seed + 1)
    names = [t.name for t in graph.tensors if t.kind == "input"]
    shapes = {t.name: tuple(t.shape)
              for t in graph.tensors if t.kind == "input"}
    for _ in range(n_requests):
        server.submit({nm: rng.standard_normal(shapes[nm]).astype(np.float32)
                       for nm in names})
        server.step()            # serve opportunistically while loading
    server.drain()
    return server.stats()
