"""repro.serve subpackage.

- :mod:`.engine` / :mod:`.continuous` — KV-cache decoding engines (the
  transformer-family serving path);
- :mod:`.plan_server` — the plan-routed CNN serving runtime: batch-aware
  compiled arena plans behind a deadline-batching request queue
  (:class:`~repro.serve.plan_server.PlanServer`).
"""
from repro.serve.plan_server import (FastExec, PlanServer, ServeRequest,
                                     throughput_demo)

__all__ = ["FastExec", "PlanServer", "ServeRequest", "throughput_demo"]
