"""repro.serve subpackage."""
