"""repro.data subpackage."""
