"""Synthetic-corpus data pipeline: deterministic document stream, packing,
host-side batching, sharded device feed.

There is no dataset on disk in this container, so the corpus is a seeded
"hash stream" of variable-length documents over the arch's vocabulary —
enough to drive real training steps, verify loss decrease on learnable
structure (documents are n-gram-ish: each token depends on the previous
one), and exercise packing and sharding end to end.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

try:  # optional jax import so pure-numpy tests can use the pipeline
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
except Exception:  # pragma: no cover
    jax = None


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    eos_id: int = 0


class SyntheticCorpus:
    """Deterministic bigram-flavoured documents (learnable structure)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # sparse "bigram" successor table: token t -> a small candidate set
        self._succ = rng.integers(1, v, size=(min(v, 4096), 4), dtype=np.int64)

    def documents(self) -> Iterator[np.ndarray]:
        rng = np.random.default_rng(self.cfg.seed + 1)
        v = self.cfg.vocab_size
        while True:
            n = max(8, int(rng.exponential(self.cfg.mean_doc_len)))
            toks = np.empty(n, np.int64)
            toks[0] = rng.integers(1, v)
            for i in range(1, n):
                cands = self._succ[toks[i - 1] % len(self._succ)]
                toks[i] = cands[rng.integers(0, len(cands))]
            yield toks

    def packed_batches(self) -> Iterator[Dict[str, np.ndarray]]:
        """Packs documents (EOS-delimited) into (B, S+1) windows, yielding
        {"inputs": (B,S), "targets": (B,S)}."""
        cfg = self.cfg
        docs = self.documents()
        buf = np.empty(0, np.int64)
        need = cfg.global_batch * (cfg.seq_len + 1)
        while True:
            while buf.size < need:
                d = next(docs)
                buf = np.concatenate([buf, d, [cfg.eos_id]])
            chunk = buf[:need].reshape(cfg.global_batch, cfg.seq_len + 1)
            buf = buf[need:]
            yield {
                "inputs": chunk[:, :-1].astype(np.int32),
                "targets": chunk[:, 1:].astype(np.int32),
            }


def shard_batch(batch: Dict[str, np.ndarray], mesh=None, batch_axes=("data",)):
    """Place a host batch onto the mesh with batch-dim sharding."""
    if jax is None or mesh is None:
        return batch
    ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def put(x):
        spec = P(ax, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return {k: put(v) for k, v in batch.items()}


def embedding_batches(cfg: DataConfig, d_model: int,
                      seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Frontend-stub stream for audio/VLM archs: precomputed frame/patch
    embeddings plus next-token targets."""
    rng = np.random.default_rng(seed)
    while True:
        yield {
            "inputs": rng.standard_normal(
                (cfg.global_batch, cfg.seq_len, d_model)).astype(np.float32),
            "targets": rng.integers(
                0, cfg.vocab_size,
                (cfg.global_batch, cfg.seq_len)).astype(np.int32),
        }
