"""Mesh-axis environment + activation sharding constraints.

The model code is mesh-agnostic: it calls :func:`constrain` with *logical*
axis names ("batch", "model", "seq", None...). The launcher installs an
:class:`AxisEnv` mapping logical names to physical mesh axes — e.g. batch ->
("pod", "data") on the multi-pod mesh, ("data",) on one pod. Outside any env
(unit tests on a bare CPU) ``constrain`` is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


class AxisEnv:
    def __init__(self, mesh: Mesh, batch: Tuple[str, ...] = ("data",),
                 model: str = "model", fsdp: bool = False):
        self.mesh = mesh
        self.batch = tuple(batch)
        self.model = model
        #: expert/mlp weights additionally sharded over the data axis
        self.fsdp = fsdp

    def resolve(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == "batch":
            return self.batch if len(self.batch) > 1 else self.batch[0]
        if logical == "model":
            return self.model
        raise ValueError(f"unknown logical axis {logical!r}")

    def spec(self, *dims: Optional[str]) -> P:
        return P(*[self.resolve(d) for d in dims])

    def sharding(self, *dims: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*dims))


def current_env() -> Optional[AxisEnv]:
    return getattr(_state, "env", None)


@contextlib.contextmanager
def axis_env(mesh: Mesh, batch: Tuple[str, ...] = ("data",),
             model: str = "model", fsdp: bool = False):
    prev = current_env()
    _state.env = AxisEnv(mesh, batch, model, fsdp)
    try:
        yield _state.env
    finally:
        _state.env = prev


def constrain(x: jax.Array, *dims: Optional[str]) -> jax.Array:
    """with_sharding_constraint against the installed env (no-op without)."""
    env = current_env()
    if env is None:
        return x
    # skip axes that do not divide (XLA tolerates uneven but padding hurts)
    spec = []
    for size, d in zip(x.shape, dims):
        phys = env.resolve(d)
        if phys is None:
            spec.append(None)
            continue
        n = 1
        for a in (phys if isinstance(phys, tuple) else (phys,)):
            n *= env.mesh.shape[a]
        spec.append(phys if size % n == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(env.mesh, P(*spec)))
