"""MusicGen-medium — decoder-only over EnCodec tokens; the EnCodec frontend
is a stub (input_specs supplies frame embeddings). [arXiv:2306.05284]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    attention="gqa",
    activation="gelu",
    rope_theta=1e4,
    frontend="audio_stub",
    frontend_prefix=0,
    source="arXiv:2306.05284",
)
