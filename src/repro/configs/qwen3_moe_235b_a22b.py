"""Qwen3-MoE 235B-A22B — 128 experts, top-8. [hf:Qwen/Qwen3-30B-A3B scaled
per assignment: 94L, d_model 4096, 64 q heads / 4 kv, moe d_ff 1536,
vocab 151936]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    attention="gqa",
    activation="silu",
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=1536,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B",
)
