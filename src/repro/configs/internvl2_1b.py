"""InternVL2-1B — InternViT vision encoder (stub) + Qwen2-0.5B-class LM
backbone (24L, d 896, 14H/2KV). [arXiv:2404.16821]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    attention="gqa",
    qkv_bias=True,
    activation="silu",
    rope_theta=1e6,
    frontend="vision_stub",
    frontend_prefix=256,   # patch embeddings per image tile
    source="arXiv:2404.16821",
)
