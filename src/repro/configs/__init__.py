"""Assigned architecture registry (``--arch <id>``).

Every config cites its source; smoke variants via ``.reduced()``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ArchConfig, ShapeConfig, SHAPES

_ARCH_MODULES = [
    "qwen3_moe_235b_a22b",
    "musicgen_medium",
    "nemotron_4_15b",
    "hymba_1_5b",
    "minicpm3_4b",
    "rwkv6_1_6b",
    "internvl2_1b",
    "yi_6b",
    "qwen2_5_3b",
    "olmoe_1b_7b",
]


def registry() -> Dict[str, ArchConfig]:
    out = {}
    for m in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        cfg = mod.CONFIG
        out[cfg.name] = cfg
    return out


def get_arch(name: str) -> ArchConfig:
    reg = registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(reg)}")
    return reg[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def arch_names() -> List[str]:
    return list(registry())
