"""Yi-6B — llama-arch GQA (32H/4KV). [arXiv:2403.04652]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    attention="gqa",
    activation="silu",
    rope_theta=5e6,
    source="arXiv:2403.04652",
)
