"""MiniCPM3-4B — multi-head latent attention (MLA). [hf:openbmb/MiniCPM3-4B]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=64,          # qk nope head dim
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    activation="silu",
    q_lora_rank=768,
    kv_lora_rank=256,
    rope_head_dim=32,
    v_head_dim=64,
    rope_theta=1e4,
    source="hf:openbmb/MiniCPM3-4B",
)
