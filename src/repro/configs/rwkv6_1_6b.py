"""RWKV6 (Finch) 1.6B — attention-free, data-dependent decay.
[arXiv:2404.05892]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    attention="none",
    activation="sq_relu",   # rwkv channel mix uses squared relu
    ssm_state=64,           # wkv head size
    source="arXiv:2404.05892",
)
