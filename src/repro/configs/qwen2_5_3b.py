"""Qwen2.5-3B — GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B family]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    attention="gqa",
    qkv_bias=True,
    activation="silu",
    rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-0.5B",
)
