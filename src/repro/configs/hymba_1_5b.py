"""Hymba-1.5B — hybrid: parallel attention + Mamba heads per block,
ssm_state 16. [arXiv:2411.13676]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attention="hybrid",
    activation="silu",
    ssm_state=16,
    ssm_expand=2,
    conv_kernel=4,
    sliding_window=1024,
    rope_theta=1e4,
    source="arXiv:2411.13676",
)
