"""Nemotron-4 15B — GQA (48H/8KV), squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    attention="gqa",
    activation="sq_relu",
    rope_theta=1e4,
    source="arXiv:2402.16819",
)
