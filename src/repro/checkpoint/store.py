"""Checkpointing: pytree <-> npz with '/'-joined key paths.

Single-file npz per step; sharded arrays are gathered through addressable
shards (single-host container) and restored with the caller's shardings.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import numpy as np

import jax


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save(path: str, tree, step: Optional[int] = None) -> str:
    if step is not None:
        path = os.path.join(path, f"step_{step:08d}.npz")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    np.savez(path, **flat)
    return path


def restore(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs); device placement follows ``shardings`` if given."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}

    leaves_like, treedef = jax.tree.flatten(like)
    flat_like = _flatten(like)
    assert set(flat_like) == set(flat), (
        f"checkpoint keys mismatch: {set(flat_like) ^ set(flat)}")

    def build(template, prefix=""):
        if isinstance(template, dict):
            return {k: build(v, f"{prefix}{k}/") for k, v in template.items()}
        if isinstance(template, (list, tuple)):
            return type(template)(
                build(v, f"{prefix}{i}/") for i, v in enumerate(template))
        return flat[prefix[:-1]]

    arrs = build(like)
    if shardings is not None:
        arrs = jax.tree.map(
            lambda a, s: jax.device_put(a, s), arrs, shardings)
    del leaves_like, treedef
    return arrs


def latest(path: str) -> Optional[str]:
    if not os.path.isdir(path):
        return None
    cands = sorted(f for f in os.listdir(path)
                   if f.startswith("step_") and f.endswith(".npz"))
    return os.path.join(path, cands[-1]) if cands else None
