"""repro.checkpoint subpackage."""
