"""Architecture configuration for the assigned model families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One decoder architecture. Every assigned arch is an instance; reduced
    smoke variants are produced with :meth:`reduced`."""

    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                # 0 => attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention
    attention: str = "gqa"        # gqa | mla | none | hybrid
    qkv_bias: bool = False
    rope_theta: float = 1e6
    #: window used by the sub-quadratic long-context decode variant; 0 = full
    sliding_window: int = 4096
    activation: str = "silu"      # silu | sq_relu | gelu

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # MLA (MiniCPM3 / DeepSeek-style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0        # per-head rope sub-dim for MLA
    v_head_dim: int = 0

    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    conv_kernel: int = 4

    # modality frontend ("stub": input_specs provides embeddings directly)
    frontend: str = "none"        # none | vision_stub | audio_stub
    #: number of prefix embedding positions supplied by the frontend stub
    frontend_prefix: int = 0

    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    #: store the GQA KV cache in int8 with per-(slot, kv-head) scales —
    #: halves decode's dominant HBM term (see EXPERIMENTS.md §Perf)
    kv_quant: bool = False
    source: str = ""              # citation

    # ------------------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.attention == "none"

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k decode with bounded memory/compute?
        SSM/hybrid natively; attention archs via the sliding-window variant
        (enabled for all of them — recorded in DESIGN.md)."""
        return True

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attention in ("gqa", "hybrid"):
            per_layer += d * self.q_dim + self.q_dim * d + 2 * d * self.kv_dim
        if self.attention == "mla":
            qd = self.q_lora_rank or d
            per_layer += d * self.q_lora_rank + self.q_lora_rank * self.num_heads * (
                self.head_dim + self.rope_head_dim)
            per_layer += d * (self.kv_lora_rank + self.rope_head_dim)
            per_layer += self.kv_lora_rank * self.num_heads * (
                self.head_dim + self.v_head_dim)
            per_layer += self.num_heads * self.v_head_dim * d
            del qd
        if self.attention in ("none", "hybrid"):  # ssm branch
            dint = self.d_model * self.ssm_expand
            per_layer += d * dint * 3 + dint * d
        n_mats = 3 if self.activation == "silu" else 2  # gated vs plain MLP
        if self.is_moe:
            per_layer += d * self.num_experts  # router
            per_layer += self.num_experts * 3 * d * self.moe_d_ff
        else:
            per_layer += n_mats * d * self.d_ff
        return n + per_layer * L

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        dense = self.param_count()
        moe_all = self.num_layers * self.num_experts * 3 * self.d_model * self.moe_d_ff
        moe_act = self.num_layers * self.experts_per_token * 3 * self.d_model * self.moe_d_ff
        return dense - moe_all + moe_act

    def reduced(self) -> "ArchConfig":
        """2-layer, d_model<=512, <=4-expert smoke variant of the family."""
        d = min(self.d_model, 256)
        hd = 32
        heads = max(2, min(4, self.num_heads or 2))
        kv = max(1, min(heads, self.num_kv_heads or heads))
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=d,
            num_heads=0 if self.attn_free else heads,
            num_kv_heads=0 if self.attn_free else kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.is_moe else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.is_moe else 0,
            moe_d_ff=min(self.moe_d_ff, 128) if self.is_moe else 0,
            q_lora_rank=min(self.q_lora_rank, 64),
            kv_lora_rank=min(self.kv_lora_rank, 32),
            rope_head_dim=min(self.rope_head_dim, 16),
            v_head_dim=hd if self.v_head_dim else 0,
            ssm_state=min(self.ssm_state, 16),
            sliding_window=min(self.sliding_window, 64),
            frontend_prefix=min(self.frontend_prefix, 8),
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""
    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode | long_decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "long_decode"),
}
