"""Config-driven decoder stack covering all assigned families.

Layers are stacked (leading ``L`` dim on every block parameter) and applied
with ``jax.lax.scan`` so the compiled HLO stays one-block-sized regardless of
depth — essential for the 94-layer dry-runs.

Three entry points:
  forward_train(cfg, params, inputs)            -> logits, aux
  prefill(cfg, params, inputs, cache_len)       -> logits, cache
  decode_step(cfg, params, cache, tokens, pos)  -> logits, cache

``inputs`` is a token array (B,S) int32, or pre-computed embeddings
(B,S,d_model) for the audio/VLM frontend-stub families.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.config import ArchConfig
from repro.sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _block_init(cfg: ArchConfig, key) -> Params:
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    p: Params = {"norm1": L.rms_norm_init(cfg.d_model, dt),
                 "norm2": L.rms_norm_init(cfg.d_model, dt)}
    if cfg.attention == "gqa":
        p["attn"] = L.attn_init(cfg, ks[0])
    elif cfg.attention == "mla":
        p["attn"] = L.mla_init(cfg, ks[0])
    elif cfg.attention == "hybrid":
        p["attn"] = L.attn_init(cfg, ks[0])
        p["mamba"] = S.mamba_init(cfg, ks[1])
    elif cfg.attention == "none":
        p["rwkv"] = S.rwkv_init(cfg, ks[0])
    else:
        raise ValueError(cfg.attention)
    if cfg.attention == "none":
        p["cmix"] = S.rwkv_channel_mix_init(cfg, ks[2])
    elif cfg.is_moe:
        p["moe"] = M.moe_init(cfg, ks[2])
    else:
        p["mlp"] = L.mlp_init(cfg, ks[2])
    return p


def init_params(cfg: ArchConfig, key) -> Params:
    ke, kb, kh = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    blocks = jax.vmap(lambda k: _block_init(cfg, k))(
        jax.random.split(kb, cfg.num_layers))
    p = {
        "embed": jax.random.normal(ke, (cfg.vocab_size, cfg.d_model), dt) * 0.02,
        "blocks": blocks,
        "final_norm": L.rms_norm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(kh, (cfg.d_model, cfg.vocab_size),
                                         dt) * 0.02
    return p


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _block_seq(cfg: ArchConfig, bp: Params, x: jax.Array, window: int
               ) -> Tuple[jax.Array, Params, jax.Array]:
    """Full-sequence block (train / prefill). Returns (x, cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(bp["norm1"], x)
    cache: Params = {}
    if cfg.attention == "gqa":
        y, cache = L.attn_forward(bp["attn"], h, cfg, window)
    elif cfg.attention == "mla":
        y, cache = L.mla_forward(bp["attn"], h, cfg, window)
    elif cfg.attention == "hybrid":
        ya, ca = L.attn_forward(bp["attn"], h, cfg, window or cfg.sliding_window)
        ym, cm = S.mamba_forward(bp["mamba"], h, cfg)
        y = 0.5 * (ya + ym)
        cache = {**ca, **cm}
    else:  # rwkv
        y, cache = S.rwkv_forward(bp["rwkv"], h, cfg)
    x = constrain(x + y, "batch", None, None)
    h = L.rms_norm(bp["norm2"], x)
    if cfg.attention == "none":
        hp = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        y = S.rwkv_channel_mix(bp["cmix"], h, hp)
        cache["cm_shift"] = h[:, -1]
    elif cfg.is_moe:
        y, aux = M.moe_ffn(bp["moe"], h, cfg)
    else:
        y = L.mlp(bp["mlp"], h, cfg)
    x = constrain(x + y, "batch", None, None)
    return x, cache, aux


def _block_dec(cfg: ArchConfig, bp: Params, x: jax.Array, cache: Params,
               pos: jax.Array, window: int) -> Tuple[jax.Array, Params]:
    """Single-token decode block."""
    h = L.rms_norm(bp["norm1"], x)
    new: Params = {}
    if cfg.attention == "gqa":
        y, new = L.attn_decode(bp["attn"], h, cache, pos, cfg, window)
    elif cfg.attention == "mla":
        y, new = L.mla_decode(bp["attn"], h, cache, pos, cfg, window)
    elif cfg.attention == "hybrid":
        ya, ca = L.attn_decode(bp["attn"], h,
                               {"k": cache["k"], "v": cache["v"]}, pos, cfg,
                               window or cfg.sliding_window)
        ym, cm = S.mamba_decode(bp["mamba"], h,
                                {"ssm": cache["ssm"], "conv": cache["conv"]},
                                cfg)
        y = 0.5 * (ya + ym)
        new = {**ca, **cm}
    else:
        y, new = S.rwkv_decode(bp["rwkv"], h, cache, cfg)
    x = x + y
    h = L.rms_norm(bp["norm2"], x)
    if cfg.attention == "none":
        y = S.rwkv_channel_mix(bp["cmix"], h, cache["cm_shift"][:, None])
        new["cm_shift"] = h[:, 0]
    elif cfg.is_moe:
        y, _ = M.moe_ffn(bp["moe"], h, cfg)
    else:
        y = L.mlp(bp["mlp"], h, cfg)
    return x + y, new


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed(cfg: ArchConfig, params: Params, inputs: jax.Array) -> jax.Array:
    if inputs.dtype in (jnp.int32, jnp.int64):
        x = params["embed"][inputs]
    else:  # frontend stub already produced embeddings
        x = inputs.astype(jnp.dtype(cfg.dtype))
    return constrain(x, "batch", None, None)


def unembed(cfg: ArchConfig, params: Params, x: jax.Array) -> jax.Array:
    x = L.rms_norm(params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return constrain(logits, "batch", None, "model")


# ---------------------------------------------------------------------------
# Full passes (scan over stacked layers)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def identity_barrier(x: jax.Array) -> jax.Array:
    """``optimization_barrier`` with a straight-through gradient.

    jax 0.4.x defines no differentiation rule for the barrier primitive, so
    using it bare inside a scan body breaks every train step. The barrier is
    semantically the identity — the backward pass forwards cotangents
    unchanged while the forward keeps the XLA scheduling fence."""
    return jax.lax.optimization_barrier(x)


def _identity_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _identity_barrier_bwd(_, ct):
    return (ct,)


identity_barrier.defvjp(_identity_barrier_fwd, _identity_barrier_bwd)


#: layers per remat group: the scan saves one residual carry per GROUP, so
#: grouping halves (G=2) the dominant carry stacks at the cost of one extra
#: in-group forward during backprop (§Perf hillclimb 2). Only worth it for
#: deep stacks — shallow models pay the in-group transients for nothing.
REMAT_GROUP = 2
REMAT_GROUP_MIN_LAYERS = 48


def forward_hidden(cfg: ArchConfig, params: Params, inputs: jax.Array,
                   remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Returns (final hidden states (B,S,d) pre-norm/head, moe aux loss) —
    callers that want a memory-bounded loss apply the head per seq chunk."""
    x = embed(cfg, params, inputs)
    g = REMAT_GROUP if (remat and cfg.num_layers % REMAT_GROUP == 0
                        and cfg.num_layers >= REMAT_GROUP_MIN_LAYERS) else 1

    def body(x, bp):
        x = identity_barrier(x)
        aux = jnp.zeros((), jnp.float32)
        for i in range(g):  # unrolled group (g small)
            bpi = jax.tree.map(lambda t: t[i], bp) if g > 1 else bp
            x, _, a = _block_seq(cfg, bpi, x, window=0)
            aux = aux + a
        return x, aux / g

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    blocks = params["blocks"]
    if g > 1:
        blocks = jax.tree.map(
            lambda t: t.reshape(t.shape[0] // g, g, *t.shape[1:]), blocks)
    x, aux = jax.lax.scan(body, x, blocks)
    return x, jnp.mean(aux)


def forward_train(cfg: ArchConfig, params: Params, inputs: jax.Array,
                  remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V), moe aux loss)."""
    x = embed(cfg, params, inputs)

    def body(x, bp):
        # barrier pins the saved residual to the carry's own dtype (bf16) —
        # without it XLA hoists the norm's f32 convert into the residual
        # stack, doubling the remat-carry memory (see EXPERIMENTS.md §Perf)
        x = identity_barrier(x)
        x, _, a = _block_seq(cfg, bp, x, window=0)
        return x, a  # aux as a scan output keeps the carry bf16-only

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, aux = jax.lax.scan(body, x, params["blocks"])
    return unembed(cfg, params, x), jnp.mean(aux)


def prefill(cfg: ArchConfig, params: Params, inputs: jax.Array,
            cache_len: Optional[int] = None, window: int = 0
            ) -> Tuple[jax.Array, Params]:
    """Full-sequence pass that also materialises the decode cache."""
    x = embed(cfg, params, inputs)
    s = x.shape[1]
    cache_len = cache_len or s

    def body(x, bp):
        x, cache, _ = _block_seq(cfg, bp, x, window=window)
        cache = _pad_cache(cfg, cache, cache_len, s)
        return x, cache

    x, cache = jax.lax.scan(body, x, params["blocks"])
    logits = unembed(cfg, params, x[:, -1:])
    return logits, cache


def _pad_cache(cfg: ArchConfig, cache: Params, cache_len: int, s: int) -> Params:
    out = {}
    for k, v in cache.items():
        if k in ("k", "v", "c_kv", "k_rope") and v.ndim >= 3 and v.shape[1] == s:
            if cache_len > s:
                pad = [(0, 0)] * v.ndim
                pad[1] = (0, cache_len - s)
                v = jnp.pad(v, pad)
            elif cache_len < s:  # sliding window: keep the trailing window
                v = v[:, s - cache_len:]
        out[k] = v
    if cfg.kv_quant and cfg.attention == "gqa" and "k" in out:
        from repro.models.layers import _quantize_kv
        for name in ("k", "v"):
            q, sc = _quantize_kv(out[name])
            out[name], out[name + "_scale"] = q, sc
    return out


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jax.Array, pos: jax.Array, window: int = 0
                ) -> Tuple[jax.Array, Params]:
    """tokens: (B,1) int32 (all families embed decoded tokens); pos scalar.

    The cache rides in the scan CARRY and each layer's slice is updated with
    dynamic_update_index — one donated buffer updated in place (the DMO
    O_s=|out| case). Passing it as scan xs/ys instead makes XLA double-buffer
    the full (L,...) stacks (~2.5x cache in temps — measured in §Perf)."""
    x = embed(cfg, params, tokens)

    def body(carry, scan_in):
        x, cache = carry
        bp, l = scan_in
        c_l = jax.tree.map(
            lambda t: jax.lax.dynamic_index_in_dim(t, l, 0, keepdims=False),
            cache)
        x, new_l = _block_dec(cfg, bp, x, c_l, pos, window)
        cache = jax.tree.map(
            lambda t, n: jax.lax.dynamic_update_index_in_dim(
                t, n.astype(t.dtype), l, 0), cache, new_l)
        return (x, cache), None

    (x, new_cache), _ = jax.lax.scan(
        body, (x, cache),
        (params["blocks"], jnp.arange(cfg.num_layers)))
    return unembed(cfg, params, x), new_cache


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, cache_len: int) -> Params:
    """Zeroed decode cache (stacked over layers)."""
    dt = jnp.dtype(cfg.dtype)
    lyr, b, c = cfg.num_layers, batch, cache_len
    cache: Params = {}
    if cfg.attention in ("gqa", "hybrid"):
        kvshape = (lyr, b, c, cfg.num_kv_heads, cfg.head_dim)
        if cfg.kv_quant and cfg.attention == "gqa":
            cache["k"] = jnp.zeros(kvshape, jnp.int8)
            cache["v"] = jnp.zeros(kvshape, jnp.int8)
            cache["k_scale"] = jnp.zeros(kvshape[:-1], jnp.float32)
            cache["v_scale"] = jnp.zeros(kvshape[:-1], jnp.float32)
        else:
            cache["k"] = jnp.zeros(kvshape, dt)
            cache["v"] = jnp.zeros(kvshape, dt)
    if cfg.attention == "mla":
        cache["c_kv"] = jnp.zeros((lyr, b, c, cfg.kv_lora_rank), dt)
        cache["k_rope"] = jnp.zeros((lyr, b, c, cfg.rope_head_dim), dt)
    if cfg.attention == "none":
        h = S.rwkv_heads(cfg)
        cache["wkv"] = jnp.zeros((lyr, b, h, 64, 64), jnp.float32)
        cache["shift"] = jnp.zeros((lyr, b, cfg.d_model), dt)
        cache["cm_shift"] = jnp.zeros((lyr, b, cfg.d_model), dt)
    if cfg.attention == "hybrid":
        di = cfg.d_model * cfg.ssm_expand
        cache["ssm"] = jnp.zeros((lyr, b, di, cfg.ssm_state), jnp.float32)
        cache["conv"] = jnp.zeros((lyr, b, cfg.conv_kernel - 1, di), dt)
    return cache
