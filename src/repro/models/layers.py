"""Transformer building blocks: norms, RoPE, GQA/MLA attention, MLPs.

Pure functions over parameter dicts (pytrees). All attention math keeps a
float32 softmax; parameters live in ``cfg.dtype``.

Cache convention: decode caches are ring buffers of length ``cache_len``
(= full seq for decode_32k, = sliding window for long_500k); ``pos`` is the
number of tokens already consumed (scalar int32).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

Params = Dict[str, jax.Array]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 0.02,
               bias: bool = False) -> Params:
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rms_norm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Masked multi-head attention core
# ---------------------------------------------------------------------------


#: sequences longer than this use the blockwise online-softmax path
FLASH_THRESHOLD = 2048
FLASH_BLOCK = 1024


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
          mask: Optional[jax.Array]) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,T,KV,D) with H % KV == 0; mask (B,1,S,T) bool."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = q.reshape(b, s, kv, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, kf) / jnp.sqrt(d)
    if mask is not None:
        scores = jnp.where(mask[:, None, ...], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def _sdpa_blockwise(q: jax.Array, k: jax.Array, v: jax.Array,
                    offset: int, window: int,
                    block: int = FLASH_BLOCK) -> jax.Array:
    """Causal attention with online softmax over KV blocks (flash-style):
    never materialises the (S,T) score matrix. q:(B,S,H,D), k/v:(B,T,KV,D)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    nb = -(-t // block)
    tp = nb * block
    if tp != t:
        k = jnp.pad(k, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    qf = q.reshape(b, s, kvh, g, d).astype(jnp.float32) / jnp.sqrt(d)
    kb = k.reshape(b, nb, block, kvh, d)
    vb = v.reshape(b, nb, block, kvh, d)
    qpos = offset + jnp.arange(s)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, bi = xs
        kpos = bi * block + jnp.arange(block)
        sc = jnp.einsum("bskgd,btkd->bkgst", qf, kblk.astype(jnp.float32))
        msk = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] < t)
        if window:
            msk &= kpos[None, :] > qpos[:, None] - window
        sc = jnp.where(msk[None, None, None], sc, -jnp.inf)
        m_new = jnp.maximum(m, sc.max(-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(sc - m_safe[..., None])
        p = jnp.where(jnp.isfinite(sc), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, vblk.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, kvh, g, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, s, d), jnp.float32)
    kb_t = jnp.moveaxis(kb, 1, 0)
    vb_t = jnp.moveaxis(vb, 1, 0)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kb_t, vb_t, jnp.arange(nb)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1).reshape(b, s, h, d)
    return out.astype(q.dtype)


def causal_mask(s: int, t: int, offset: int, window: int = 0) -> jax.Array:
    """(1,1,S,T) bool: query i (global pos offset+i) may see key j<=pos,
    optionally within a trailing window."""
    qpos = offset + jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m[None, None]


# ---------------------------------------------------------------------------
# GQA attention (optional sliding window; optional QKV bias)
# ---------------------------------------------------------------------------


def attn_init(cfg: ArchConfig, key) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.q_dim, dt, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_dim, dt, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_dim, dt, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.q_dim, cfg.d_model, dt),
    }


def _qkv(p: Params, x: jax.Array, cfg: ArchConfig, positions) -> Tuple:
    b, s, _ = x.shape
    q = linear(p["wq"], x).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = linear(p["wk"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = linear(p["wv"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_forward(p: Params, x: jax.Array, cfg: ArchConfig,
                 window: int = 0) -> Tuple[jax.Array, Params]:
    """Training / prefill: full causal attention over x. Returns output and
    the KV cache {k, v} (B,S,KV,D). Long sequences take the blockwise
    online-softmax path (never materialising the S×S score matrix)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _qkv(p, x, cfg, positions)
    if s > FLASH_THRESHOLD:
        y = _sdpa_blockwise(q, k, v, offset=0, window=window)
    else:
        y = _sdpa(q, k, v, causal_mask(s, s, 0, window))
    y = linear(p["wo"], y.reshape(b, s, cfg.q_dim))
    return y, {"k": k, "v": v}


def _quantize_kv(t: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """t: (B,1,KV,D) -> int8 values + per-(B,1,KV) scale."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def attn_decode(p: Params, x: jax.Array, cache: Params, pos: jax.Array,
                cfg: ArchConfig, window: int = 0) -> Tuple[jax.Array, Params]:
    """One-token decode. cache: {k,v} (B,C,KV,D) ring buffer; pos = tokens
    already in cache — a scalar, or a (B,) vector for ragged batches
    (continuous-batching serving). When the cache is int8 (cfg.kv_quant)
    values carry per-(slot, kv-head) scales and are dequantised on read —
    halving decode's dominant HBM term. Returns output (B,1,d), new cache."""
    b = x.shape[0]
    cache_len = cache["k"].shape[1]
    posv = jnp.broadcast_to(jnp.asarray(pos), (b,))
    slot = posv % cache_len                                   # (B,)
    q, k, v = _qkv(p, x, cfg, posv[:, None])
    bi = jnp.arange(b)
    quant = cache["k"].dtype == jnp.int8
    if quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        cache = dict(cache)
        cache["k_scale"] = cache["k_scale"].at[bi, slot].set(ks[:, 0])
        cache["v_scale"] = cache["v_scale"].at[bi, slot].set(vs[:, 0])
        k, v = kq, vq
    ck = cache["k"].at[bi, slot].set(k[:, 0])
    cv = cache["v"].at[bi, slot].set(v[:, 0])
    if quant:
        new_cache = {"k": ck, "v": cv, "k_scale": cache["k_scale"],
                     "v_scale": cache["v_scale"]}
        ck = ck.astype(jnp.float32) * cache["k_scale"][..., None]
        cv = cv.astype(jnp.float32) * cache["v_scale"][..., None]
    # validity: ring slots holding tokens (pos-window, pos], per request
    idx = jnp.arange(cache_len)
    age = (slot[:, None] - idx[None, :]) % cache_len          # (B,C), 0=newest
    valid = age < jnp.minimum(posv + 1, cache_len)[:, None]
    if window:
        valid &= age < window
    mask = valid[:, None, None, :]
    y = _sdpa(q, ck, cv, jnp.broadcast_to(mask, (b, 1, 1, cache_len)))
    y = linear(p["wo"], y.reshape(b, 1, cfg.q_dim))
    return y, (new_cache if quant else {"k": ck, "v": cv})


# ---------------------------------------------------------------------------
# MLA attention (MiniCPM3 / DeepSeek-style latent KV compression)
# ---------------------------------------------------------------------------


def mla_init(cfg: ArchConfig, key) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 7)
    h, dn, dr, dv = (cfg.num_heads, cfg.head_dim, cfg.rope_head_dim,
                     cfg.v_head_dim or cfg.head_dim)
    return {
        "wq_a": dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, dt),
        "q_norm": rms_norm_init(cfg.q_lora_rank, dt),
        "wq_b": dense_init(ks[1], cfg.q_lora_rank, h * (dn + dr), dt),
        "wkv_a": dense_init(ks[2], cfg.d_model, cfg.kv_lora_rank + dr, dt),
        "kv_norm": rms_norm_init(cfg.kv_lora_rank, dt),
        "wk_b": dense_init(ks[3], cfg.kv_lora_rank, h * dn, dt),
        "wv_b": dense_init(ks[4], cfg.kv_lora_rank, h * dv, dt),
        "wo": dense_init(ks[5], h * dv, cfg.d_model, dt),
    }


def _mla_q(p: Params, x: jax.Array, cfg: ArchConfig, positions):
    b, s, _ = x.shape
    h, dn, dr = cfg.num_heads, cfg.head_dim, cfg.rope_head_dim
    q = linear(p["wq_b"], rms_norm(p["q_norm"], linear(p["wq_a"], x)))
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p: Params, x: jax.Array, cfg: ArchConfig, positions):
    dr = cfg.rope_head_dim
    kv = linear(p["wkv_a"], x)
    c_kv = rms_norm(p["kv_norm"], kv[..., :cfg.kv_lora_rank])
    k_rope = apply_rope(kv[..., None, cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)[..., 0, :]
    del dr
    return c_kv, k_rope


def mla_forward(p: Params, x: jax.Array, cfg: ArchConfig,
                window: int = 0) -> Tuple[jax.Array, Params]:
    b, s, _ = x.shape
    h, dn, dv = cfg.num_heads, cfg.head_dim, cfg.v_head_dim or cfg.head_dim
    positions = jnp.arange(s)[None, :]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_latent(p, x, cfg, positions)
    k_nope = linear(p["wk_b"], c_kv).reshape(b, s, h, dn)
    v = linear(p["wv_b"], c_kv).reshape(b, s, h, dv)
    # fold the shared rope sub-dim into per-head keys so both score terms run
    # through one (possibly blockwise) SDPA
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  (b, s, h, cfg.rope_head_dim))], axis=-1)
    if dv < dn + cfg.rope_head_dim:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                        (0, dn + cfg.rope_head_dim - dv)))
    if s > FLASH_THRESHOLD:
        y = _sdpa_blockwise(q_full, k_full, v, offset=0, window=window)
    else:
        y = _sdpa(q_full, k_full, v, causal_mask(s, s, 0, window))
    y = y[..., :dv]
    y = linear(p["wo"], y.reshape(b, s, h * dv))
    return y, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(p: Params, x: jax.Array, cache: Params, pos: jax.Array,
               cfg: ArchConfig, window: int = 0) -> Tuple[jax.Array, Params]:
    """Absorbed-form MLA decode: attention runs in the compressed latent
    space (the cache stores c_kv + k_rope only — the technique's memory win)."""
    b = x.shape[0]
    h, dn, dv = cfg.num_heads, cfg.head_dim, cfg.v_head_dim or cfg.head_dim
    r = cfg.kv_lora_rank
    cache_len = cache["c_kv"].shape[1]
    posv = jnp.broadcast_to(jnp.asarray(pos), (b,))
    slot = posv % cache_len
    positions = posv[:, None]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_latent(p, x, cfg, positions)
    bi = jnp.arange(b)
    cc = cache["c_kv"].at[bi, slot].set(c_kv[:, 0])
    cr = cache["k_rope"].at[bi, slot].set(k_rope[:, 0])
    # absorb W_uk into q: (B,1,H,dn) @ (r,H,dn) -> (B,H,r)
    wk_b = p["wk_b"]["w"].reshape(r, h, dn)
    q_lat = jnp.einsum("bshd,rhd->bhr", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    scores = (jnp.einsum("bhr,btr->bht", q_lat,
                         cc.astype(jnp.float32))
              + jnp.einsum("bshd,btd->bht", q_rope.astype(jnp.float32),
                           cr.astype(jnp.float32)))
    scores = scores / jnp.sqrt(dn + cfg.rope_head_dim)
    idx = jnp.arange(cache_len)
    age = (slot[:, None] - idx[None, :]) % cache_len
    valid = age < jnp.minimum(posv + 1, cache_len)[:, None]
    if window:
        valid &= age < window
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    lat = jnp.einsum("bht,btr->bhr", w, cc.astype(jnp.float32))
    wv_b = p["wv_b"]["w"].reshape(r, h, dv)
    y = jnp.einsum("bhr,rhd->bhd", lat, wv_b.astype(jnp.float32))
    y = y.reshape(b, 1, h * dv).astype(x.dtype)
    return linear(p["wo"], y), {"c_kv": cc, "k_rope": cr}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(cfg: ArchConfig, key, d_ff: Optional[int] = None) -> Params:
    dt = _dtype(cfg)
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], cfg.d_model, ff, dt),
        "w_down": dense_init(ks[1], ff, cfg.d_model, dt),
    }
    if cfg.activation == "silu":  # gated (SwiGLU)
        p["w_gate"] = dense_init(ks[2], cfg.d_model, ff, dt)
    return p


def mlp(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    up = linear(p["w_up"], x)
    if cfg.activation == "silu":
        h = jax.nn.silu(linear(p["w_gate"], x)) * up
    elif cfg.activation == "sq_relu":
        r = jax.nn.relu(up)
        h = r * r
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(cfg.activation)
    return linear(p["w_down"], h)
