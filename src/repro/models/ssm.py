"""Attention-free sequence mixers: RWKV6 (Finch) and a Mamba-style selective
SSM (used by the Hymba hybrid blocks).

Both are written as (a) a full-sequence form built on ``jax.lax.scan`` over
time for train/prefill, and (b) an O(1)-state single-step form for decode.
State pytrees are the DMO ``O_s = |out|`` case: they are donated and updated
in place by the serving engine.

RWKV6 recurrence (per head, D = head dim, state S in R^{D x D}):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with data-dependent decay w_t = exp(-exp(decay(x_t))) — the Finch change.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import dense_init, linear, rms_norm, rms_norm_init

Params = Dict[str, jax.Array]

_RWKV_HEAD = 64


def rwkv_heads(cfg: ArchConfig) -> int:
    return cfg.d_model // _RWKV_HEAD


# ---------------------------------------------------------------------------
# RWKV6 time mixing
# ---------------------------------------------------------------------------


def rwkv_init(cfg: ArchConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "mu": jax.random.uniform(ks[0], (5, d), dt),  # token-shift mixes r,k,v,w,g
        "wr": dense_init(ks[1], d, d, dt),
        "wk": dense_init(ks[2], d, d, dt),
        "wv": dense_init(ks[3], d, d, dt),
        "wd": dense_init(ks[4], d, d, dt, scale=0.002),   # data-dependent decay
        "wg": dense_init(ks[5], d, d, dt),
        "wo": dense_init(ks[6], d, d, dt),
        "u": jnp.zeros((d,), dt),                          # bonus (per channel)
    }


def _rwkv_proj(p: Params, x: jax.Array, x_prev: jax.Array):
    """Token-shift interpolation then the five projections.
    x, x_prev: (B,S,d) where x_prev is x shifted right by one."""
    mix = lambda i: x * p["mu"][i] + x_prev * (1 - p["mu"][i])
    r = linear(p["wr"], mix(0))
    k = linear(p["wk"], mix(1))
    v = linear(p["wv"], mix(2))
    w = jnp.exp(-jnp.exp(linear(p["wd"], mix(3)).astype(jnp.float32)))
    g = jax.nn.silu(linear(p["wg"], mix(4)))
    return r, k, v, w, g


def _heads(x: jax.Array, h: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], h, _RWKV_HEAD)


def _rwkv_step(state, rkvw, u):
    """state: (B,H,D,D). r,k,v: (B,H,D); w: (B,H,D) decay in [0,1]."""
    r, k, v, w = rkvw
    kv = k[..., :, None] * v[..., None, :]                    # (B,H,D,D)
    out = jnp.einsum("bhd,bhde->bhe", r, state + u[..., :, None] * kv)
    state = w[..., :, None] * state + kv
    return state, out


#: sequence length from which the chunked (vectorised) WKV form is used.
#: The per-step scan round-trips the (B,H,D,D) state through HBM every
#: token; the chunked closed form turns S steps into S/Q einsum chunks
#: (§Perf hillclimb 1). 0 < CHUNK keeps both paths testable.
WKV_CHUNK = 64


def rwkv_forward(p: Params, x: jax.Array, cfg: ArchConfig,
                 chunked: bool = True) -> Tuple[jax.Array, Params]:
    """Full-sequence RWKV6 time mixing. Returns (y, final state)."""
    b, s, d = x.shape
    h = rwkv_heads(cfg)
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, w, g = _rwkv_proj(p, x, x_prev)
    rh, kh, vh = (_heads(t, h).astype(jnp.float32) for t in (r, k, v))
    wh = _heads(w, h)
    u = _heads(p["u"].astype(jnp.float32)[None], h)[0]        # (H,D)
    state0 = jnp.zeros((b, h, _RWKV_HEAD, _RWKV_HEAD), jnp.float32)

    if chunked and s % WKV_CHUNK == 0 and s > WKV_CHUNK:
        state, y = _wkv_chunked(rh, kh, vh, wh, u, state0, WKV_CHUNK)
    else:
        def step(carry, t):
            st, out = _rwkv_step(carry, t, u)
            return st, out

        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rh, kh, vh, wh))
        state, outs = jax.lax.scan(step, state0, xs)
        y = jnp.moveaxis(outs, 0, 1)
    y = y.reshape(b, s, d).astype(x.dtype)
    y = y * g
    y = linear(p["wo"], y)
    return y, {"wkv": state, "shift": x[:, -1]}


def _wkv_chunked(r, k, v, w, u, state0, q):
    """Chunked WKV: within a Q-chunk the recurrence has the closed form

        o_t = (r_t ⊙ W_{t-1}) S_0 + Σ_{j<t} (r_t·(k_j ⊙ W_{t-1}/W_j)) v_j
              + (r_t·(u ⊙ k_t)) v_t
        S'  = diag(W_{Q-1}) S_0 + Σ_j (k_j ⊙ W_{Q-1}/W_j)^T v_j

    with W_t = Π_{i<=t} w_i (per channel). All decay ratios have non-positive
    log, so the pairwise exp tensor is built in log space and never
    overflows. One lax.scan over chunks carries S (the only sequential HBM
    state), everything inside a chunk is einsum-parallel.
    r,k,v,w: (B,S,H,D) f32/(0,1); state0: (B,H,D,D). Returns (S', y (B,S,H,D))."""
    b, s, h, d = r.shape
    nc = s // q
    resh = lambda t: jnp.moveaxis(t.reshape(b, nc, q, h, d), 1, 0)
    rc, kc, vc = resh(r), resh(k), resh(v)          # (N,B,Q,H,D)
    lw = jnp.cumsum(jnp.log(jnp.maximum(resh(w), 1e-38)), axis=2)
    lw_prev = jnp.pad(lw, ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))[:, :, :-1]

    tq = jnp.arange(q)
    mask_lt = (tq[:, None] > tq[None, :])[None, :, :, None, None]  # j < t
    eye = jnp.eye(q)

    def chunk_step(st, xs):
        r_n, k_n, v_n, lw_n, lwp_n = xs             # (B,Q,H,D) each
        # pairwise intra-chunk decays exp(lw_prev[t]-lw[j]), j<t: (B,Q,Q,H,D)
        lr = lwp_n[:, :, None] - lw_n[:, None, :]
        dec = jnp.exp(jnp.where(mask_lt, lr, -jnp.inf))
        att = jnp.einsum("btjhd,bthd,bjhd->bthj", dec, r_n, k_n)
        diag = jnp.einsum("bthd,hd,bthd->bth", r_n, u, k_n)
        att = att + diag[..., None] * eye[None, :, None, :]  # (B,t,H,j)
        y_n = jnp.einsum("bthj,bjhd->bthd", att, v_n)
        # cross-chunk contribution from the carried state
        y_n = y_n + jnp.einsum("bthd,bhde->bthe", r_n * jnp.exp(lwp_n), st)
        # state update: S' = diag(W_{Q-1}) S + Σ_j (k_j W_{Q-1}/W_j)^T v_j
        k_dec = k_n * jnp.exp(lw_n[:, -1:] - lw_n)
        st = (jnp.exp(lw_n[:, -1])[..., None] * st
              + jnp.einsum("bjhd,bjhe->bhde", k_dec, v_n))
        return st, y_n

    state, y = jax.lax.scan(chunk_step, state0, (rc, kc, vc, lw, lw_prev))
    y = jnp.moveaxis(y, 0, 1).reshape(b, nc * q, h, d)
    return state, y.reshape(b, s, h, d)


def rwkv_decode(p: Params, x: jax.Array, state: Params, cfg: ArchConfig
                ) -> Tuple[jax.Array, Params]:
    """One-token step. state = {wkv: (B,H,D,D) f32, shift: (B,d)}."""
    b, _, d = x.shape
    h = rwkv_heads(cfg)
    x1 = x[:, 0]
    r, k, v, w, g = _rwkv_proj(p, x1[:, None], state["shift"][:, None])
    rh, kh, vh = (_heads(t[:, 0], h).astype(jnp.float32) for t in (r, k, v))
    wh = _heads(w[:, 0], h)
    u = _heads(p["u"].astype(jnp.float32)[None], h)[0]
    st, out = _rwkv_step(state["wkv"], (rh, kh, vh, wh), u)
    y = out.reshape(b, 1, d).astype(x.dtype) * g
    return linear(p["wo"], y), {"wkv": st, "shift": x1}


def rwkv_channel_mix_init(cfg: ArchConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {
        "mu": jax.random.uniform(ks[0], (2, cfg.d_model), dt),
        "wk": dense_init(ks[1], cfg.d_model, cfg.d_ff, dt),
        "wv": dense_init(ks[2], cfg.d_ff, cfg.d_model, dt),
    }


def rwkv_channel_mix(p: Params, x: jax.Array, x_prev: jax.Array) -> jax.Array:
    k = linear(p["wk"], x * p["mu"][0] + x_prev * (1 - p["mu"][0]))
    k = jnp.square(jax.nn.relu(k))
    return linear(p["wv"], k)


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (Hymba's parallel SSM heads)
# ---------------------------------------------------------------------------


def mamba_init(cfg: ArchConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    di = d * cfg.ssm_expand
    n = cfg.ssm_state
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], d, 2 * di, dt),
        "conv": jax.random.normal(ks[1], (cfg.conv_kernel, di), dt) * 0.02,
        "w_bc": dense_init(ks[2], di, 2 * n, dt),
        "w_dt": dense_init(ks[3], di, di, dt, scale=0.002),
        "a_log": jnp.zeros((di, n), jnp.float32),
        "d_skip": jnp.ones((di,), dt),
        "w_out": dense_init(ks[4], di, d, dt),
    }


def _mamba_scan_inputs(p: Params, xz: jax.Array, conv_state: jax.Array):
    """xz: (B,S,2*di) already projected. Returns gate z and per-step (x, dt,
    B, C) plus the new conv ring state (last K-1 pre-conv activations)."""
    di = p["conv"].shape[1]
    kk = p["conv"].shape[0]
    x, z = xz[..., :di], xz[..., di:]
    hist = jnp.concatenate([conv_state, x], axis=1)           # (B,K-1+S,di)
    conv = sum(hist[:, i:i + x.shape[1]] * p["conv"][i] for i in range(kk))
    conv = jax.nn.silu(conv)
    dt = jax.nn.softplus(linear(p["w_dt"], conv).astype(jnp.float32))
    bc = linear(p["w_bc"], conv)
    n = bc.shape[-1] // 2
    bmat, cmat = bc[..., :n], bc[..., n:]
    new_conv_state = hist[:, hist.shape[1] - (kk - 1):]
    return z, conv, dt, bmat, cmat, new_conv_state


def _mamba_step(state, inp, a):
    """state: (B,di,N); x,dt: (B,di); b,c: (B,N)."""
    x, dt, bmat, cmat = inp
    da = jnp.exp(dt[..., None] * a[None])                     # (B,di,N)
    state = state * da + (dt * x)[..., None] * bmat[:, None, :].astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", state, cmat.astype(jnp.float32))
    return state, y


def mamba_forward(p: Params, x: jax.Array, cfg: ArchConfig
                  ) -> Tuple[jax.Array, Params]:
    b, s, d = x.shape
    di = d * cfg.ssm_expand
    kk = cfg.conv_kernel
    xz = linear(p["w_in"], x)
    conv0 = jnp.zeros((b, kk - 1, di), x.dtype)
    z, conv, dt, bmat, cmat, conv_state = _mamba_scan_inputs(p, xz, conv0)
    a = -jnp.exp(p["a_log"])                                  # (di,N)
    state0 = jnp.zeros((b, di, cfg.ssm_state), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in
               (conv.astype(jnp.float32), dt, bmat, cmat))
    state, ys = jax.lax.scan(lambda c, t: _mamba_step(c, t, a), state0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    y = (y + conv * p["d_skip"]) * jax.nn.silu(z)
    return linear(p["w_out"], y), {"ssm": state, "conv": conv_state}


def mamba_decode(p: Params, x: jax.Array, state: Params, cfg: ArchConfig
                 ) -> Tuple[jax.Array, Params]:
    b, _, d = x.shape
    xz = linear(p["w_in"], x)                                  # (B,1,2di)
    z, conv, dt, bmat, cmat, conv_state = _mamba_scan_inputs(
        p, xz, state["conv"])
    a = -jnp.exp(p["a_log"])
    st, y = _mamba_step(state["ssm"],
                        (conv[:, 0].astype(jnp.float32), dt[:, 0],
                         bmat[:, 0], cmat[:, 0]), a)
    y = y[:, None].astype(x.dtype)
    y = (y + conv * p["d_skip"]) * jax.nn.silu(z)
    return linear(p["w_out"], y), {"ssm": st, "conv": conv_state}
