"""repro.models subpackage."""
