"""Mixture-of-Experts layer: top-k router + capacity-based dispatch.

Dispatch strategy (GShard/Switch-style, FLOPs-honest): each token copy is
assigned a slot in its expert's capacity buffer via a cumulative-sum
position; copies beyond capacity are dropped (capacity factor 1.25 by
default, so drops are rare at balanced load). Expert FFNs are computed as a
single 3-way einsum over the (E, C, d) buffer, so the expert dimension
shards cleanly over the ``model`` mesh axis (expert parallelism) and the
compiled FLOPs are ≈ capacity_factor × the active-parameter FLOPs.

Router auxiliary load-balancing loss follows Switch Transformer (importance
× load), returned alongside the output for the training loss.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import dense_init

Params = Dict[str, jax.Array]


def moe_init(cfg: ArchConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    s = 0.02
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": jax.random.normal(ks[1], (e, d, f), dt) * s,
        "w_up": jax.random.normal(ks[2], (e, d, f), dt) * s,
        "w_down": jax.random.normal(ks[3], (e, f, d), dt) * s,
    }


def _capacity(tokens: int, cfg: ArchConfig) -> int:
    c = int(tokens * cfg.experts_per_token * cfg.capacity_factor
            / cfg.num_experts)
    return max(cfg.experts_per_token, c)


def moe_ffn(p: Params, x: jax.Array, cfg: ArchConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (B,S,d), aux load-balance loss (scalar f32).

    Under an installed mesh AxisEnv this takes the expert-parallel
    ``shard_map`` path (GShard groups = data shards, experts local to model
    shards — no cross-shard scatter); without a mesh it runs the plain
    single-device dispatch below."""
    from repro.sharding import current_env
    env = current_env()
    if env is not None:
        return _moe_ffn_shardmap(p, x, cfg, env)
    return _moe_ffn_local(p, x, cfg)


def _moe_ffn_local(p: Params, x: jax.Array, cfg: ArchConfig
                   ) -> Tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf @ p["router"]["w"].astype(xf.dtype)).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, k)                        # (T,k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * mean(importance) . mean(load)
    importance = probs.mean(0)                                      # (E,)
    load = jnp.zeros((e,), jnp.float32).at[gate_i.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(importance * load)

    # slot assignment: position of each copy within its expert, by cumsum
    flat_e = gate_i.reshape(t * k)                                  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)             # (T*k,E)
    pos = (jnp.cumsum(onehot, axis=0) - onehot) * onehot
    pos = pos.sum(-1)                                               # (T*k,)
    cap = _capacity(t, cfg)
    keep = pos < cap
    dest = jnp.where(keep, flat_e * cap + pos, e * cap)             # drop->OOB

    # dispatch: (E*C, d) buffer of token copies (pad row at the end)
    token_row = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].set(
        xf[token_row], mode="drop")
    expert_in = buf[:e * cap].reshape(e, cap, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    y_exp = jnp.einsum("ecf,efd->ecd", h, p["w_down"])               # (E,C,d)

    # combine: gather each copy's expert output, weight, sum per token
    y_flat = y_exp.reshape(e * cap, d)
    y_copy = jnp.where(keep[:, None],
                       y_flat[jnp.minimum(dest, e * cap - 1)], 0.0)
    w_copy = (gate_w.reshape(t * k) * keep).astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[token_row].add(
        y_copy * w_copy[:, None])
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel shard_map path
# ---------------------------------------------------------------------------


def _moe_ffn_shardmap(p: Params, x: jax.Array, cfg: ArchConfig, env
                      ) -> Tuple[jax.Array, jax.Array]:
    """Each (pod, data) shard routes its local tokens; each model shard
    computes only its local experts and contributes a partial output that is
    psum'ed over the model axis. FSDP-sharded expert weights are explicitly
    all-gathered over the data axis per layer (standard FSDP schedule)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    e, k = cfg.num_experts, cfg.experts_per_token
    ba = env.batch
    model = env.model
    e_loc = e // env.mesh.shape[model]
    n_data = 1
    for a in ba:
        n_data *= env.mesh.shape[a]
    if x.shape[0] % n_data:       # e.g. batch-1 long-context decode:
        ba = ()                   # replicate tokens over the data axis
    bspec = (ba if len(ba) > 1 else ba[0]) if ba else None
    fsdp_ax = "data" if env.fsdp else None
    wcol = P(model, None, fsdp_ax)      # (E,d,f) sharded
    wrow = P(model, fsdp_ax, None)      # (E,f,d) sharded

    def local_fn(router_w, wg, wu, wd, xl):
        bl, sl, d = xl.shape
        t = bl * sl
        xf = xl.reshape(t, d)
        if env.fsdp:  # gather the FSDP-split expert dims
            wg = _ag_last(wg, "data")
            wu = _ag_last(wu, "data")
            wd = jnp.moveaxis(_ag_last(jnp.moveaxis(wd, 1, 2), "data"), 2, 1)
        # matmul in activation dtype, f32 afterwards: keeps the remat
        # residual of this shard_map in bf16 (an f32 (T,d) cast here would
        # be saved per layer and double the carry stack — §Perf)
        logits = (xf @ router_w.astype(xf.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_i = jax.lax.top_k(probs, k)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

        importance = probs.mean(0)
        load = jnp.zeros((e,), jnp.float32).at[gate_i.reshape(-1)].add(
            1.0) / (t * k)
        aux = e * jnp.sum(importance * load)
        for ax in ba:
            aux = jax.lax.pmean(aux, ax)

        cap = _capacity(t, cfg)
        flat_e = gate_i.reshape(t * k)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = ((jnp.cumsum(onehot, axis=0) - onehot) * onehot).sum(-1)
        keep = pos < cap
        e0 = jax.lax.axis_index(model) * e_loc
        local = (flat_e >= e0) & (flat_e < e0 + e_loc) & keep
        dest = jnp.where(local, (flat_e - e0) * cap + pos, e_loc * cap)

        token_row = jnp.repeat(jnp.arange(t), k)
        buf = jnp.zeros((e_loc * cap + 1, d), xl.dtype).at[dest].set(
            xf[token_row], mode="drop")
        expert_in = buf[:e_loc * cap].reshape(e_loc, cap, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, wg))
        h = h * jnp.einsum("ecd,edf->ecf", expert_in, wu)
        y_exp = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e_loc * cap, d)
        y_copy = jnp.where(local[:, None],
                           y_exp[jnp.minimum(dest, e_loc * cap - 1)], 0.0)
        w_copy = (gate_w.reshape(t * k) * local).astype(xl.dtype)
        part = jnp.zeros((t, d), xl.dtype).at[token_row].add(
            y_copy * w_copy[:, None])
        out = jax.lax.psum(part, model)
        return out.reshape(bl, sl, d), aux

    fn = shard_map(
        local_fn, mesh=env.mesh,
        in_specs=(P(None, None), wcol, wcol, wrow, P(bspec, None, None)),
        out_specs=(P(bspec, None, None), P()),
        check_rep=False)
    # shard_map's linearisation residuals (f32 router probs, dispatch
    # buffers) leak through an OUTER jax.checkpoint — an inner remat pins
    # the saved state to this call's bf16 inputs only (§Perf hillclimb 2)
    fn = jax.checkpoint(fn)
    return fn(p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"], x)


def _ag_last(w: jax.Array, axis: str) -> jax.Array:
    """all-gather (concatenate) the last dim — the FSDP weight gather."""
    return jax.lax.all_gather(w, axis, axis=w.ndim - 1, tiled=True)
