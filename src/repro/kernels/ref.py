"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dwconv2d(x: jax.Array, w: jax.Array, stride: int = 1,
             pad: int = 0) -> jax.Array:
    """x: (IH, IW, C); w: (KH, KW, C) -> (OH, OW, C)."""
    ih, iw, c = x.shape
    kh, kw, _ = w.shape
    oh = (ih + 2 * pad - kh) // stride + 1
    ow = (iw + 2 * pad - kw) // stride + 1
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    out = jnp.zeros((oh, ow, c), jnp.float32)
    for fy in range(kh):
        for fx in range(kw):
            sl = xp[fy:fy + oh * stride:stride, fx:fx + ow * stride:stride]
            out = out + sl.astype(jnp.float32) * w[fy, fx][None, None, :]
    return out.astype(x.dtype)


def rmsnorm_scale_residual(x: jax.Array, g: jax.Array, r: jax.Array,
                           eps: float = 1e-6) -> jax.Array:
    """out = r + rmsnorm(x) * g (rows along leading dims)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (r.astype(jnp.float32) + y * g.astype(jnp.float32)).astype(x.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = True) -> jax.Array:
    """q,k,v: (S, H, D) / (T, H, D) single batch; full softmax oracle."""
    s, h, d = q.shape
    t = k.shape[0]
    sc = jnp.einsum("shd,thd->hst", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / jnp.sqrt(d)
    if causal:
        mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None] + (t - s)
        sc = jnp.where(mask[None], sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("hst,thd->shd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
