"""In-place fused residual-add + RMSNorm Pallas kernel.

The paper's ideal diagonal case (Fig. 3a): elementwise(-per-row) ops have
``O_s = |out|`` — input and output fully share storage. Realised here with
``input_output_aliases={0: 0}``: the residual stream buffer is updated in
place, one (block, d) VMEM tile per grid step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel(x_ref, g_ref, r_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = r + x * jax.lax.rsqrt(ms + eps) * g_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_scale_residual_inplace(x: jax.Array, g: jax.Array, r: jax.Array,
                                   eps: float = 1e-6, block: int = 128,
                                   interpret: Optional[bool] = None
                                   ) -> jax.Array:
    """x, r: (N, d); g: (d,). Output aliases x. ``interpret=None`` defers to
    the shared ``REPRO_DMO_INTERPRET`` switch."""
    interpret = resolve_interpret(interpret)
    n, d = x.shape
    b = min(block, n)
    while n % b:
        b -= 1
    grid = (n // b,)
    fn = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((b, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, d), lambda i: (i, 0)),
        input_output_aliases={0: 0},
        interpret=interpret,
    )
    return fn(x, g, r)
