"""One switch for Pallas interpret-vs-compiled execution.

Every Pallas kernel in the repo used to hardcode ``interpret: bool = True``
(the CPU-CI-safe default) with no way to flip the whole stack onto compiled
TPU lowering. :func:`default_interpret` is that shared switch: kernels take
``interpret: Optional[bool] = None`` and resolve ``None`` here, so one env
var retargets the executor backend and every standalone kernel together::

    REPRO_DMO_INTERPRET=0  # compiled lowering (requires a real TPU/GPU)
    REPRO_DMO_INTERPRET=1  # force interpret mode (the default)

Unset, the default stays interpret mode — correct on CPU CI, and the safe
choice anywhere a Mosaic lowering is unavailable.
"""
from __future__ import annotations

import os
from typing import Optional

_FALSY = ("0", "false", "no", "off", "compiled")


def default_interpret() -> bool:
    """The stack-wide interpret default: ``REPRO_DMO_INTERPRET`` when set
    (``0``/``false``/``off``/``compiled`` select compiled lowering),
    else True."""
    v = os.environ.get("REPRO_DMO_INTERPRET")
    if v is None or not v.strip():
        return True
    return v.strip().lower() not in _FALSY


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Per-call override (explicit bool) or the shared default (None)."""
    return default_interpret() if interpret is None else bool(interpret)
