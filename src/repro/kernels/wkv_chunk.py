"""Fused chunked-WKV (RWKV6) Pallas kernel — HC1's "next lever".

The jaxpr-level chunked form (repro.models.ssm._wkv_chunked) already removed
the per-token HBM round-trip, but its per-chunk (Q,Q,H,D) decay tensor and
(Q,Q) attention-like intermediates still live in HBM between einsums. This
kernel fuses the whole time dimension of one (batch, head) pair into a
single program: the recurrent state, the chunk tiles and every pairwise
intermediate stay in VMEM; HBM traffic is exactly one read of r/k/v/log-w
and one write of y — the roofline floor for this op.

Grid: (B, H) — programs are independent (state is per-head), so the grid
axes are genuinely parallel (no diagonal hazard here: each program owns its
output rows exclusively; contrast with the DMO arena kernel where grid
order IS the safety argument).

Validated in interpret mode against both the sequential scan and the
chunked jaxpr implementation (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, sT_ref, *,
            s: int, d: int, q: int):
    """refs: (1, S, D) per (b,h) program; u (1, D); y (1, S, D);
    sT (1, D, D) final state."""
    nc = s // q
    u = u_ref[0]                                           # (D,)
    tq = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    mask_lt = tq > jq                                      # j < t
    eye = (tq == jq).astype(jnp.float32)

    def chunk(ci, state):
        r = r_ref[0, pl.dslice(ci * q, q), :].astype(jnp.float32)   # (Q,D)
        k = k_ref[0, pl.dslice(ci * q, q), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(ci * q, q), :].astype(jnp.float32)
        lw = lw_ref[0, pl.dslice(ci * q, q), :].astype(jnp.float32)
        lwc = jnp.cumsum(lw, axis=0)                       # (Q,D) within chunk
        lwp = jnp.concatenate([jnp.zeros((1, d), jnp.float32),
                               lwc[:-1]], axis=0)
        # pairwise decay exp(lwp[t] - lwc[j]) for j < t, else 0
        lr = lwp[:, None, :] - lwc[None, :, :]             # (Q,Q,D)
        dec = jnp.where(mask_lt[..., None], jnp.exp(lr), 0.0)
        att = jnp.einsum("tjd,td,jd->tj", dec, r, k)
        att = att + eye * jnp.einsum("td,d,td->t", r, u, k)[:, None]
        y = att @ v                                        # (Q,D)
        y = y + (r * jnp.exp(lwp)) @ state                 # cross-chunk
        y_ref[0, pl.dslice(ci * q, q), :] = y.astype(y_ref.dtype)
        k_dec = k * jnp.exp(lwc[-1:] - lwc)
        state = jnp.exp(lwc[-1])[:, None] * state + k_dec.T @ v
        return state

    state = jax.lax.fori_loop(0, nc, chunk,
                              jnp.zeros((d, d), jnp.float32))
    sT_ref[0] = state


def wkv_chunk_kernel(r: jax.Array, k: jax.Array, v: jax.Array,
                     logw: jax.Array, u: jax.Array, q: int = 64,
                     interpret: Optional[bool] = None):
    """r,k,v,logw: (B,S,H,D) (logw = log decay, <= 0); u: (H,D).
    Returns (y (B,S,H,D) f32, final state (B,H,D,D) f32).
    ``interpret=None`` defers to the shared ``REPRO_DMO_INTERPRET``
    switch."""
    interpret = resolve_interpret(interpret)
    b, s, h, d = r.shape
    assert s % q == 0
    tr = lambda t: jnp.moveaxis(t, 2, 1).reshape(b * h, s, d)
    rr, kk, vv, ll = tr(r), tr(k), tr(v), tr(logw)
    uu = jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, d)
    fn = pl.pallas_call(
        functools.partial(_kernel, s=s, d=d, q=q),
        out_shape=(jax.ShapeDtypeStruct((b * h, s, d), jnp.float32),
                   jax.ShapeDtypeStruct((b * h, d, d), jnp.float32)),
        grid=(b * h,),
        in_specs=[pl.BlockSpec((1, s, d), lambda i: (i, 0, 0))] * 4
        + [pl.BlockSpec((1, d), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, d, d), lambda i: (i, 0, 0))),
        interpret=interpret,
    )
    y, st = fn(rr.astype(jnp.float32), kk.astype(jnp.float32),
               vv.astype(jnp.float32), ll.astype(jnp.float32), uu)
    y = jnp.moveaxis(y.reshape(b, h, s, d), 1, 2)
    return y, st.reshape(b, h, d, d)
