"""Diagonal-memory-optimised depthwise conv2d over a row-blocked VMEM arena.

The paper overlaps an op's input and output buffers inside the MCU's SRAM
arena. The TPU analogue of that SRAM is **VMEM**: ONE ``(rows, rowlen)``
arena stays resident, with the input tensor placed ``d_rows`` rows above the
output region — ``d_rows`` is derived from the *analytic* safe overlap
``O_s`` (repro.core.overlap.analytic), rounded up to row granularity (the
"block-granular O_s"). The kernel walks output rows in ascending order in a
sequential ``fori_loop``; a parallel grid over rows would break the diagonal
guarantee, exactly the paper's multi-threading caveat (§III.F).

Because reads for output row ``i`` come from input rows ``i*stride + d``
onward and the write goes to row ``i``, with ``d`` chosen from ``O_s``, no
live input value is ever clobbered — so the op needs
``max(rows_in + d, rows_out)`` arena rows instead of ``rows_in + rows_out``.

This was the prototype the generalised row-blocked arena program grew from;
it is now a thin wrapper over :mod:`repro.kernels.arena_ops` — a single
blocked ``OpSpec`` (row offsets ``d_rows``/``0``, ``input_output_aliases=
{0: 0}``) driving the shared depthwise kernel, the same code path
:func:`repro.core.planner.legalise_for_blocks` layouts execute through.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.arena_ops import OpSpec, apply_op
from repro.kernels.runtime import resolve_interpret


def dmo_dwconv2d_arena(arena: jax.Array, w: jax.Array, *, ih: int, iw: int,
                       c: int, stride: int, pad: int, d_rows: int,
                       oh: int, ow: int,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Run the in-place depthwise conv on a prepared arena.

    arena: (R, rowlen) with the input occupying rows [d_rows, d_rows+ih) and
    the first iw*c entries of each row. Output lands in rows [0, oh).
    """
    kh, kw, _ = w.shape
    spec = OpSpec(
        kind="depthwise_conv2d",
        in_off=(d_rows,),
        in_shape=((ih, iw, c),),
        out_off=0,
        out_shape=(oh, ow, c),
        meta=(kh, kw, stride, stride, 1, 1, pad, pad, 1),
        rowlen=int(arena.shape[1]),
        in_rows=((ih, iw * c),),
        out_rows=(oh, ow * c),
    )
    # the generalised kernel takes (kh, kw, ic, multiplier) filters
    return apply_op(arena, spec, (w.reshape(kh, kw, c, 1),),
                    resolve_interpret(interpret))
