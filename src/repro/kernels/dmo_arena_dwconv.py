"""Diagonal-memory-optimised depthwise conv2d as a Pallas TPU kernel.

The paper overlaps an op's input and output buffers inside the MCU's SRAM
arena. The TPU analogue of that SRAM is **VMEM**: this kernel keeps ONE flat
arena resident in VMEM, with the input tensor placed ``d_rows`` rows above
the output region — ``d_rows`` is derived from the *analytic* safe overlap
``O_s`` (repro.core.overlap.analytic), rounded up to row granularity (the
"block-granular O_s" of DESIGN.md §3). The kernel walks output rows in
ascending order (a sequential ``fori_loop``; the TPU-grid equivalent would
be an ``arbitrary``-semantics grid axis — parallel grids would break the
diagonal guarantee exactly like the paper's multi-threading caveat III.F).

Because reads for output row ``i`` come from input rows ``i*stride + d``
onward and the write goes to row ``i``, with ``d`` chosen from ``O_s``, no
live input value is ever clobbered — so the op needs
``max(rows_in + d, rows_out)`` arena rows instead of ``rows_in + rows_out``.

``input_output_aliases={0: 0}`` makes the arena genuinely in-place at the
XLA level (the O_s = |out| donation case composed with the partial-overlap
layout inside).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(arena_ref, w_ref, out_ref, *, ih: int, oh: int, ow: int, iw: int,
            c: int, kh: int, kw: int, stride: int, pad: int, d_rows: int):
    """arena/out_ref: (R, rowlen) f32 aliased; w_ref: (kh, kw, c)."""
    rowlen = arena_ref.shape[1]
    w = w_ref[...]

    def body(i, _):
        # gather the kh input rows feeding output row i (clamped + masked)
        acc = jnp.zeros((ow, c), jnp.float32)
        for fy in range(kh):                       # static unroll (kh small)
            iy = i * stride - pad + fy
            valid_row = (iy >= 0) & (iy < ih)
            src = arena_ref[pl.dslice(jnp.clip(iy, 0, ih - 1) + d_rows, 1), :]
            row = src.reshape(rowlen)[: iw * c].reshape(iw, c)
            for fx in range(kw):
                ixs = jax.lax.broadcasted_iota(jnp.int32, (ow, 1), 0)
                ix = ixs * stride - pad + fx
                valid = (ix >= 0) & (ix < iw) & valid_row
                taps = jnp.take_along_axis(
                    row, jnp.clip(ix, 0, iw - 1), axis=0)
                acc += jnp.where(valid, taps, 0.0) * w[fy, fx][None, :]
        out_row = jnp.zeros((1, rowlen), jnp.float32)
        out_row = out_row.at[0, : ow * c].set(acc.reshape(ow * c))
        out_ref[pl.dslice(i, 1), :] = out_row
        return 0

    jax.lax.fori_loop(0, oh, body, 0)


def _valid_iy_bound(ih: int):
    return ih


def dmo_dwconv2d_arena(arena: jax.Array, w: jax.Array, *, ih: int, iw: int,
                       c: int, stride: int, pad: int, d_rows: int,
                       oh: int, ow: int, interpret: bool = True) -> jax.Array:
    """Run the in-place depthwise conv on a prepared arena.

    arena: (R, rowlen) with the input occupying rows [d_rows, d_rows+ih) and
    the first iw*c entries of each row. Output lands in rows [0, oh).
    """
    kh, kw, _ = w.shape
    fn = pl.pallas_call(
        functools.partial(_kernel, ih=ih, oh=oh, ow=ow, iw=iw, c=c, kh=kh,
                          kw=kw, stride=stride, pad=pad, d_rows=d_rows),
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        in_specs=[
            pl.BlockSpec(arena.shape, lambda: (0, 0)),   # whole arena in VMEM
            pl.BlockSpec(w.shape, lambda: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec(arena.shape, lambda: (0, 0)),
        input_output_aliases={0: 0},                     # in-place arena
        interpret=interpret,
    )
    return fn(arena, w)
