"""Generalised DMO arena kernels: every supported op as a Pallas call over
ONE flat *byte* arena buffer.

This generalises :mod:`repro.kernels.dmo_arena_dwconv` (a single hard-coded
depthwise conv) to the full op set a :class:`~repro.core.planner.Plan` can
contain: conv2d / depthwise_conv2d / pool / elementwise / softmax /
fully_connected / matmul / concat / pad / mean. Each op becomes one
``pl.pallas_call`` whose first operand is the flat uint8 arena and whose
output *aliases* it (``input_output_aliases={0: 0}``), so the arena is
threaded in-place through the op sequence — the TPU-VMEM analogue of the
paper's SRAM tensor arena.

The arena is byte-granular and the kernels are **dtype-parameterised**
(``OpSpec.dtype``): f32 ops bitcast 4-byte windows of the arena to float32,
int8 ops bitcast single bytes to int8 and run the quantised tier — int32
accumulation plus the float32 scale/zero-point requantisation of
:mod:`repro.core.exec.ops` (``requantise``), mirrored here
operation-for-operation so numpy and pallas agree to <= 1 LSB. Mixed-dtype
plans therefore execute in one buffer with no implicit element size.

Safety contract (paper §III.A): kernels read *and* write through the aliased
output ref, and conv/pool walk output rows in ascending index order inside a
sequential ``fori_loop``. Reads for output row ``i`` therefore happen after
the row ``i-1`` store — exactly the element order the safe overlap ``O_s``
was derived against, which is why a planner-approved layout cannot clobber a
live value. A parallel grid over rows would break that guarantee, precisely
the paper's multi-threading caveat (§III.F) — keep the row loop sequential.

``interpret=True`` (the default) runs the kernels on CPU; compiled TPU
execution of a *flat* arena with byte-granular dynamic slices would fight
the (8, 128) tiling constraints, so on-device use should go through
row-blocked layouts like the dwconv kernel's ``(rows, rowlen)`` arena.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: jnp mirrors of repro.core.exec.ops.ELEMENTWISE (same names, same maths).
_ELEMENTWISE = {
    "relu": lambda a: jnp.maximum(a, 0.0),
    "relu6": lambda a: jnp.clip(a, 0.0, 6.0),
    "sigmoid": lambda a: 1.0 / (1.0 + jnp.exp(-a)),
    "identity": lambda a: a,
    "add": lambda a, b: a + b,
    "mul": lambda a, b: a * b,
    "sub": lambda a, b: a - b,
}

#: Op kinds that carry one synthesized weight operand.
WEIGHTED_KINDS = frozenset({"conv2d", "depthwise_conv2d", "fully_connected"})


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Hashable, fully static description of one lowered op: *byte* offsets
    into the flat arena, shapes, the arena dtype tier ("f32" or "i8"), and
    kind-specific parameters (plus quantisation statics for int8 ops). Two
    plans with identical layouts produce equal specs, so lowered programs
    are shared."""

    kind: str
    in_off: Tuple[int, ...]            # byte offset per data input
    in_shape: Tuple[Tuple[int, ...], ...]
    out_off: int                       # byte offset of the output
    out_shape: Tuple[int, ...]
    dtype: str = "f32"                 # arena tier: "f32" | "i8"
    meta: Tuple = ()                   # kind-specific statics (see builders)
    qmeta: Tuple = ()                  # int8 statics (zero points, multipliers)


def _elems(shape: Tuple[int, ...]) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


def _isz(dtype: str) -> int:
    return 1 if dtype == "i8" else 4


def _read(ref, byte_off, elems: int, dtype: str):
    """``elems`` values of the given tier from the uint8 arena at a (possibly
    traced) byte offset, as a flat typed vector."""
    if dtype == "i8":
        raw = ref[pl.dslice(byte_off, elems)]
        return jax.lax.bitcast_convert_type(raw, jnp.int8)
    raw = ref[pl.dslice(byte_off, 4 * elems)].reshape(elems, 4)
    return jax.lax.bitcast_convert_type(raw, jnp.float32)


def _read_t(ref, byte_off, shape: Tuple[int, ...], dtype: str):
    return _read(ref, byte_off, _elems(shape), dtype).reshape(shape)


def _write(ref, byte_off, value):
    """Store a typed value back into the uint8 arena at a byte offset."""
    flat = value.reshape(-1)
    raw = jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)
    ref[pl.dslice(byte_off, raw.size)] = raw


def _requant(acc, mult: float, zp: int):
    """jnp mirror of repro.core.exec.ops.requantise (same f32 arithmetic)."""
    q = jnp.round(acc.astype(jnp.float32) * jnp.float32(mult)) + zp
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def _dequant(x, scale: float, zp: int):
    return (x.astype(jnp.float32) - zp) * jnp.float32(scale)


def _quant(v, scale: float, zp: int):
    q = jnp.round(v / jnp.float32(scale)) + zp
    return jnp.clip(q, -128, 127).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Kernel bodies — all state lives in out_ref (the aliased arena); the input
# operand only seeds its initial contents via the alias.
# ---------------------------------------------------------------------------


def _conv_kernel(_a, w_ref, o_ref, *, spec: OpSpec):
    ih, iw, ic = spec.in_shape[0][-3:]
    oh, ow, oc = spec.out_shape[-3:]
    kh, kw, sh, sw, dh, dw, ph, pw, mult = spec.meta
    in_off, out_off = spec.in_off[0], spec.out_off
    depthwise = spec.kind == "depthwise_conv2d"
    quant = spec.dtype == "i8"
    isz = _isz(spec.dtype)

    def body(oy, _):
        if quant:
            x_zp, amult, y_zp = spec.qmeta
            acc = jnp.zeros((ow, oc), jnp.int32)
        else:
            acc = jnp.zeros((ow, oc), jnp.float32)
        for fy in range(kh):                    # static unroll (kh small)
            iy = oy * sh - ph + fy * dh
            row_ok = (iy >= 0) & (iy < ih)
            iy_c = jnp.clip(iy, 0, ih - 1)
            row = _read(o_ref, in_off + iy_c * iw * ic * isz, iw * ic,
                        spec.dtype).reshape(iw, ic)
            if quant:
                row = row.astype(jnp.int32) - x_zp
            for fx in range(kw):
                ix = jax.lax.broadcasted_iota(jnp.int32, (ow, 1), 0)
                ix = ix * sw - pw + fx * dw
                valid = (ix >= 0) & (ix < iw) & row_ok
                taps = jnp.take_along_axis(row, jnp.clip(ix, 0, iw - 1),
                                           axis=0)          # (ow, ic)
                taps = jnp.where(valid, taps, 0 if quant else 0.0)
                w = w_ref[fy, fx]
                if quant:
                    w = w.astype(jnp.int32)
                if depthwise:
                    acc += (taps[:, :, None]
                            * w[None, :, :]).reshape(ow, ic * mult)
                else:
                    acc += jnp.dot(
                        taps, w, preferred_element_type=(
                            jnp.int32 if quant else jnp.float32))
        out = _requant(acc, amult, y_zp) if quant else acc
        _write(o_ref, out_off + oy * ow * oc * isz, out)
        return 0

    jax.lax.fori_loop(0, oh, body, 0)


def _pool_kernel(_a, o_ref, *, spec: OpSpec):
    ih, iw, c = spec.in_shape[0][-3:]
    oh, ow, _ = spec.out_shape[-3:]
    kh, kw, sh, sw, ph, pw, mode = spec.meta
    in_off, out_off = spec.in_off[0], spec.out_off
    quant = spec.dtype == "i8"
    isz = _isz(spec.dtype)

    def body(oy, _):
        if quant:
            acc = jnp.full((ow, c), -2147483647 if mode == "max" else 0,
                           jnp.int32)
        else:
            acc = jnp.full((ow, c), -jnp.inf if mode == "max" else 0.0,
                           jnp.float32)
        cnt = jnp.zeros((ow, 1), jnp.float32)
        for fy in range(kh):
            iy = oy * sh - ph + fy
            row_ok = (iy >= 0) & (iy < ih)
            iy_c = jnp.clip(iy, 0, ih - 1)
            row = _read(o_ref, in_off + iy_c * iw * c * isz, iw * c,
                        spec.dtype).reshape(iw, c)
            if quant:
                row = row.astype(jnp.int32)
            for fx in range(kw):
                ix = jax.lax.broadcasted_iota(jnp.int32, (ow, 1), 0)
                ix = ix * sw - pw + fx
                valid = (ix >= 0) & (ix < iw) & row_ok
                taps = jnp.take_along_axis(row, jnp.clip(ix, 0, iw - 1),
                                           axis=0)
                if mode == "max":
                    acc = jnp.where(valid, jnp.maximum(acc, taps), acc)
                else:
                    acc = acc + jnp.where(valid, taps, 0 if quant else 0.0)
                    cnt = cnt + valid.astype(jnp.float32)
        if quant:
            x_zp, amult, y_zp = spec.qmeta
            if mode == "avg":
                val = acc.astype(jnp.float32) / jnp.maximum(cnt, 1.0) - x_zp
            else:
                val = acc - x_zp
            out = _requant(val, amult, y_zp)
        else:
            out = acc / jnp.maximum(cnt, 1.0) if mode == "avg" else acc
        _write(o_ref, out_off + oy * ow * c * isz, out)
        return 0

    jax.lax.fori_loop(0, oh, body, 0)


def _elementwise_kernel(_a, o_ref, *, spec: OpSpec):
    fn = _ELEMENTWISE[spec.meta[0]]
    xs = [_read_t(o_ref, off, shp, spec.dtype)
          for off, shp in zip(spec.in_off, spec.in_shape)]
    if spec.dtype == "i8":
        in_q, (ys, yzp) = spec.qmeta
        xs = [_dequant(x, s, zp) for x, (s, zp) in zip(xs, in_q)]
    if len(xs) == 2 and _elems(spec.in_shape[1]) != _elems(spec.in_shape[0]):
        xs[1] = jnp.broadcast_to(xs[1], xs[0].shape)
    v = fn(*xs).astype(jnp.float32)
    _write(o_ref, spec.out_off,
           _quant(v, ys, yzp) if spec.dtype == "i8" else v)


def _softmax_kernel(_a, o_ref, *, spec: OpSpec):
    x = _read_t(o_ref, spec.in_off[0], spec.in_shape[0], spec.dtype)
    if spec.dtype == "i8":
        (xs, xzp), (ys, yzp) = spec.qmeta
        x = _dequant(x, xs, xzp)
    e = jnp.exp(x - jnp.max(x, axis=-1, keepdims=True))
    y = e / jnp.sum(e, axis=-1, keepdims=True)
    _write(o_ref, spec.out_off,
           _quant(y, ys, yzp) if spec.dtype == "i8" else y)


def _fully_connected_kernel(_a, w_ref, o_ref, *, spec: OpSpec):
    idim = spec.in_shape[0][-1]
    x = _read_t(o_ref, spec.in_off[0], spec.in_shape[0],
                spec.dtype).reshape(-1, idim)
    if spec.dtype == "i8":
        x_zp, amult, y_zp = spec.qmeta
        acc = jnp.dot(x.astype(jnp.int32) - x_zp,
                      w_ref[...].astype(jnp.int32),
                      preferred_element_type=jnp.int32)
        y = _requant(acc, amult, y_zp)
    else:
        y = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    _write(o_ref, spec.out_off, y.reshape(spec.out_shape))


def _matmul_kernel(_a, o_ref, *, spec: OpSpec):
    a = _read_t(o_ref, spec.in_off[0], spec.in_shape[0], spec.dtype)
    a = a.reshape(-1, spec.in_shape[0][-1])
    b = _read_t(o_ref, spec.in_off[1], spec.in_shape[1], spec.dtype)
    if spec.dtype == "i8":
        a_zp, b_zp, amult, y_zp = spec.qmeta
        acc = jnp.dot(a.astype(jnp.int32) - a_zp,
                      b.astype(jnp.int32) - b_zp,
                      preferred_element_type=jnp.int32)
        y = _requant(acc, amult, y_zp)
    else:
        y = jnp.dot(a, b, preferred_element_type=jnp.float32)
    _write(o_ref, spec.out_off, y.reshape(spec.out_shape))


def _rescale(x, src, dst):
    """jnp mirror of repro.core.exec.ops.rescale_q (f32 multiplier is baked
    into qmeta by the lowering, so both backends use the identical bits)."""
    (s_zp, mult), (y_zp,) = src, dst
    return _requant(x.astype(jnp.int32) - s_zp, mult, y_zp)


def _concat_kernel(_a, o_ref, *, spec: OpSpec):
    axis = spec.meta[0]
    xs = [_read_t(o_ref, off, shp, spec.dtype)
          for off, shp in zip(spec.in_off, spec.in_shape)]
    if spec.dtype == "i8":
        in_q, (yzp,) = spec.qmeta
        xs = [_rescale(x, q, (yzp,)) for x, q in zip(xs, in_q)]
    _write(o_ref, spec.out_off, jnp.concatenate(xs, axis=axis))


def _pad_kernel(_a, o_ref, *, spec: OpSpec):
    x = _read_t(o_ref, spec.in_off[0], spec.in_shape[0], spec.dtype)
    if spec.dtype == "i8":
        (x_zp, mult), (y_zp,) = spec.qmeta
        padded = jnp.pad(x, spec.meta[0], constant_values=x_zp)
        _write(o_ref, spec.out_off, _rescale(padded, (x_zp, mult), (y_zp,)))
        return
    _write(o_ref, spec.out_off, jnp.pad(x, spec.meta[0]))


def _mean_kernel(_a, o_ref, *, spec: OpSpec):
    x = _read_t(o_ref, spec.in_off[0], spec.in_shape[0], spec.dtype)
    axes = spec.meta[0]
    if spec.dtype == "i8":
        x_zp, amult, y_zp = spec.qmeta
        cnt = 1
        for ax in axes:
            cnt *= x.shape[ax]
        acc = jnp.sum(x.astype(jnp.int32), axis=axes)
        val = acc.astype(jnp.float32) / jnp.float32(cnt) - x_zp
        y = _requant(val, amult, y_zp)
    else:
        y = jnp.mean(x, axis=axes)
    _write(o_ref, spec.out_off, y.reshape(spec.out_shape))


_KERNELS = {
    "conv2d": _conv_kernel,
    "depthwise_conv2d": _conv_kernel,
    "pool": _pool_kernel,
    "elementwise": _elementwise_kernel,
    "softmax": _softmax_kernel,
    "fully_connected": _fully_connected_kernel,
    "matmul": _matmul_kernel,
    "concat": _concat_kernel,
    "pad": _pad_kernel,
    "mean": _mean_kernel,
}


def apply_op(arena: jax.Array, spec: OpSpec, weights: Tuple[jax.Array, ...],
             interpret: bool = True) -> jax.Array:
    """Run one op in-place on the flat byte arena; returns the (aliased)
    arena."""
    kernel = functools.partial(_KERNELS[spec.kind], spec=spec)
    fn = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={0: 0},            # the arena is donated through
        interpret=interpret,
    )
    return fn(arena, *weights)


def lower_program(specs: Tuple[OpSpec, ...], interpret: bool = True):
    """Jit-compiled executor for a spec sequence: ``fn(arena, *weights) ->
    arena``. The arena argument is donated, so together with the per-op
    aliasing the whole network runs in one flat buffer. Cached on the spec
    content — structurally identical plans share the compiled program."""
    return _lower_program_cached(tuple(specs), bool(interpret))


@functools.lru_cache(maxsize=128)
def _lower_program_cached(specs: Tuple[OpSpec, ...], interpret: bool):
    weight_counts = tuple(1 if s.kind in WEIGHTED_KINDS else 0 for s in specs)

    def run(arena, *wflat):
        i = 0
        for spec, nw in zip(specs, weight_counts):
            arena = apply_op(arena, spec, wflat[i:i + nw], interpret)
            i += nw
        return arena

    return jax.jit(run, donate_argnums=0)
