"""Generalised DMO arena kernels: every supported op as a Pallas call over
ONE flat arena buffer.

This generalises :mod:`repro.kernels.dmo_arena_dwconv` (a single hard-coded
depthwise conv) to the full op set a :class:`~repro.core.planner.Plan` can
contain: conv2d / depthwise_conv2d / pool / elementwise / softmax /
fully_connected / matmul / concat / pad / mean. Each op becomes one
``pl.pallas_call`` whose first operand is the flat f32 arena and whose output
*aliases* it (``input_output_aliases={0: 0}``), so the arena is threaded
in-place through the op sequence — the TPU-VMEM analogue of the paper's SRAM
tensor arena.

Safety contract (paper §III.A): kernels read *and* write through the aliased
output ref, and conv/pool walk output rows in ascending index order inside a
sequential ``fori_loop``. Reads for output row ``i`` therefore happen after
the row ``i-1`` store — exactly the element order the safe overlap ``O_s``
was derived against, which is why a planner-approved layout cannot clobber a
live value. A parallel grid over rows would break that guarantee, precisely
the paper's multi-threading caveat (§III.F) — keep the row loop sequential.

``interpret=True`` (the default) runs the kernels on CPU; compiled TPU
execution of a *flat* arena with element-granular dynamic slices would fight
the (8, 128) tiling constraints, so on-device use should go through
row-blocked layouts like the dwconv kernel's ``(rows, rowlen)`` arena.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: jnp mirrors of repro.core.exec.ops.ELEMENTWISE (same names, same maths).
_ELEMENTWISE = {
    "relu": lambda a: jnp.maximum(a, 0.0),
    "relu6": lambda a: jnp.clip(a, 0.0, 6.0),
    "sigmoid": lambda a: 1.0 / (1.0 + jnp.exp(-a)),
    "identity": lambda a: a,
    "add": lambda a, b: a + b,
    "mul": lambda a, b: a * b,
    "sub": lambda a, b: a - b,
}

#: Op kinds that carry one synthesized weight operand.
WEIGHTED_KINDS = frozenset({"conv2d", "depthwise_conv2d", "fully_connected"})


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Hashable, fully static description of one lowered op: element offsets
    into the flat arena, shapes, and kind-specific parameters. Two plans with
    identical layouts produce equal specs, so lowered programs are shared."""

    kind: str
    in_off: Tuple[int, ...]            # element offset per data input
    in_shape: Tuple[Tuple[int, ...], ...]
    out_off: int
    out_shape: Tuple[int, ...]
    meta: Tuple = ()                   # kind-specific statics (see builders)


def _elems(shape: Tuple[int, ...]) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


def _read(ref, off: int, shape: Tuple[int, ...]):
    return ref[pl.dslice(off, _elems(shape))].reshape(shape)


def _write(ref, off: int, value):
    ref[pl.dslice(off, _elems(value.shape))] = value.reshape(-1)


# ---------------------------------------------------------------------------
# Kernel bodies — all state lives in out_ref (the aliased arena); the input
# operand only seeds its initial contents via the alias.
# ---------------------------------------------------------------------------


def _conv_kernel(_a, w_ref, o_ref, *, spec: OpSpec):
    ih, iw, ic = spec.in_shape[0][-3:]
    oh, ow, oc = spec.out_shape[-3:]
    kh, kw, sh, sw, dh, dw, ph, pw, mult = spec.meta
    in_off, out_off = spec.in_off[0], spec.out_off
    depthwise = spec.kind == "depthwise_conv2d"

    def body(oy, _):
        acc = jnp.zeros((ow, oc), jnp.float32)
        for fy in range(kh):                    # static unroll (kh small)
            iy = oy * sh - ph + fy * dh
            row_ok = (iy >= 0) & (iy < ih)
            iy_c = jnp.clip(iy, 0, ih - 1)
            row = o_ref[pl.dslice(in_off + iy_c * iw * ic, iw * ic)]
            row = row.reshape(iw, ic)
            for fx in range(kw):
                ix = jax.lax.broadcasted_iota(jnp.int32, (ow, 1), 0)
                ix = ix * sw - pw + fx * dw
                valid = (ix >= 0) & (ix < iw) & row_ok
                taps = jnp.take_along_axis(row, jnp.clip(ix, 0, iw - 1),
                                           axis=0)          # (ow, ic)
                taps = jnp.where(valid, taps, 0.0)
                if depthwise:
                    acc += (taps[:, :, None]
                            * w_ref[fy, fx][None, :, :]).reshape(ow, ic * mult)
                else:
                    acc += jnp.dot(taps, w_ref[fy, fx],
                                   preferred_element_type=jnp.float32)
        _write(o_ref, out_off + oy * ow * oc, acc)
        return 0

    jax.lax.fori_loop(0, oh, body, 0)


def _pool_kernel(_a, o_ref, *, spec: OpSpec):
    ih, iw, c = spec.in_shape[0][-3:]
    oh, ow, _ = spec.out_shape[-3:]
    kh, kw, sh, sw, ph, pw, mode = spec.meta
    in_off, out_off = spec.in_off[0], spec.out_off

    def body(oy, _):
        acc = jnp.full((ow, c), -jnp.inf if mode == "max" else 0.0,
                       jnp.float32)
        cnt = jnp.zeros((ow, 1), jnp.float32)
        for fy in range(kh):
            iy = oy * sh - ph + fy
            row_ok = (iy >= 0) & (iy < ih)
            iy_c = jnp.clip(iy, 0, ih - 1)
            row = o_ref[pl.dslice(in_off + iy_c * iw * c, iw * c)]
            row = row.reshape(iw, c)
            for fx in range(kw):
                ix = jax.lax.broadcasted_iota(jnp.int32, (ow, 1), 0)
                ix = ix * sw - pw + fx
                valid = (ix >= 0) & (ix < iw) & row_ok
                taps = jnp.take_along_axis(row, jnp.clip(ix, 0, iw - 1),
                                           axis=0)
                if mode == "max":
                    acc = jnp.where(valid, jnp.maximum(acc, taps), acc)
                else:
                    acc = acc + jnp.where(valid, taps, 0.0)
                    cnt = cnt + valid.astype(jnp.float32)
        out = acc / jnp.maximum(cnt, 1.0) if mode == "avg" else acc
        _write(o_ref, out_off + oy * ow * c, out)
        return 0

    jax.lax.fori_loop(0, oh, body, 0)


def _elementwise_kernel(_a, o_ref, *, spec: OpSpec):
    fn = _ELEMENTWISE[spec.meta[0]]
    xs = [_read(o_ref, off, shp)
          for off, shp in zip(spec.in_off, spec.in_shape)]
    if len(xs) == 2 and _elems(spec.in_shape[1]) != _elems(spec.in_shape[0]):
        xs[1] = jnp.broadcast_to(xs[1], xs[0].shape)
    _write(o_ref, spec.out_off, fn(*xs).astype(jnp.float32))


def _softmax_kernel(_a, o_ref, *, spec: OpSpec):
    x = _read(o_ref, spec.in_off[0], spec.in_shape[0])
    e = jnp.exp(x - jnp.max(x, axis=-1, keepdims=True))
    _write(o_ref, spec.out_off, e / jnp.sum(e, axis=-1, keepdims=True))


def _fully_connected_kernel(_a, w_ref, o_ref, *, spec: OpSpec):
    idim = spec.in_shape[0][-1]
    x = _read(o_ref, spec.in_off[0], spec.in_shape[0]).reshape(-1, idim)
    y = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    _write(o_ref, spec.out_off, y.reshape(spec.out_shape))


def _matmul_kernel(_a, o_ref, *, spec: OpSpec):
    a = _read(o_ref, spec.in_off[0], spec.in_shape[0])
    a = a.reshape(-1, spec.in_shape[0][-1])
    b = _read(o_ref, spec.in_off[1], spec.in_shape[1])
    y = jnp.dot(a, b, preferred_element_type=jnp.float32)
    _write(o_ref, spec.out_off, y.reshape(spec.out_shape))


def _concat_kernel(_a, o_ref, *, spec: OpSpec):
    axis = spec.meta[0]
    xs = [_read(o_ref, off, shp)
          for off, shp in zip(spec.in_off, spec.in_shape)]
    _write(o_ref, spec.out_off, jnp.concatenate(xs, axis=axis))


def _pad_kernel(_a, o_ref, *, spec: OpSpec):
    x = _read(o_ref, spec.in_off[0], spec.in_shape[0])
    _write(o_ref, spec.out_off, jnp.pad(x, spec.meta[0]))


def _mean_kernel(_a, o_ref, *, spec: OpSpec):
    x = _read(o_ref, spec.in_off[0], spec.in_shape[0])
    y = jnp.mean(x, axis=spec.meta[0]).reshape(spec.out_shape)
    _write(o_ref, spec.out_off, y)


_KERNELS = {
    "conv2d": _conv_kernel,
    "depthwise_conv2d": _conv_kernel,
    "pool": _pool_kernel,
    "elementwise": _elementwise_kernel,
    "softmax": _softmax_kernel,
    "fully_connected": _fully_connected_kernel,
    "matmul": _matmul_kernel,
    "concat": _concat_kernel,
    "pad": _pad_kernel,
    "mean": _mean_kernel,
}


def apply_op(arena: jax.Array, spec: OpSpec, weights: Tuple[jax.Array, ...],
             interpret: bool = True) -> jax.Array:
    """Run one op in-place on the flat arena; returns the (aliased) arena."""
    kernel = functools.partial(_KERNELS[spec.kind], spec=spec)
    fn = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={0: 0},            # the arena is donated through
        interpret=interpret,
    )
    return fn(arena, *weights)


def lower_program(specs: Tuple[OpSpec, ...], interpret: bool = True):
    """Jit-compiled executor for a spec sequence: ``fn(arena, *weights) ->
    arena``. The arena argument is donated, so together with the per-op
    aliasing the whole network runs in one flat buffer. Cached on the spec
    content — structurally identical plans share the compiled program."""
    return _lower_program_cached(tuple(specs), bool(interpret))


@functools.lru_cache(maxsize=128)
def _lower_program_cached(specs: Tuple[OpSpec, ...], interpret: bool):
    weight_counts = tuple(1 if s.kind in WEIGHTED_KINDS else 0 for s in specs)

    def run(arena, *wflat):
        i = 0
        for spec, nw in zip(specs, weight_counts):
            arena = apply_op(arena, spec, wflat[i:i + nw], interpret)
            i += nw
        return arena

    return jax.jit(run, donate_argnums=0)
