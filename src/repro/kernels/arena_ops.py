"""Generalised DMO arena kernels: every supported op as a Pallas call over
ONE shared arena buffer, in one of three arena programs.

This generalises :mod:`repro.kernels.dmo_arena_dwconv` (a single hard-coded
depthwise conv) to the full op set a :class:`~repro.core.planner.Plan` can
contain: conv2d / depthwise_conv2d / pool / elementwise / softmax /
fully_connected / matmul / concat / pad / mean. Each op becomes one
``pl.pallas_call`` whose first operand is the shared arena and whose output
*aliases* it (``input_output_aliases={0: 0}``), so the arena is threaded
in-place through the op sequence — the TPU-VMEM analogue of the paper's SRAM
tensor arena.

Three arena addressings share the same kernel bodies through a small memory
access layer (an :class:`OpSpec` with ``rowlen == 0`` selects the flat
program, ``rowlen > 0`` the blocked one, and ``win_rows > 0`` on top of
that the streaming one):

- **flat** (:class:`_FlatMem`) — the arena is a 1-D *byte* buffer; operands
  live at byte offsets and kernels bitcast their windows to the tier the
  spec declares (f32 windows / int8 bytes, the quantised tier running int32
  accumulation plus the float32 requantisation of
  :mod:`repro.core.exec.ops`). Mixed-dtype plans execute in one buffer, but
  byte-granular dynamic slices fight the TPU's (8, 128)/(32, 128) VMEM
  tilings — this program is interpret-mode only.
- **row-blocked** (:class:`_BlockMem`) — the arena is a 2-D
  ``(rows, rowlen)`` buffer *typed* to the plan's dtype, laid out by
  :func:`repro.core.planner.legalise_for_blocks`: operands occupy whole
  arena rows at row-aligned offsets, conv/pool walk image rows via
  ``pl.dslice`` on the row axis, and no bitcasts are needed — the same
  program lowers under ``interpret=False``. Packed layouts (spec
  ``in_addr``/``out_addr`` triples) put ``cols_per_row`` narrow image rows
  in each arena row — reads dynamic-slice the lane phase, writes RMW the
  whole arena row — or span one wide image row over ``row_span``
  consecutive arena rows. The whole arena is VMEM-resident, so the VMEM
  capacity caps ``total_rows``.
- **streaming** (:class:`_StreamRollMem` / :class:`_StreamStageMem`) — the
  arena stays in ``pltpu.ANY`` (HBM) and each op DMAs only its *live
  window* (:class:`repro.core.planner.WindowSchedule`) into VMEM scratch
  with ``pltpu.make_async_copy``. Row-streaming ops (conv / depthwise /
  pool) run a row-tile grid: a double-buffered rolling input window (the
  tile-``t+1`` fetch is issued before the tile-``t`` wait) plus a one-tile
  output slot whose rows DMA back as they are produced. Every other kind
  stages whole operand blocks into packed scratch slots
  (:func:`repro.core.planner.staged_slots`, fetches pipelined over two
  rotating DMA semaphores), computes, and copies the output block back.
  The VMEM ceiling becomes ``max_window_rows``, not ``total_rows``.

Split row bands (§II.A) need no kernels of their own: a banded conv/pool's
spec carries its band shapes and its explicit band-local pads (a producer
band's leading row pad is *negative* — ``iy = oy*sh - ph + fy*dh`` simply
starts deeper in the full input), so the ordinary row kernels index exactly
the band's rows in both the flat and the row-blocked program.

Safety contract (paper §III.A): kernels read *and* write through the aliased
output ref, and conv/pool walk output rows in ascending index order inside a
sequential ``fori_loop``. Reads for output row ``i`` therefore happen after
the row ``i-1`` store — exactly the element order the safe overlap ``O_s``
was derived against, which is why a planner-approved layout cannot clobber a
live value. In the blocked program a row store clobbers the *whole* arena
row (tiling padding included), which is why the legaliser re-derives each
diagonal distance at row granularity. A parallel grid over rows would break
the guarantee, precisely the paper's multi-threading caveat (§III.F) — keep
the row loop sequential.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: jnp mirrors of repro.core.exec.ops.ELEMENTWISE (same names, same maths).
_ELEMENTWISE = {
    "relu": lambda a: jnp.maximum(a, 0.0),
    "relu6": lambda a: jnp.clip(a, 0.0, 6.0),
    "sigmoid": lambda a: 1.0 / (1.0 + jnp.exp(-a)),
    "identity": lambda a: a,
    "add": lambda a, b: a + b,
    "mul": lambda a, b: a * b,
    "sub": lambda a, b: a - b,
}

#: Op kinds that carry one synthesized weight operand.
WEIGHTED_KINDS = frozenset({"conv2d", "depthwise_conv2d", "fully_connected"})


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Hashable, fully static description of one lowered op: operand
    placements in the shared arena, shapes, the arena dtype tier ("f32" or
    "i8"), and kind-specific parameters (plus quantisation statics for int8
    ops). Two plans with identical layouts produce equal specs, so lowered
    programs are shared.

    ``rowlen == 0`` selects the flat byte program: ``in_off``/``out_off``
    are *byte* offsets into a 1-D uint8 arena. ``rowlen > 0`` selects the
    row-blocked program over a typed ``(rows, rowlen)`` arena: offsets are
    arena *row* indices and ``in_rows``/``out_rows`` carry each operand's
    ``(rows, used-elements-per-row)`` block shape from its
    :class:`~repro.core.planner.BlockLayout`. ``win_rows > 0`` (on top of
    ``rowlen > 0``) selects the streaming grid program: the arena lives in
    ``pltpu.ANY`` and only ``win_rows`` rows are VMEM-resident —
    ``win_starts`` is the planner's per-output-tile fetch start table for
    rolling conv/pool windows (empty = staged whole-block op), ``win_lo``
    the low edge of the op's live-window extent (reporting only)."""

    kind: str
    in_off: Tuple[int, ...]            # byte (flat) | arena-row (blocked)
    in_shape: Tuple[Tuple[int, ...], ...]
    out_off: int
    out_shape: Tuple[int, ...]
    dtype: str = "f32"                 # arena tier: "f32" | "i8"
    meta: Tuple = ()                   # kind-specific statics (see builders)
    qmeta: Tuple = ()                  # int8 statics (zero points, multipliers)
    rowlen: int = 0                    # arena row elements (0 = flat program)
    in_rows: Tuple[Tuple[int, int], ...] = ()  # (rows, used) per input
    out_rows: Tuple[int, int] = ()             # (rows, used) of the output
    win_lo: int = 0                    # live-window extent low edge (rows)
    win_rows: int = 0                  # VMEM-resident rows (0 = non-streaming)
    win_starts: Tuple[int, ...] = ()   # rolling-window fetch starts per tile
    #: Packed row addressing (blocked/streaming programs only): per-operand
    #: ``(cols_per_row, row_span, image_rowlen)`` triples from the packed
    #: :class:`~repro.core.planner.BlockLayout` geometry. Empty = the legacy
    #: one-image-row-per-arena-row addressing (and bit-identical specs for
    #: legacy plans). ``out_tile`` is the *image* rows one streaming grid
    #: tile computes (0 = the dtype sublane, the legacy tiling).
    in_addr: Tuple[Tuple[int, int, int], ...] = ()
    out_addr: Tuple[int, int, int] = ()
    out_tile: int = 0
    #: Fused band-chain super-kernel (``kind == "fused"``): the chain's
    #: member ops in graph order as nested stage specs. Stage offsets whose
    #: ``in_scratch``/``out_scratch`` flag is set are *scratch-local* slot
    #: offsets (rows blocked / bytes flat, packed by
    #: :func:`repro.core.planner.fused_slots`); chain-internal tensors
    #: therefore never touch the arena — one ``pallas_call`` runs the whole
    #: chain with its halos resident in VMEM and only the terminal stage
    #: (the reassembling concat) writes back at the planned offset.
    stages: Tuple["OpSpec", ...] = ()
    scratch_rows: int = 0              # chain scratch: rows (blocked) | bytes (flat)
    in_scratch: Tuple[int, ...] = ()   # stage flag: input i reads the scratch ref
    out_scratch: int = 0               # stage flag: output writes the scratch ref
    in_slots: Tuple[int, ...] = ()     # fused streaming: ext-input scratch slots
    out_slot: int = 0                  # fused streaming: terminal-output slot


def _elems(shape: Tuple[int, ...]) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


def _isz(dtype: str) -> int:
    return 1 if dtype == "i8" else 4


def _jnp_dtype(dtype: str):
    return jnp.int8 if dtype == "i8" else jnp.float32


def _sub(dtype: str) -> int:
    """Sublane tile rows for the arena dtype (mirrors planner.TPU_TILES)."""
    return 32 if dtype == "i8" else 8


def _addr_in(spec: OpSpec, i: int) -> Tuple[int, int, int]:
    """Input ``i``'s packed addressing triple ((1, 1, 0) = legacy)."""
    return spec.in_addr[i] if spec.in_addr else (1, 1, 0)


def _addr_out(spec: OpSpec) -> Tuple[int, int, int]:
    return spec.out_addr if spec.out_addr else (1, 1, 0)


def _tile_geom(spec: OpSpec) -> Tuple[int, int]:
    """(image rows, sublane-rounded arena rows) of one streaming output
    tile — mirrors planner.tile_rows/tile_arena_rows (``out_tile`` is a
    multiple of ``cols_per_row``, so lane phases complete within a tile)."""
    sub = _sub(spec.dtype)
    tr = spec.out_tile or sub
    c, k, _ = _addr_out(spec)
    ar = (tr - 1) // c + 1 if c > 1 else tr * k
    return tr, -(-ar // sub) * sub


# ---------------------------------------------------------------------------
# Memory access layer: the one place the two arena addressings differ.
# Kernel bodies below are written once against this API.
# ---------------------------------------------------------------------------


class _FlatMem:
    """Flat byte-arena accessor: bitcast typed windows at byte offsets.

    Per-operand refs resolve through ``_in_ref``/``_out_ref`` (the arena ref
    for plain ops); the fused-chain subclasses override them to route
    scratch-flagged operands to the chain's VMEM scratch buffer."""

    def __init__(self, ref, spec: OpSpec):
        self.ref, self.spec = ref, spec
        self.isz = _isz(spec.dtype)

    def _in_ref(self, i: int):
        return self.ref

    def _out_ref(self):
        return self.ref

    def _read(self, ref, byte_off, elems: int):
        if self.spec.dtype == "i8":
            raw = ref[pl.dslice(byte_off, elems)]
            return jax.lax.bitcast_convert_type(raw, jnp.int8)
        raw = ref[pl.dslice(byte_off, 4 * elems)].reshape(elems, 4)
        return jax.lax.bitcast_convert_type(raw, jnp.float32)

    def read_t(self, i: int):
        """Input ``i`` as a typed tensor in its view shape."""
        shape = self.spec.in_shape[i]
        return self._read(self._in_ref(i), self.spec.in_off[i],
                          _elems(shape)).reshape(shape)

    def read_row(self, i: int, iy):
        """One image row (W*C elements) of input ``i`` at a traced row
        index."""
        row = _elems(self.spec.in_shape[i][-2:])
        return self._read(self._in_ref(i),
                          self.spec.in_off[i] + iy * row * self.isz, row)

    def _write(self, ref, byte_off, value):
        flat = value.reshape(-1)
        raw = jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)
        ref[pl.dslice(byte_off, raw.size)] = raw

    def write(self, value):
        self._write(self._out_ref(), self.spec.out_off, value)

    def write_row(self, oy, value):
        row = _elems(self.spec.out_shape[-2:])
        self._write(self._out_ref(),
                    self.spec.out_off + oy * row * self.isz, value)

    def fori_rows(self, oh: int, body) -> None:
        """Sequential walk over every output row (§III.F: keep it serial)."""
        jax.lax.fori_loop(0, oh, body, 0)


def _pad_cols(block, rows: int, used: int, L: int, dt):
    """Zero-fill each row's tile-padding tail out to the arena row."""
    if used == L:
        return block
    return jnp.concatenate(
        [block, jnp.zeros((rows, L - used), dt)], axis=1)


def _out_block(value, rows: int, used: int, L: int, dt):
    """An output tensor as a padded (rows, L) arena block (dense tail and
    per-row tile padding zero-filled)."""
    flat = value.reshape(-1).astype(dt)
    if flat.size < rows * used:
        flat = jnp.concatenate(
            [flat, jnp.zeros(rows * used - flat.size, dt)])
    return _pad_cols(flat.reshape(rows, used), rows, used, L, dt)


def _dec_row(ref, row0, iy, L: int, used: int, addr: Tuple[int, int, int]):
    """One image row (``used`` elements) of an operand whose image row 0
    starts at arena row ``row0`` of a (rows, L) ref, at a traced row index
    ``iy``. Packed rows live at lane phase ``(iy % c) * rl`` of arena row
    ``iy // c``; a spanning image row occupies ``k`` consecutive arena
    rows."""
    c, k, rl = addr
    if c > 1:
        row = ref[pl.dslice(row0 + iy // c, 1), :].reshape(L)
        return jax.lax.dynamic_slice(row, ((iy % c) * rl,), (rl,))
    if k > 1:
        return ref[pl.dslice(row0 + iy * k, k), :].reshape(k * L)[:used]
    return ref[pl.dslice(row0 + iy, 1), :].reshape(L)[:used]


def _dec_block(block, rows: int, used: int, L: int,
               addr: Tuple[int, int, int], n: int):
    """A whole (rows, L) operand block flattened to its first ``n``
    elements. Packed/legacy rows are contiguous over the used prefix
    (packing is row-major in image order); spanning rows carry per-image-row
    column padding that must be stripped."""
    _, k, rl = addr
    if k > 1:
        h = rows // k
        flat = block.reshape(h, k * L)[:, :rl].reshape(h * rl)
    else:
        flat = block[:, :used].reshape(rows * used)
    return flat[:n]


def _enc_block(value, rows: int, used: int, L: int, dt,
               addr: Tuple[int, int, int]):
    """Inverse of :func:`_dec_block`: an output tensor as a padded
    (rows, L) arena block under the given addressing."""
    _, k, rl = addr
    if k > 1:
        h = rows // k
        flat = value.reshape(-1).astype(dt)
        if flat.size < h * rl:
            flat = jnp.concatenate([flat, jnp.zeros(h * rl - flat.size, dt)])
        return _pad_cols(flat.reshape(h, rl), h, rl, k * L,
                         dt).reshape(rows, L)
    return _out_block(value, rows, used, L, dt)


class _BlockMem:
    """Row-blocked accessor: whole arena rows of a typed (R, L) buffer via
    ``pl.dslice`` on the row axis — no bitcasts, compiled-mode lowerable."""

    def __init__(self, ref, spec: OpSpec):
        self.ref, self.spec = ref, spec
        self.dt = _jnp_dtype(spec.dtype)
        self.L = spec.rowlen

    def _in_ref(self, i: int):
        return self.ref

    def _out_ref(self):
        return self.ref

    def read_t(self, i: int):
        rows, used = self.spec.in_rows[i]
        shape = self.spec.in_shape[i]
        block = self._in_ref(i)[pl.dslice(self.spec.in_off[i], rows), :]
        return _dec_block(block, rows, used, self.L, _addr_in(self.spec, i),
                          _elems(shape)).reshape(shape)

    def read_row(self, i: int, iy):
        used = _elems(self.spec.in_shape[i][-2:])
        return _dec_row(self._in_ref(i), self.spec.in_off[i], iy, self.L,
                        used, _addr_in(self.spec, i))

    def write(self, value):
        rows, used = self.spec.out_rows
        self._out_ref()[pl.dslice(self.spec.out_off, rows), :] = \
            _enc_block(value, rows, used, self.L, self.dt,
                       _addr_out(self.spec))

    def write_row(self, oy, value):
        # A packed row store is a read-modify-write of the whole arena row
        # (the other lane phases must survive); safe because the row loop is
        # sequential and the planner's O_s is derived at whole-arena-row
        # granularity, phases included.
        used = _elems(self.spec.out_shape[-2:])
        c, k, rl = _addr_out(self.spec)
        ref, off = self._out_ref(), self.spec.out_off
        val = value.reshape(-1).astype(self.dt)
        if c > 1:
            ar = off + oy // c
            row = ref[pl.dslice(ar, 1), :].reshape(self.L)
            row = jax.lax.dynamic_update_slice(row, val, ((oy % c) * rl,))
            ref[pl.dslice(ar, 1), :] = row.reshape(1, self.L)
        elif k > 1:
            ref[pl.dslice(off + oy * k, k), :] = _pad_cols(
                val.reshape(1, used), 1, used, k * self.L,
                self.dt).reshape(k, self.L)
        else:
            ref[pl.dslice(off + oy, 1), :] = \
                _pad_cols(val.reshape(1, used), 1, used, self.L, self.dt)

    def fori_rows(self, oh: int, body) -> None:
        jax.lax.fori_loop(0, oh, body, 0)


class _RoutedMem:
    """Mixin for fused-chain stages: each operand resolves to the arena ref
    or to the chain's VMEM scratch ref per the stage spec's
    ``in_scratch``/``out_scratch`` flags. Scratch-flagged offsets are
    scratch-local slot positions; arena-flagged ones the plan's placements —
    so the written-once bodies run unmodified while chain-internal values
    stay VMEM-resident."""

    def __init__(self, arena_ref, scratch_ref, spec: OpSpec):
        super().__init__(arena_ref, spec)
        self.scratch_ref = scratch_ref

    def _in_ref(self, i: int):
        flags = self.spec.in_scratch
        return self.scratch_ref if flags and flags[i] else self.ref

    def _out_ref(self):
        return self.scratch_ref if self.spec.out_scratch else self.ref


class _RoutedFlatMem(_RoutedMem, _FlatMem):
    pass


class _RoutedBlockMem(_RoutedMem, _BlockMem):
    pass


class _StreamRollMem:
    """Streaming accessor for one output-row tile of a rolling-window
    conv/pool: reads index the double-buffered VMEM input-window slot
    (arena row ``r`` lives at scratch row ``r - base``; reads that fall
    outside the window are the kernels' clamped+masked taps, which the
    dynamic slice clamps in-bounds and the mask discards), writes land in
    the one-tile output slot and DMA straight back to the arena row they
    belong to. ``fori_rows`` restricts the shared kernel bodies to this
    tile's output rows — the bodies themselves stay written-once."""

    def __init__(self, in_ref, out_ref, arena_ref, sem, spec: OpSpec,
                 base, row_lo, row_hi):
        self.in_ref, self.out_ref = in_ref, out_ref
        self.arena_ref, self.sem, self.spec = arena_ref, sem, spec
        self.base, self.row_lo, self.row_hi = base, row_lo, row_hi
        self.dt = _jnp_dtype(spec.dtype)
        self.L = spec.rowlen

    def read_row(self, i: int, iy):
        used = _elems(self.spec.in_shape[i][-2:])
        return _dec_row(self.in_ref, self.spec.in_off[i] - self.base, iy,
                        self.L, used, _addr_in(self.spec, i))

    def write_row(self, oy, value):
        # Packed output rows RMW their slot row (phases accumulate — the
        # tile covers whole arena rows, ``out_tile = sub*c`` image rows) and
        # DMA the whole arena row back per phase; the redundant copies are
        # idempotent and the final one carries every phase. Spanning rows
        # write and copy ``k`` arena rows at once.
        used = _elems(self.spec.out_shape[-2:])
        c, k, rl = _addr_out(self.spec)
        val = value.reshape(-1).astype(self.dt)
        if c > 1:
            ar = oy // c                    # operand-relative arena row
            j = ar - self.row_lo // c       # slot row (row_lo % c == 0)
            row = self.out_ref[pl.dslice(j, 1), :].reshape(self.L)
            row = jax.lax.dynamic_update_slice(row, val, ((oy % c) * rl,))
            self.out_ref[pl.dslice(j, 1), :] = row.reshape(1, self.L)
            n = 1
        elif k > 1:
            ar = oy * k
            j = (oy - self.row_lo) * k
            self.out_ref[pl.dslice(j, k), :] = _pad_cols(
                val.reshape(1, used), 1, used, k * self.L,
                self.dt).reshape(k, self.L)
            n = k
        else:
            ar = oy
            j = oy - self.row_lo
            self.out_ref[pl.dslice(j, 1), :] = \
                _pad_cols(val.reshape(1, used), 1, used, self.L, self.dt)
            n = 1
        cp = pltpu.make_async_copy(
            self.out_ref.at[pl.dslice(j, n), :],
            self.arena_ref.at[pl.dslice(self.spec.out_off + ar, n), :],
            self.sem)
        cp.start()
        cp.wait()

    def fori_rows(self, oh: int, body) -> None:
        jax.lax.fori_loop(self.row_lo, self.row_hi, body, 0)


class _StreamStageMem:
    """Streaming accessor for a staged whole-block op: operand blocks were
    DMA'd into packed scratch slots before the body runs (read-all before
    write-all — exactly the blocked kernels' order), the output block is
    staged in its slot and copied back in one DMA."""

    def __init__(self, ref, arena_ref, sem, spec: OpSpec,
                 offs: Tuple[int, ...], out_slot: int):
        self.ref, self.arena_ref, self.sem, self.spec = \
            ref, arena_ref, sem, spec
        self.offs, self.out_slot = offs, out_slot
        self.dt = _jnp_dtype(spec.dtype)
        self.L = spec.rowlen

    def read_t(self, i: int):
        rows, used = self.spec.in_rows[i]
        shape = self.spec.in_shape[i]
        block = self.ref[pl.dslice(self.offs[i], rows), :]
        return _dec_block(block, rows, used, self.L, _addr_in(self.spec, i),
                          _elems(shape)).reshape(shape)

    def write(self, value):
        rows, used = self.spec.out_rows
        self.ref[pl.dslice(self.out_slot, rows), :] = \
            _enc_block(value, rows, used, self.L, self.dt,
                       _addr_out(self.spec))
        cp = pltpu.make_async_copy(
            self.ref.at[pl.dslice(self.out_slot, rows), :],
            self.arena_ref.at[pl.dslice(self.spec.out_off, rows), :],
            self.sem)
        cp.start()
        cp.wait()


def _mem(ref, spec: OpSpec):
    return _BlockMem(ref, spec) if spec.rowlen else _FlatMem(ref, spec)


def _requant(acc, mult: float, zp: int):
    """jnp mirror of repro.core.exec.ops.requantise (same f32 arithmetic)."""
    q = jnp.round(acc.astype(jnp.float32) * jnp.float32(mult)) + zp
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def _dequant(x, scale: float, zp: int):
    return (x.astype(jnp.float32) - zp) * jnp.float32(scale)


def _quant(v, scale: float, zp: int):
    q = jnp.round(v / jnp.float32(scale)) + zp
    return jnp.clip(q, -128, 127).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Kernel bodies — all state lives in the aliased arena (or its staged
# scratch window); the input operand only seeds the initial contents via
# the alias. Bodies are addressing-agnostic: every arena touch goes through
# the mem layer, so the flat, blocked and streaming programs share them.
# ---------------------------------------------------------------------------


def _conv_kernel(mem, w_ref, *, spec: OpSpec):
    ih, iw, ic = spec.in_shape[0][-3:]
    oh, ow, oc = spec.out_shape[-3:]
    kh, kw, sh, sw, dh, dw, ph, pw, mult = spec.meta
    depthwise = spec.kind == "depthwise_conv2d"
    quant = spec.dtype == "i8"

    def body(oy, _):
        if quant:
            x_zp, amult, y_zp = spec.qmeta
            acc = jnp.zeros((ow, oc), jnp.int32)
        else:
            acc = jnp.zeros((ow, oc), jnp.float32)
        for fy in range(kh):                    # static unroll (kh small)
            iy = oy * sh - ph + fy * dh
            row_ok = (iy >= 0) & (iy < ih)
            iy_c = jnp.clip(iy, 0, ih - 1)
            row = mem.read_row(0, iy_c).reshape(iw, ic)
            if quant:
                row = row.astype(jnp.int32) - x_zp
            for fx in range(kw):
                ix = jax.lax.broadcasted_iota(jnp.int32, (ow, 1), 0)
                ix = ix * sw - pw + fx * dw
                valid = (ix >= 0) & (ix < iw) & row_ok
                taps = jnp.take_along_axis(row, jnp.clip(ix, 0, iw - 1),
                                           axis=0)          # (ow, ic)
                taps = jnp.where(valid, taps, 0 if quant else 0.0)
                w = w_ref[fy, fx]
                if quant:
                    w = w.astype(jnp.int32)
                if depthwise:
                    acc += (taps[:, :, None]
                            * w[None, :, :]).reshape(ow, ic * mult)
                else:
                    acc += jnp.dot(
                        taps, w, preferred_element_type=(
                            jnp.int32 if quant else jnp.float32))
        out = _requant(acc, amult, y_zp) if quant else acc
        mem.write_row(oy, out)
        return 0

    mem.fori_rows(oh, body)


def _pool_kernel(mem, *, spec: OpSpec):
    ih, iw, c = spec.in_shape[0][-3:]
    oh, ow, _ = spec.out_shape[-3:]
    kh, kw, sh, sw, ph, pw, mode = spec.meta
    quant = spec.dtype == "i8"

    def body(oy, _):
        if quant:
            acc = jnp.full((ow, c), -2147483647 if mode == "max" else 0,
                           jnp.int32)
        else:
            acc = jnp.full((ow, c), -jnp.inf if mode == "max" else 0.0,
                           jnp.float32)
        cnt = jnp.zeros((ow, 1), jnp.float32)
        for fy in range(kh):
            iy = oy * sh - ph + fy
            row_ok = (iy >= 0) & (iy < ih)
            iy_c = jnp.clip(iy, 0, ih - 1)
            row = mem.read_row(0, iy_c).reshape(iw, c)
            if quant:
                row = row.astype(jnp.int32)
            for fx in range(kw):
                ix = jax.lax.broadcasted_iota(jnp.int32, (ow, 1), 0)
                ix = ix * sw - pw + fx
                valid = (ix >= 0) & (ix < iw) & row_ok
                taps = jnp.take_along_axis(row, jnp.clip(ix, 0, iw - 1),
                                           axis=0)
                if mode == "max":
                    acc = jnp.where(valid, jnp.maximum(acc, taps), acc)
                else:
                    acc = acc + jnp.where(valid, taps, 0 if quant else 0.0)
                    cnt = cnt + valid.astype(jnp.float32)
        if quant:
            x_zp, amult, y_zp = spec.qmeta
            if mode == "avg":
                val = acc.astype(jnp.float32) / jnp.maximum(cnt, 1.0) - x_zp
            else:
                val = acc - x_zp
            out = _requant(val, amult, y_zp)
        else:
            out = acc / jnp.maximum(cnt, 1.0) if mode == "avg" else acc
        mem.write_row(oy, out)
        return 0

    mem.fori_rows(oh, body)


def _elementwise_kernel(mem, *, spec: OpSpec):
    fn = _ELEMENTWISE[spec.meta[0]]
    xs = [mem.read_t(i) for i in range(len(spec.in_shape))]
    if spec.dtype == "i8":
        in_q, (ys, yzp) = spec.qmeta
        xs = [_dequant(x, s, zp) for x, (s, zp) in zip(xs, in_q)]
    if len(xs) == 2 and _elems(spec.in_shape[1]) != _elems(spec.in_shape[0]):
        xs[1] = jnp.broadcast_to(xs[1], xs[0].shape)
    v = fn(*xs).astype(jnp.float32)
    mem.write(_quant(v, ys, yzp) if spec.dtype == "i8" else v)


def _softmax_kernel(mem, *, spec: OpSpec):
    x = mem.read_t(0)
    if spec.dtype == "i8":
        (xs, xzp), (ys, yzp) = spec.qmeta
        x = _dequant(x, xs, xzp)
    e = jnp.exp(x - jnp.max(x, axis=-1, keepdims=True))
    y = e / jnp.sum(e, axis=-1, keepdims=True)
    mem.write(_quant(y, ys, yzp) if spec.dtype == "i8" else y)


def _fully_connected_kernel(mem, w_ref, *, spec: OpSpec):
    idim = spec.in_shape[0][-1]
    x = mem.read_t(0).reshape(-1, idim)
    if spec.dtype == "i8":
        x_zp, amult, y_zp = spec.qmeta
        acc = jnp.dot(x.astype(jnp.int32) - x_zp,
                      w_ref[...].astype(jnp.int32),
                      preferred_element_type=jnp.int32)
        y = _requant(acc, amult, y_zp)
    else:
        y = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    mem.write(y.reshape(spec.out_shape))


def _matmul_kernel(mem, *, spec: OpSpec):
    a = mem.read_t(0).reshape(-1, spec.in_shape[0][-1])
    b = mem.read_t(1)
    if spec.dtype == "i8":
        a_zp, b_zp, amult, y_zp = spec.qmeta
        acc = jnp.dot(a.astype(jnp.int32) - a_zp,
                      b.astype(jnp.int32) - b_zp,
                      preferred_element_type=jnp.int32)
        y = _requant(acc, amult, y_zp)
    else:
        y = jnp.dot(a, b, preferred_element_type=jnp.float32)
    mem.write(y.reshape(spec.out_shape))


def _rescale(x, src, dst):
    """jnp mirror of repro.core.exec.ops.rescale_q (f32 multiplier is baked
    into qmeta by the lowering, so both backends use the identical bits)."""
    (s_zp, mult), (y_zp,) = src, dst
    return _requant(x.astype(jnp.int32) - s_zp, mult, y_zp)


def _concat_kernel(mem, *, spec: OpSpec):
    axis = spec.meta[0]
    xs = [mem.read_t(i) for i in range(len(spec.in_shape))]
    if spec.dtype == "i8":
        in_q, (yzp,) = spec.qmeta
        xs = [_rescale(x, q, (yzp,)) for x, q in zip(xs, in_q)]
    mem.write(jnp.concatenate(xs, axis=axis))


def _pad_kernel(mem, *, spec: OpSpec):
    x = mem.read_t(0)
    if spec.dtype == "i8":
        (x_zp, mult), (y_zp,) = spec.qmeta
        padded = jnp.pad(x, spec.meta[0], constant_values=x_zp)
        mem.write(_rescale(padded, (x_zp, mult), (y_zp,)))
        return
    mem.write(jnp.pad(x, spec.meta[0]))


def _mean_kernel(mem, *, spec: OpSpec):
    x = mem.read_t(0)
    axes = spec.meta[0]
    if spec.dtype == "i8":
        x_zp, amult, y_zp = spec.qmeta
        cnt = 1
        for ax in axes:
            cnt *= x.shape[ax]
        acc = jnp.sum(x.astype(jnp.int32), axis=axes)
        val = acc.astype(jnp.float32) / jnp.float32(cnt) - x_zp
        y = _requant(val, amult, y_zp)
    else:
        y = jnp.mean(x, axis=axes)
    mem.write(y.reshape(spec.out_shape))


_BODIES = {
    "conv2d": _conv_kernel,
    "depthwise_conv2d": _conv_kernel,
    "pool": _pool_kernel,
    "elementwise": _elementwise_kernel,
    "softmax": _softmax_kernel,
    "fully_connected": _fully_connected_kernel,
    "matmul": _matmul_kernel,
    "concat": _concat_kernel,
    "pad": _pad_kernel,
    "mean": _mean_kernel,
}


def _plain_kernel(*refs, spec: OpSpec):
    """Flat/row-blocked kernel: refs are (arena_in, *weights, arena_out);
    the body reads and writes through the aliased output ref."""
    _BODIES[spec.kind](_mem(refs[-1], spec), *refs[1:-1], spec=spec)


def spec_weight_count(spec: OpSpec) -> int:
    """Weight operands a lowered spec consumes (a fused chain consumes all
    of its stages' weights, in stage order)."""
    if spec.kind == "fused":
        return sum(1 for st in spec.stages if st.kind in WEIGHTED_KINDS)
    return 1 if spec.kind in WEIGHTED_KINDS else 0


def _fused_kernel(*refs, spec: OpSpec):
    """Fused band-chain super-kernel (flat or row-blocked program): refs are
    (arena_in, *stage_weights, arena_out, scratch). Stages run in graph
    order against the aliased arena-out ref; chain-internal operands route
    to the VMEM scratch ref per their stage flags, so intermediate bands and
    their halo rows never touch the arena — only the terminal stage (the
    reassembling concat) writes back at the planned offset. Stage order is
    the graph order, so every read of the chain input precedes the terminal
    write: the planner may overlap the chain's input and output via plain
    disjoint liveness."""
    o_ref, scratch = refs[-2], refs[-1]
    w_refs = refs[1:-2]
    wi = 0
    for st in spec.stages:
        nw = 1 if st.kind in WEIGHTED_KINDS else 0
        cls = _RoutedBlockMem if st.rowlen else _RoutedFlatMem
        _BODIES[st.kind](cls(o_ref, scratch, st), *w_refs[wi:wi + nw],
                         spec=st)
        wi += nw


# ---------------------------------------------------------------------------
# Streaming grid programs: arena in pltpu.ANY (HBM), live window in VMEM.
# ---------------------------------------------------------------------------


def _stream_roll_kernel(a_ref, *rest, spec: OpSpec):
    """One output-row tile of a rolling-window conv/dw-conv/pool. Grid step
    ``t`` computes output rows ``[t*tr, min((t+1)*tr, oh))`` (``tr`` image
    rows = one sublane tile of packed arena rows) out of a
    double-buffered VMEM input window whose arena fetch start is the
    planner's static ``win_starts[t]`` (the single source of truth — the
    kernel just indexes the table). The tile-``t+1`` fetch is issued before
    the tile-``t`` wait; that prefetch may race rows the current tile is
    writing back, but those raced rows are never read except through
    clamped+masked taps (the O_s row invariant keeps every *live* read at
    arena rows >= the write frontier), so the overlap is benign. Fetches
    source the aliased *output* ref so the window observes all previous
    write-backs."""
    nw = 1 if spec.kind in WEIGHTED_KINDS else 0
    w_refs, o_ref = rest[:nw], rest[nw]
    in_win, out_buf, in_sems, out_sem = rest[nw + 1:]

    oh = spec.out_shape[-3]
    T = len(spec.win_starts)
    tr, tile_ar = _tile_geom(spec)
    win_in = spec.win_rows - tile_ar
    t = pl.program_id(0)

    def start_of(tt):
        # static select chain over the planner's table (a captured jnp
        # constant is not a legal kernel operand; T is small)
        s = jnp.int32(spec.win_starts[0])
        for i in range(1, T):
            s = jnp.where(tt >= i, jnp.int32(spec.win_starts[i]), s)
        return s

    def fetch(tt):
        slot = jax.lax.rem(tt, 2)
        return pltpu.make_async_copy(
            o_ref.at[pl.dslice(start_of(tt), win_in), :],
            in_win.at[slot],
            in_sems.at[slot])

    @pl.when(t == 0)
    def _():
        fetch(t).start()

    @pl.when(t + 1 < T)
    def _():
        fetch(t + 1).start()

    fetch(t).wait()

    row_lo = t * tr
    row_hi = jnp.minimum(row_lo + tr, oh)
    mem = _StreamRollMem(in_win.at[jax.lax.rem(t, 2)], out_buf, o_ref,
                         out_sem, spec, start_of(t), row_lo, row_hi)
    _BODIES[spec.kind](mem, *w_refs, spec=spec)


def _stream_stage_kernel(a_ref, *rest, spec: OpSpec, offs, out_slot):
    """Staged whole-block op: DMA every operand block from the ANY arena
    into its packed VMEM slot (fetches pipelined over two rotating
    semaphores), run the written-once body against the staged window, then
    copy the output block back in one DMA. Read-all-before-write-all — the
    exact element order of the blocked program, so in-place overlaps are
    handled identically."""
    nw = 1 if spec.kind in WEIGHTED_KINDS else 0
    w_refs, o_ref = rest[:nw], rest[nw]
    buf, in_sems, out_sem = rest[nw + 1:]

    cps = [pltpu.make_async_copy(
        o_ref.at[pl.dslice(spec.in_off[i], rows), :],
        buf.at[pl.dslice(offs[i], rows), :],
        in_sems.at[i % 2])
        for i, (rows, _) in enumerate(spec.in_rows)]
    for cp in cps[:2]:
        cp.start()
    for i, cp in enumerate(cps):
        cp.wait()
        if i + 2 < len(cps):
            cps[i + 2].start()

    mem = _StreamStageMem(buf, o_ref, out_sem, spec, offs, out_slot)
    _BODIES[spec.kind](mem, *w_refs, spec=spec)


def _stream_fused_kernel(a_ref, *rest, spec: OpSpec):
    """Streaming fused band chain: stage every *external* input block from
    the ANY arena into its packed VMEM slot (fetches pipelined over two
    rotating semaphores, exactly the staged program), run ALL chain stages
    entirely inside the scratch buffer (stage specs carry scratch-local slot
    offsets for every operand — internals, externals and the terminal
    output alike), then copy the terminal output block back in one DMA. The
    chain's VMEM residency is the :func:`repro.core.planner.fused_slots`
    ``include_io`` packing = the window schedule's ``win_rows``."""
    nw = spec_weight_count(spec)
    w_refs, o_ref = rest[:nw], rest[nw]
    buf, in_sems, out_sem = rest[nw + 1:]

    cps = [pltpu.make_async_copy(
        o_ref.at[pl.dslice(spec.in_off[i], rows), :],
        buf.at[pl.dslice(spec.in_slots[i], rows), :],
        in_sems.at[i % 2])
        for i, (rows, _) in enumerate(spec.in_rows)]
    for cp in cps[:2]:
        cp.start()
    for i, cp in enumerate(cps):
        cp.wait()
        if i + 2 < len(cps):
            cps[i + 2].start()

    wi = 0
    for st in spec.stages:
        snw = 1 if st.kind in WEIGHTED_KINDS else 0
        _BODIES[st.kind](_BlockMem(buf, st), *w_refs[wi:wi + snw], spec=st)
        wi += snw

    rows, _ = spec.out_rows
    cp = pltpu.make_async_copy(
        buf.at[pl.dslice(spec.out_slot, rows), :],
        o_ref.at[pl.dslice(spec.out_off, rows), :],
        out_sem)
    cp.start()
    cp.wait()


def _apply_stream(arena: jax.Array, spec: OpSpec,
                  weights: Tuple[jax.Array, ...], interpret: bool):
    dt = _jnp_dtype(spec.dtype)
    L = spec.rowlen
    sub = _sub(spec.dtype)
    io_specs = dict(
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)]
        + [pl.BlockSpec(memory_space=pltpu.VMEM)] * len(weights),
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )
    if spec.kind == "fused":                   # band-chain super-kernel
        fn = pl.pallas_call(
            functools.partial(_stream_fused_kernel, spec=spec),
            scratch_shapes=[
                pltpu.VMEM((max(spec.scratch_rows, spec.win_rows), L), dt),
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA(()),
            ],
            **io_specs,
        )
    elif spec.win_starts:                      # rolling conv/dw/pool window
        _, tile_ar = _tile_geom(spec)
        fn = pl.pallas_call(
            functools.partial(_stream_roll_kernel, spec=spec),
            grid=(len(spec.win_starts),),
            scratch_shapes=[
                pltpu.VMEM((2, spec.win_rows - tile_ar, L), dt),
                pltpu.VMEM((tile_ar, L), dt),
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA(()),
            ],
            **io_specs,
        )
    else:                                      # staged whole-block op
        from repro.core.planner import staged_slots  # no import cycle
        offs, out_slot, total = staged_slots(
            [r for r, _ in spec.in_rows], spec.out_rows[0], sub)
        fn = pl.pallas_call(
            functools.partial(_stream_stage_kernel, spec=spec,
                              offs=offs, out_slot=out_slot),
            scratch_shapes=[
                pltpu.VMEM((max(total, spec.win_rows), L), dt),
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA(()),
            ],
            **io_specs,
        )
    return fn(arena, *weights)


def apply_op(arena: jax.Array, spec: OpSpec, weights: Tuple[jax.Array, ...],
             interpret: bool = True) -> jax.Array:
    """Run one op in-place on the shared arena (flat 1-D byte buffer,
    row-blocked 2-D typed buffer, or ANY-space streamed buffer, per the
    spec); returns the (aliased) arena."""
    if spec.win_rows:
        return _apply_stream(arena, spec, weights, interpret)
    if spec.kind == "fused":
        # one launch for the whole chain; intermediates live in the VMEM
        # scratch (typed rows for the blocked program, raw bytes for flat)
        scratch = [pltpu.VMEM((spec.scratch_rows, spec.rowlen),
                              _jnp_dtype(spec.dtype)) if spec.rowlen
                   else pltpu.VMEM((spec.scratch_rows,), jnp.uint8)]
        kernel = functools.partial(_fused_kernel, spec=spec)
    else:
        scratch = []
        kernel = functools.partial(_plain_kernel, spec=spec)
    fn = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={0: 0},            # the arena is donated through
        scratch_shapes=scratch,
        interpret=interpret,
    )
    return fn(arena, *weights)


def lower_program(specs: Tuple[OpSpec, ...], interpret: bool = True):
    """Jit-compiled executor for a spec sequence: ``fn(arena, *weights) ->
    arena``. The arena argument is donated, so together with the per-op
    aliasing the whole network runs in one shared buffer. Cached on the spec
    content — structurally identical plans share the compiled program."""
    return _lower_program_cached(tuple(specs), bool(interpret))


@functools.lru_cache(maxsize=128)
def _lower_program_cached(specs: Tuple[OpSpec, ...], interpret: bool):
    weight_counts = tuple(spec_weight_count(s) for s in specs)

    def run(arena, *wflat):
        i = 0
        for spec, nw in zip(specs, weight_counts):
            arena = apply_op(arena, spec, wflat[i:i + nw], interpret)
            i += nw
        return arena

    return jax.jit(run, donate_argnums=0)
