"""Generalised DMO arena kernels: every supported op as a Pallas call over
ONE shared arena buffer, in either of two arena programs.

This generalises :mod:`repro.kernels.dmo_arena_dwconv` (a single hard-coded
depthwise conv) to the full op set a :class:`~repro.core.planner.Plan` can
contain: conv2d / depthwise_conv2d / pool / elementwise / softmax /
fully_connected / matmul / concat / pad / mean. Each op becomes one
``pl.pallas_call`` whose first operand is the shared arena and whose output
*aliases* it (``input_output_aliases={0: 0}``), so the arena is threaded
in-place through the op sequence — the TPU-VMEM analogue of the paper's SRAM
tensor arena.

Two arena addressings share the same kernel bodies through a small memory
access layer (:class:`_FlatMem` / :class:`_BlockMem`; an :class:`OpSpec`
with ``rowlen == 0`` selects the flat program, ``rowlen > 0`` the blocked
one):

- **flat** — the arena is a 1-D *byte* buffer; operands live at byte
  offsets and kernels bitcast their windows to the tier the spec declares
  (f32 windows / int8 bytes, the quantised tier running int32 accumulation
  plus the float32 requantisation of :mod:`repro.core.exec.ops`). Mixed-
  dtype plans execute in one buffer, but byte-granular dynamic slices fight
  the TPU's (8, 128)/(32, 128) VMEM tilings — this program is
  interpret-mode only.
- **row-blocked** — the arena is a 2-D ``(rows, rowlen)`` buffer *typed* to
  the plan's dtype, laid out by
  :func:`repro.core.planner.legalise_for_blocks`: operands occupy whole
  arena rows at sublane-tile-aligned row offsets, conv/pool walk one image
  row per arena row via ``pl.dslice`` on the row axis, and no bitcasts are
  needed — the same program lowers under ``interpret=False`` (compiled
  mode on a real TPU).

Split row bands (§II.A) need no kernels of their own: a banded conv/pool's
spec carries its band shapes and its explicit band-local pads (a producer
band's leading row pad is *negative* — ``iy = oy*sh - ph + fy*dh`` simply
starts deeper in the full input), so the ordinary row kernels index exactly
the band's rows in both the flat and the row-blocked program.

Safety contract (paper §III.A): kernels read *and* write through the aliased
output ref, and conv/pool walk output rows in ascending index order inside a
sequential ``fori_loop``. Reads for output row ``i`` therefore happen after
the row ``i-1`` store — exactly the element order the safe overlap ``O_s``
was derived against, which is why a planner-approved layout cannot clobber a
live value. In the blocked program a row store clobbers the *whole* arena
row (tiling padding included), which is why the legaliser re-derives each
diagonal distance at row granularity. A parallel grid over rows would break
the guarantee, precisely the paper's multi-threading caveat (§III.F) — keep
the row loop sequential.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: jnp mirrors of repro.core.exec.ops.ELEMENTWISE (same names, same maths).
_ELEMENTWISE = {
    "relu": lambda a: jnp.maximum(a, 0.0),
    "relu6": lambda a: jnp.clip(a, 0.0, 6.0),
    "sigmoid": lambda a: 1.0 / (1.0 + jnp.exp(-a)),
    "identity": lambda a: a,
    "add": lambda a, b: a + b,
    "mul": lambda a, b: a * b,
    "sub": lambda a, b: a - b,
}

#: Op kinds that carry one synthesized weight operand.
WEIGHTED_KINDS = frozenset({"conv2d", "depthwise_conv2d", "fully_connected"})


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Hashable, fully static description of one lowered op: operand
    placements in the shared arena, shapes, the arena dtype tier ("f32" or
    "i8"), and kind-specific parameters (plus quantisation statics for int8
    ops). Two plans with identical layouts produce equal specs, so lowered
    programs are shared.

    ``rowlen == 0`` selects the flat byte program: ``in_off``/``out_off``
    are *byte* offsets into a 1-D uint8 arena. ``rowlen > 0`` selects the
    row-blocked program over a typed ``(rows, rowlen)`` arena: offsets are
    arena *row* indices and ``in_rows``/``out_rows`` carry each operand's
    ``(rows, used-elements-per-row)`` block shape from its
    :class:`~repro.core.planner.BlockLayout`."""

    kind: str
    in_off: Tuple[int, ...]            # byte (flat) | arena-row (blocked)
    in_shape: Tuple[Tuple[int, ...], ...]
    out_off: int
    out_shape: Tuple[int, ...]
    dtype: str = "f32"                 # arena tier: "f32" | "i8"
    meta: Tuple = ()                   # kind-specific statics (see builders)
    qmeta: Tuple = ()                  # int8 statics (zero points, multipliers)
    rowlen: int = 0                    # arena row elements (0 = flat program)
    in_rows: Tuple[Tuple[int, int], ...] = ()  # (rows, used) per input
    out_rows: Tuple[int, int] = ()             # (rows, used) of the output


def _elems(shape: Tuple[int, ...]) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


def _isz(dtype: str) -> int:
    return 1 if dtype == "i8" else 4


def _jnp_dtype(dtype: str):
    return jnp.int8 if dtype == "i8" else jnp.float32


# ---------------------------------------------------------------------------
# Memory access layer: the one place the two arena addressings differ.
# Kernel bodies below are written once against this API.
# ---------------------------------------------------------------------------


class _FlatMem:
    """Flat byte-arena accessor: bitcast typed windows at byte offsets."""

    def __init__(self, ref, spec: OpSpec):
        self.ref, self.spec = ref, spec
        self.isz = _isz(spec.dtype)

    def _read(self, byte_off, elems: int):
        if self.spec.dtype == "i8":
            raw = self.ref[pl.dslice(byte_off, elems)]
            return jax.lax.bitcast_convert_type(raw, jnp.int8)
        raw = self.ref[pl.dslice(byte_off, 4 * elems)].reshape(elems, 4)
        return jax.lax.bitcast_convert_type(raw, jnp.float32)

    def read_t(self, i: int):
        """Input ``i`` as a typed tensor in its view shape."""
        shape = self.spec.in_shape[i]
        return self._read(self.spec.in_off[i], _elems(shape)).reshape(shape)

    def read_row(self, i: int, iy):
        """One image row (W*C elements) of input ``i`` at a traced row
        index."""
        row = _elems(self.spec.in_shape[i][-2:])
        return self._read(self.spec.in_off[i] + iy * row * self.isz, row)

    def _write(self, byte_off, value):
        flat = value.reshape(-1)
        raw = jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)
        self.ref[pl.dslice(byte_off, raw.size)] = raw

    def write(self, value):
        self._write(self.spec.out_off, value)

    def write_row(self, oy, value):
        row = _elems(self.spec.out_shape[-2:])
        self._write(self.spec.out_off + oy * row * self.isz, value)


class _BlockMem:
    """Row-blocked accessor: whole arena rows of a typed (R, L) buffer via
    ``pl.dslice`` on the row axis — no bitcasts, compiled-mode lowerable."""

    def __init__(self, ref, spec: OpSpec):
        self.ref, self.spec = ref, spec
        self.dt = _jnp_dtype(spec.dtype)
        self.L = spec.rowlen

    def read_t(self, i: int):
        rows, used = self.spec.in_rows[i]
        shape = self.spec.in_shape[i]
        block = self.ref[pl.dslice(self.spec.in_off[i], rows), :]
        flat = block[:, :used].reshape(rows * used)
        return flat[:_elems(shape)].reshape(shape)

    def read_row(self, i: int, iy):
        used = _elems(self.spec.in_shape[i][-2:])
        row = self.ref[pl.dslice(self.spec.in_off[i] + iy, 1), :]
        return row.reshape(self.L)[:used]

    def _pad_cols(self, block, rows: int, used: int):
        """Zero-fill each row's tile-padding tail out to the arena row."""
        if used == self.L:
            return block
        return jnp.concatenate(
            [block, jnp.zeros((rows, self.L - used), self.dt)], axis=1)

    def write(self, value):
        rows, used = self.spec.out_rows
        flat = value.reshape(-1).astype(self.dt)
        if flat.size < rows * used:       # dense tail padding
            flat = jnp.concatenate(
                [flat, jnp.zeros(rows * used - flat.size, self.dt)])
        block = self._pad_cols(flat.reshape(rows, used), rows, used)
        self.ref[pl.dslice(self.spec.out_off, rows), :] = block

    def write_row(self, oy, value):
        used = _elems(self.spec.out_shape[-2:])
        row = value.reshape(1, used).astype(self.dt)
        self.ref[pl.dslice(self.spec.out_off + oy, 1), :] = \
            self._pad_cols(row, 1, used)


def _mem(ref, spec: OpSpec):
    return _BlockMem(ref, spec) if spec.rowlen else _FlatMem(ref, spec)


def _requant(acc, mult: float, zp: int):
    """jnp mirror of repro.core.exec.ops.requantise (same f32 arithmetic)."""
    q = jnp.round(acc.astype(jnp.float32) * jnp.float32(mult)) + zp
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def _dequant(x, scale: float, zp: int):
    return (x.astype(jnp.float32) - zp) * jnp.float32(scale)


def _quant(v, scale: float, zp: int):
    q = jnp.round(v / jnp.float32(scale)) + zp
    return jnp.clip(q, -128, 127).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Kernel bodies — all state lives in out_ref (the aliased arena); the input
# operand only seeds its initial contents via the alias. Bodies are
# addressing-agnostic: every arena touch goes through the mem layer.
# ---------------------------------------------------------------------------


def _conv_kernel(_a, w_ref, o_ref, *, spec: OpSpec):
    mem = _mem(o_ref, spec)
    ih, iw, ic = spec.in_shape[0][-3:]
    oh, ow, oc = spec.out_shape[-3:]
    kh, kw, sh, sw, dh, dw, ph, pw, mult = spec.meta
    depthwise = spec.kind == "depthwise_conv2d"
    quant = spec.dtype == "i8"

    def body(oy, _):
        if quant:
            x_zp, amult, y_zp = spec.qmeta
            acc = jnp.zeros((ow, oc), jnp.int32)
        else:
            acc = jnp.zeros((ow, oc), jnp.float32)
        for fy in range(kh):                    # static unroll (kh small)
            iy = oy * sh - ph + fy * dh
            row_ok = (iy >= 0) & (iy < ih)
            iy_c = jnp.clip(iy, 0, ih - 1)
            row = mem.read_row(0, iy_c).reshape(iw, ic)
            if quant:
                row = row.astype(jnp.int32) - x_zp
            for fx in range(kw):
                ix = jax.lax.broadcasted_iota(jnp.int32, (ow, 1), 0)
                ix = ix * sw - pw + fx * dw
                valid = (ix >= 0) & (ix < iw) & row_ok
                taps = jnp.take_along_axis(row, jnp.clip(ix, 0, iw - 1),
                                           axis=0)          # (ow, ic)
                taps = jnp.where(valid, taps, 0 if quant else 0.0)
                w = w_ref[fy, fx]
                if quant:
                    w = w.astype(jnp.int32)
                if depthwise:
                    acc += (taps[:, :, None]
                            * w[None, :, :]).reshape(ow, ic * mult)
                else:
                    acc += jnp.dot(
                        taps, w, preferred_element_type=(
                            jnp.int32 if quant else jnp.float32))
        out = _requant(acc, amult, y_zp) if quant else acc
        mem.write_row(oy, out)
        return 0

    jax.lax.fori_loop(0, oh, body, 0)


def _pool_kernel(_a, o_ref, *, spec: OpSpec):
    mem = _mem(o_ref, spec)
    ih, iw, c = spec.in_shape[0][-3:]
    oh, ow, _ = spec.out_shape[-3:]
    kh, kw, sh, sw, ph, pw, mode = spec.meta
    quant = spec.dtype == "i8"

    def body(oy, _):
        if quant:
            acc = jnp.full((ow, c), -2147483647 if mode == "max" else 0,
                           jnp.int32)
        else:
            acc = jnp.full((ow, c), -jnp.inf if mode == "max" else 0.0,
                           jnp.float32)
        cnt = jnp.zeros((ow, 1), jnp.float32)
        for fy in range(kh):
            iy = oy * sh - ph + fy
            row_ok = (iy >= 0) & (iy < ih)
            iy_c = jnp.clip(iy, 0, ih - 1)
            row = mem.read_row(0, iy_c).reshape(iw, c)
            if quant:
                row = row.astype(jnp.int32)
            for fx in range(kw):
                ix = jax.lax.broadcasted_iota(jnp.int32, (ow, 1), 0)
                ix = ix * sw - pw + fx
                valid = (ix >= 0) & (ix < iw) & row_ok
                taps = jnp.take_along_axis(row, jnp.clip(ix, 0, iw - 1),
                                           axis=0)
                if mode == "max":
                    acc = jnp.where(valid, jnp.maximum(acc, taps), acc)
                else:
                    acc = acc + jnp.where(valid, taps, 0 if quant else 0.0)
                    cnt = cnt + valid.astype(jnp.float32)
        if quant:
            x_zp, amult, y_zp = spec.qmeta
            if mode == "avg":
                val = acc.astype(jnp.float32) / jnp.maximum(cnt, 1.0) - x_zp
            else:
                val = acc - x_zp
            out = _requant(val, amult, y_zp)
        else:
            out = acc / jnp.maximum(cnt, 1.0) if mode == "avg" else acc
        mem.write_row(oy, out)
        return 0

    jax.lax.fori_loop(0, oh, body, 0)


def _elementwise_kernel(_a, o_ref, *, spec: OpSpec):
    mem = _mem(o_ref, spec)
    fn = _ELEMENTWISE[spec.meta[0]]
    xs = [mem.read_t(i) for i in range(len(spec.in_shape))]
    if spec.dtype == "i8":
        in_q, (ys, yzp) = spec.qmeta
        xs = [_dequant(x, s, zp) for x, (s, zp) in zip(xs, in_q)]
    if len(xs) == 2 and _elems(spec.in_shape[1]) != _elems(spec.in_shape[0]):
        xs[1] = jnp.broadcast_to(xs[1], xs[0].shape)
    v = fn(*xs).astype(jnp.float32)
    mem.write(_quant(v, ys, yzp) if spec.dtype == "i8" else v)


def _softmax_kernel(_a, o_ref, *, spec: OpSpec):
    mem = _mem(o_ref, spec)
    x = mem.read_t(0)
    if spec.dtype == "i8":
        (xs, xzp), (ys, yzp) = spec.qmeta
        x = _dequant(x, xs, xzp)
    e = jnp.exp(x - jnp.max(x, axis=-1, keepdims=True))
    y = e / jnp.sum(e, axis=-1, keepdims=True)
    mem.write(_quant(y, ys, yzp) if spec.dtype == "i8" else y)


def _fully_connected_kernel(_a, w_ref, o_ref, *, spec: OpSpec):
    mem = _mem(o_ref, spec)
    idim = spec.in_shape[0][-1]
    x = mem.read_t(0).reshape(-1, idim)
    if spec.dtype == "i8":
        x_zp, amult, y_zp = spec.qmeta
        acc = jnp.dot(x.astype(jnp.int32) - x_zp,
                      w_ref[...].astype(jnp.int32),
                      preferred_element_type=jnp.int32)
        y = _requant(acc, amult, y_zp)
    else:
        y = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    mem.write(y.reshape(spec.out_shape))


def _matmul_kernel(_a, o_ref, *, spec: OpSpec):
    mem = _mem(o_ref, spec)
    a = mem.read_t(0).reshape(-1, spec.in_shape[0][-1])
    b = mem.read_t(1)
    if spec.dtype == "i8":
        a_zp, b_zp, amult, y_zp = spec.qmeta
        acc = jnp.dot(a.astype(jnp.int32) - a_zp,
                      b.astype(jnp.int32) - b_zp,
                      preferred_element_type=jnp.int32)
        y = _requant(acc, amult, y_zp)
    else:
        y = jnp.dot(a, b, preferred_element_type=jnp.float32)
    mem.write(y.reshape(spec.out_shape))


def _rescale(x, src, dst):
    """jnp mirror of repro.core.exec.ops.rescale_q (f32 multiplier is baked
    into qmeta by the lowering, so both backends use the identical bits)."""
    (s_zp, mult), (y_zp,) = src, dst
    return _requant(x.astype(jnp.int32) - s_zp, mult, y_zp)


def _concat_kernel(_a, o_ref, *, spec: OpSpec):
    mem = _mem(o_ref, spec)
    axis = spec.meta[0]
    xs = [mem.read_t(i) for i in range(len(spec.in_shape))]
    if spec.dtype == "i8":
        in_q, (yzp,) = spec.qmeta
        xs = [_rescale(x, q, (yzp,)) for x, q in zip(xs, in_q)]
    mem.write(jnp.concatenate(xs, axis=axis))


def _pad_kernel(_a, o_ref, *, spec: OpSpec):
    mem = _mem(o_ref, spec)
    x = mem.read_t(0)
    if spec.dtype == "i8":
        (x_zp, mult), (y_zp,) = spec.qmeta
        padded = jnp.pad(x, spec.meta[0], constant_values=x_zp)
        mem.write(_rescale(padded, (x_zp, mult), (y_zp,)))
        return
    mem.write(jnp.pad(x, spec.meta[0]))


def _mean_kernel(_a, o_ref, *, spec: OpSpec):
    mem = _mem(o_ref, spec)
    x = mem.read_t(0)
    axes = spec.meta[0]
    if spec.dtype == "i8":
        x_zp, amult, y_zp = spec.qmeta
        cnt = 1
        for ax in axes:
            cnt *= x.shape[ax]
        acc = jnp.sum(x.astype(jnp.int32), axis=axes)
        val = acc.astype(jnp.float32) / jnp.float32(cnt) - x_zp
        y = _requant(val, amult, y_zp)
    else:
        y = jnp.mean(x, axis=axes)
    mem.write(y.reshape(spec.out_shape))


_KERNELS = {
    "conv2d": _conv_kernel,
    "depthwise_conv2d": _conv_kernel,
    "pool": _pool_kernel,
    "elementwise": _elementwise_kernel,
    "softmax": _softmax_kernel,
    "fully_connected": _fully_connected_kernel,
    "matmul": _matmul_kernel,
    "concat": _concat_kernel,
    "pad": _pad_kernel,
    "mean": _mean_kernel,
}


def apply_op(arena: jax.Array, spec: OpSpec, weights: Tuple[jax.Array, ...],
             interpret: bool = True) -> jax.Array:
    """Run one op in-place on the shared arena (flat 1-D byte buffer or
    row-blocked 2-D typed buffer, per the spec); returns the (aliased)
    arena."""
    kernel = functools.partial(_KERNELS[spec.kind], spec=spec)
    fn = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={0: 0},            # the arena is donated through
        interpret=interpret,
    )
    return fn(arena, *weights)


def lower_program(specs: Tuple[OpSpec, ...], interpret: bool = True):
    """Jit-compiled executor for a spec sequence: ``fn(arena, *weights) ->
    arena``. The arena argument is donated, so together with the per-op
    aliasing the whole network runs in one shared buffer. Cached on the spec
    content — structurally identical plans share the compiled program."""
    return _lower_program_cached(tuple(specs), bool(interpret))


@functools.lru_cache(maxsize=128)
def _lower_program_cached(specs: Tuple[OpSpec, ...], interpret: bool):
    weight_counts = tuple(1 if s.kind in WEIGHTED_KINDS else 0 for s in specs)

    def run(arena, *wflat):
        i = 0
        for spec, nw in zip(specs, weight_counts):
            arena = apply_op(arena, spec, wflat[i:i + nw], interpret)
            i += nw
        return arena

    return jax.jit(run, donate_argnums=0)
