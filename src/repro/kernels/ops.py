"""jit'd public wrappers around the Pallas kernels.

``dmo_dwconv2d`` is the end-to-end DMO path: it computes the analytic safe
overlap ``O_s`` with the *paper's* formulas (repro.core.overlap.analytic),
converts it to a row-granular arena offset, lays the input into the shared
arena and runs the in-place kernel. It also reports the arena footprint vs
the two-buffer baseline so tests can assert the memory saving.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.graph import Graph
from repro.core.overlap import safe_overlap
from repro.kernels.dmo_arena_dwconv import dmo_dwconv2d_arena
from repro.kernels.inplace_rmsnorm import rmsnorm_scale_residual_inplace
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.runtime import resolve_interpret


def dwconv_overlap_rows(ih: int, iw: int, c: int, k: int, stride: int,
                        pad: int) -> Tuple[int, int, int]:
    """(d_rows, oh, ow): arena row offset of the input derived from the
    paper's analytic O_s, rounded up to whole output rows (block-granular)."""
    oh = (ih + 2 * pad - k) // stride + 1
    ow = (iw + 2 * pad - k) // stride + 1
    g = Graph("k")
    x = g.tensor("x", (ih, iw, c), 4, "input")
    g.op("depthwise_conv2d", [x], (oh, ow, c),
         dict(kernel=(k, k), stride=(stride, stride),
              padding="same" if pad else "valid", multiplier=1))
    os_bytes = safe_overlap(g.ops[0], 0, method="analytic")
    ob = oh * ow * c * 4
    row_bytes = max(iw, ow) * c * 4
    d_rows = math.ceil((ob - os_bytes) / row_bytes)
    return d_rows, oh, ow


@functools.partial(jax.jit, static_argnames=("stride", "pad", "interpret"))
def _dmo_dwconv2d_jit(x: jax.Array, w: jax.Array, stride: int, pad: int,
                      interpret: bool) -> jax.Array:
    ih, iw, c = x.shape
    k = w.shape[0]
    d_rows, oh, ow = dwconv_overlap_rows(ih, iw, c, k, stride, pad)
    rowlen = max(iw, ow) * c
    rows = max(d_rows + ih, oh)
    arena = jnp.zeros((rows, rowlen), jnp.float32)
    arena = arena.at[d_rows:d_rows + ih, : iw * c].set(x.reshape(ih, iw * c))
    arena = dmo_dwconv2d_arena(arena, w.astype(jnp.float32), ih=ih, iw=iw,
                               c=c, stride=stride, pad=pad, d_rows=d_rows,
                               oh=oh, ow=ow, interpret=interpret)
    return arena[:oh, : ow * c].reshape(oh, ow, c)


def dmo_dwconv2d(x: jax.Array, w: jax.Array, stride: int = 1, pad: int = 0,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Depthwise conv through the shared VMEM arena. x: (IH,IW,C) f32.

    The ``REPRO_DMO_INTERPRET`` default is resolved *before* the jit
    boundary: the concrete bool is the static cache key, so flipping the
    env between calls retraces instead of silently reusing the previous
    lowering."""
    return _dmo_dwconv2d_jit(x, w, stride=stride, pad=pad,
                             interpret=resolve_interpret(interpret))


def dmo_dwconv2d_footprint(ih: int, iw: int, c: int, k: int, stride: int,
                           pad: int) -> Tuple[int, int]:
    """(arena bytes, two-buffer bytes) — the kernel-level memory saving."""
    d_rows, oh, ow = dwconv_overlap_rows(ih, iw, c, k, stride, pad)
    rowlen = max(iw, ow) * c * 4
    return (max(d_rows + ih, oh) * rowlen, ih * iw * c * 4 + oh * ow * c * 4)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _rmsnorm_residual_jit(x, g, r, interpret: bool) -> jax.Array:
    return rmsnorm_scale_residual_inplace(x, g, r, interpret=interpret)


def rmsnorm_residual(x: jax.Array, g: jax.Array, r: jax.Array,
                     interpret: Optional[bool] = None) -> jax.Array:
    """In-place fused residual + RMSNorm: out aliases x (O_s = |out|).
    The interpret default resolves before the jit boundary (see
    :func:`dmo_dwconv2d`)."""
    return _rmsnorm_residual_jit(x, g, r,
                                 interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def _flash_attention_jit(q, k, v, causal: bool, block_q: int, block_k: int,
                         interpret: bool) -> jax.Array:
    return flash_attention_kernel(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=interpret)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Blockwise online-softmax attention. q,k,v: (S,H,D)/(T,H,D). The
    interpret default resolves before the jit boundary (see
    :func:`dmo_dwconv2d`)."""
    return _flash_attention_jit(q, k, v, causal=causal, block_q=block_q,
                                block_k=block_k,
                                interpret=resolve_interpret(interpret))
