"""Blockwise online-softmax (flash) attention Pallas kernel.

Grid: (heads, q blocks); each step owns one (block_q, D) query tile in VMEM
and loops over (block_k, D) KV tiles with the running (m, l, acc) online
softmax — the score matrix never materialises. MXU-aligned tiles
(block sizes multiples of 128 at the model head dims).

This is the serving hot-spot kernel; the pure-JAX `_sdpa_blockwise` in
repro.models.layers is the same algorithm at the jaxpr level (used for the
CPU dry-run lowering), and `ref.attention` is the exact oracle.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, t: int, block_k: int,
            causal: bool, offset: int):
    bq, d = q_ref.shape[-2:]
    q = q_ref[...].reshape(bq, d).astype(jnp.float32) / (d ** 0.5)
    qi = pl.program_id(1)
    m = jnp.full((bq,), NEG, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, d), jnp.float32)
    nb = t // block_k

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T                                       # (bq, bk)
        if causal:
            qpos = offset + qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[:, None] + p @ v
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, nb, body, (m, l, acc))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128,
                           interpret: Optional[bool] = None) -> jax.Array:
    """q: (S,H,D); k,v: (T,H,D) -> (S,H,D). ``interpret=None`` defers to the
    shared ``REPRO_DMO_INTERPRET`` switch (default: interpret mode)."""
    interpret = resolve_interpret(interpret)
    s, h, d = q.shape
    t = k.shape[0]
    bq = min(block_q, s)
    while s % bq:
        bq -= 1
    bk = min(block_k, t)
    while t % bk:
        bk -= 1
    qh = jnp.moveaxis(q, 1, 0)  # (H,S,D)
    kh = jnp.moveaxis(k, 1, 0)
    vh = jnp.moveaxis(v, 1, 0)
    fn = pl.pallas_call(
        functools.partial(_kernel, t=t, block_k=bk, causal=causal,
                          offset=t - s),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        grid=(h, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((1, t, d), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((1, t, d), lambda hi, qi: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda hi, qi: (hi, qi, 0)),
        interpret=interpret,
    )
    out = fn(qh, kh, vh)
    return jnp.moveaxis(out, 0, 1)
