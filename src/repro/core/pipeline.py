"""End-to-end DMO compile pipeline (pass manager + content-addressed cache).

The paper's §II techniques — operation removal (§II.C), operation splitting
(§II.A), graph serialisation (§II.B), diagonal arena planning (§II.D/§IV) and
bit-exact verification (§I) — compose: removal exposes new diagonal cascades,
splitting changes the peak-defining pair, and the serialisation order decides
which tensors the planner can overlap. Each caller re-implementing that
plumbing (build → transform → order → plan → compare → validate) is exactly
the boilerplate this module deletes.

:func:`compile` is the single planning entrypoint::

    from repro.core.pipeline import compile
    plan = compile(graph)                  # default pass chain
    print(plan.report())                   # peak, savings, pass log, layout

Passes are registered with the :func:`register_pass` decorator and are
individually toggleable via ``compile(..., passes=(...))``. Compiled plans
are memoised in a content-addressed cache keyed by a deterministic graph
signature (op kinds, params, tensor shapes/dtypes/kinds/aliasing) plus the
compile options, so re-planning the same model is O(signature) instead of
O(NP-hard search).
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import os
import pathlib
import pickle
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core import exec as X
from repro.core import planner as P
from repro.core.arena import run_reference
from repro.core.graph import Graph, Op, Tensor
from repro.core.removal import removable, remove_concats
from repro.core.serialise import candidate_orders
from repro.core.splitting import auto_split, order_pinned

__all__ = [
    "CompileOptions", "CompiledPlan", "Pass", "auto_budget_s",
    "available_passes", "cache_clear", "cache_info", "compile",
    "compile_many", "default_passes", "graph_signature", "peak_vs_batch",
    "register_pass",
]


# ---------------------------------------------------------------------------
# Graph signatures (content addressing)
# ---------------------------------------------------------------------------


def _canon(v: Any) -> Any:
    if isinstance(v, (list, tuple)):
        return tuple(_canon(x) for x in v)
    if isinstance(v, dict):
        return tuple(sorted((k, _canon(x)) for k, x in v.items()))
    return v


def graph_signature(graph: Graph) -> str:
    """Deterministic content hash of a graph: op kinds + params and tensor
    shapes/dtypes/kinds/alias structure, with tensors numbered in first-use
    order (names are ignored, so a rebuilt identical model hits the cache)."""
    h = hashlib.sha256()
    ids: Dict[int, int] = {}

    def ref(t: Tensor) -> str:
        k = id(t)
        if k not in ids:
            alias = ref(t.alias_of) if t.alias_of is not None else ""
            ids[k] = len(ids)
            # batch folds in only when != 1 so batch-1 hashes (and their
            # persisted disk entries) are stable across this change
            batch = f":b{t.batch}" if t.batch > 1 else ""
            h.update(f"T{ids[k]}:{t.shape}:{t.dtype_bytes}:{t.kind}"
                     f"{batch}:a({alias});".encode())
        return str(ids[k])

    for op in graph.ops:
        ins = ",".join(ref(t) for t in op.inputs)
        outs = ",".join(ref(t) for t in op.outputs)
        h.update(f"O:{op.kind}|{ins}|{outs}|{_canon(op.params)!r};".encode())
    for t in graph.tensors:  # dangling model inputs still occupy the arena
        ref(t)
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Options / state / result
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CompileOptions:
    profile: str = "paper"        # overlap profile: "paper" | "extended"
    method: str = "algorithmic"   # O_s method: analytic/algorithmic/trace/auto
    #: ILS search budget: seconds (>0 enables), or "auto" to derive the
    #: budget from the graph's op/tensor count (see :func:`auto_budget_s`).
    budget_s: Union[float, str] = 0.0
    seed: int = 0
    #: Joint execution-order x overlap search: "auto" (runs whenever a search
    #: budget is set), "on" (forced, with a 1 s floor budget), "off" (the
    #: placement-only plan_search refinement of the fixed serialised order).
    #: Folded into the plan-cache key via :meth:`key` like every option.
    order_search: str = "auto"
    split: str = "auto"           # "auto" (size-gated) | "on" | "off"
    split_max_parts: int = 8
    split_ops_limit: int = 150    # "auto": skip auto_split on larger graphs
    fuse: str = "auto"            # band-chain fusion: "auto" | "on" | "off"
    #: VMEM budget (bytes) the FusePass gates per-chain scratch estimates
    #: against; None = the REPRO_DMO_VMEM_BUDGET env var, else the pallas
    #: backend default (16 MiB).
    fuse_vmem_budget: Optional[int] = None
    verify: str = "auto"          # "auto" | "constraints" | "numeric" | "off"
    backend: str = "numpy"        # executor backend a plan is compiled for
    #: Leading batch axis the plan is compiled for: the graph is rewritten
    #: through :func:`repro.core.graph.with_batch` before any pass runs, so
    #: every row count, O_s distance and streaming window scales with it.
    #: Part of :meth:`key` (``astuple``), so each batch variant is its own
    #: content-addressed cache entry — memory and disk tiers both.
    batch: int = 1

    def key(self) -> str:
        return repr(dataclasses.astuple(self))


def auto_budget_s(graph: Graph) -> float:
    """ILS wall budget derived from graph size (replaces the hand-set
    per-benchmark budgets). One ILS step re-places every tensor against every
    placed tensor, so its cost grows ~T^1.5..2 with the tensor count and a
    fixed wall budget yields ever fewer iterations on the big connected
    graphs — where the search rarely beats the greedy seeds anyway. Target a
    roughly constant iteration count instead: generous on the ~30-tensor
    MobileNets (where the paper's optimal cascades hide), tapering to the
    floor at NasNet scale. Tiny graphs also need less wall time (the
    insertion-order space itself is small), so the budget additionally grows
    ~0.4 s per op from below. Clamped to [0.5, 12] seconds."""
    t = max(1, len(graph.arena_tensors()))
    b = min(0.4 * len(graph.ops), 1e4 / (t * math.sqrt(t)))
    return float(min(12.0, max(0.5, b)))


@dataclasses.dataclass
class PipelineState:
    """Mutable state threaded through the pass chain."""
    original: Graph
    options: CompileOptions
    #: (provenance label, graph) — variants[0] is always the input graph;
    #: transform passes append rewritten graphs.
    variants: List[Tuple[str, Graph]]
    #: candidate execution orders per variant index (serialise pass).
    orders: Dict[int, List[List[Op]]] = dataclasses.field(default_factory=dict)
    baseline: Optional[P.Plan] = None
    #: fixed-order plan_dmo candidates per (variant, order) — computed by
    #: OrderSearchPass when it runs (PlanPass reuses them instead of
    #: re-planning the grid), else by PlanPass itself.
    fixed_plans: Optional[List[Tuple[str, P.Plan]]] = None
    #: the joint order x overlap search's winner (label, plan), competing
    #: against the fixed-order candidates in PlanPass.
    joint: Optional[Tuple[str, P.Plan]] = None
    order_stats: Optional[Dict[str, Any]] = None
    plan: Optional[P.Plan] = None
    winner: str = "input"
    verified: str = "none"
    recompute_elems: int = 0
    log: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class CompiledPlan:
    """Result of :func:`compile`: the winning plan, the non-overlapping
    baseline it is measured against, and the full pass provenance.

    Cache-hit note: a hit returns the memoised result, whose ``original`` /
    ``graph`` / ``plan`` reference the *first* structurally identical graph
    compiled — not necessarily the object you just passed in. Correlate
    through ``compiled.graph`` and ``compiled.plan`` (or
    :meth:`offsets_by_name`), never through your local build's Tensor/Op
    objects."""
    original: Graph
    graph: Graph            # graph the plan executes (possibly transformed)
    plan: P.Plan
    baseline: P.Plan
    passes: Tuple[str, ...]
    log: List[str]
    key: str
    winner: str             # provenance label of the winning variant
    verified: str           # "numeric" | "constraints" | "none"
    recompute_elems: int = 0
    cache_hit: bool = False
    compile_s: float = 0.0
    backend: str = "numpy"      # executor backend this plan was compiled for
    #: telemetry from the joint execution-order x overlap search (None when
    #: the order_search pass was off / skipped): fixed vs joint peaks, move
    #: and promotion counts, wall time, whether the winning order changed.
    order_stats: Optional[Dict[str, Any]] = None

    @property
    def peak_bytes(self) -> int:
        return self.plan.peak_bytes

    def execute(self, inputs=None, weights=None, *, seed: int = 0,
                backend: Optional[str] = None,
                quant: Optional[Any] = None) -> Dict[str, Any]:
        """Run the plan inside its arena on the compiled-for executor backend
        (override with ``backend=``). Inputs/weights default to the
        deterministic synthesis shared by all backends; int8 graphs take a
        :class:`~repro.core.exec.ops.QuantSpec` via ``quant`` (auto-calibrated
        when omitted). Returns the model outputs keyed by tensor name."""
        be = X.get_backend(backend or self.backend)
        return be.execute(self, inputs, weights, seed=seed, quant=quant)

    @property
    def baseline_bytes(self) -> int:
        return self.baseline.peak_bytes

    @property
    def saving_pct(self) -> float:
        if self.baseline_bytes == 0:
            return 0.0
        return 100.0 * (1.0 - self.peak_bytes / self.baseline_bytes)

    def offsets_by_name(self) -> Dict[str, int]:
        """Arena offsets keyed by tensor *name*. On a cache hit the plan's
        Tensor objects belong to the memoised graph, not necessarily the one
        passed to :func:`compile` — names survive that, object identity
        does not."""
        return {t.name: off for t, off in self.plan.offsets.items()}

    def legalised(self) -> Optional[P.BlockPlan]:
        """The plan legalised onto the row-blocked (tiled) arena grid —
        what compiled-mode Pallas execution allocates — or ``None`` when no
        row-blocked arena can express it (mixed dtypes, aggregated
        views)."""
        try:
            return P.legalise_for_blocks(self.plan)
        except ValueError:
            return None

    def report(self) -> str:
        lines = [
            f"# compile({self.original.name}): {self.peak_bytes} bytes "
            f"({self.peak_bytes / 1024:.1f} KB), "
            f"{self.saving_pct:.1f}% below baseline "
            f"{self.baseline_bytes / 1024:.1f} KB [{self.baseline.strategy}]",
            f"  strategy={self.plan.strategy} variant={self.winner} "
            f"backend={self.backend} verified={self.verified} "
            f"cache={'hit' if self.cache_hit else 'miss'} "
            f"compile={self.compile_s * 1e3:.1f} ms",
            f"  passes: {' -> '.join(self.passes)}",
        ]
        bp = self.legalised()
        if bp is not None:
            lines.append(
                f"  row-blocked (tile {bp.tiling[0]}x{bp.tiling[1]}): "
                f"{bp.padded_peak_bytes} bytes "
                f"({bp.padded_peak_bytes / 1024:.1f} KB), "
                f"+{bp.padding_overhead_pct:.1f}% tiling padding over the "
                "byte-granular peak")
        if self.recompute_elems:
            lines.append(f"  recompute: {self.recompute_elems} elements")
        if self.order_stats:
            st = self.order_stats
            lines.append(
                f"  order-search: fixed={st.get('fixed_peak')} -> "
                f"joint={st.get('peak')} "
                f"({st.get('order_accepts', 0)} order moves, "
                f"{st.get('placement_moves', 0)} placement moves, "
                f"{st.get('wall_s', 0.0):.1f}s"
                + (", order changed" if st.get("order_changed") else "")
                + ")")
        lines += [f"  | {entry}" for entry in self.log]
        lines.append(self.plan.report())
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Pass registry (register_pass idiom)
# ---------------------------------------------------------------------------

_PASSES: Dict[str, "Pass"] = {}
_PASS_ORDER: List[str] = []


class Pass:
    """A named, individually toggleable pipeline stage."""
    name: str = ""
    default: bool = True

    def run(self, state: PipelineState) -> None:
        raise NotImplementedError


def register_pass(cls):
    """Class decorator: instantiate and add to the pipeline registry in
    declaration order (which is the default execution order)."""
    inst = cls()
    if not inst.name:
        raise ValueError(f"{cls.__name__} must set a pass name")
    if inst.name in _PASSES:
        raise ValueError(f"duplicate pass {inst.name!r}")
    _PASSES[inst.name] = inst
    _PASS_ORDER.append(inst.name)
    return cls


def available_passes() -> Tuple[str, ...]:
    return tuple(_PASS_ORDER)


def default_passes() -> Tuple[str, ...]:
    return tuple(n for n in _PASS_ORDER if _PASSES[n].default)


# ---------------------------------------------------------------------------
# Passes
# ---------------------------------------------------------------------------


@register_pass
class BaselinePass(Pass):
    """Best non-overlapping plan of the *input* graph — the paper's
    "Original" column, and the floor every compiled plan must beat."""
    name = "baseline"

    def run(self, state: PipelineState) -> None:
        state.baseline = P.plan_original(state.original)
        state.log.append(
            f"baseline: {state.baseline.strategy} "
            f"peak={state.baseline.peak_bytes}")


@register_pass
class RemoveConcatsPass(Pass):
    """§II.C operation removal: elide concats whose inputs can write directly
    into the aggregated tensor (branch outputs become views)."""
    name = "remove_concats"

    def run(self, state: PipelineState) -> None:
        g = state.variants[-1][1]
        n = sum(1 for op in g.ops if removable(g, op))
        if not n:
            state.log.append("remove_concats: nothing removable")
            return
        state.variants.append(("remove_concats", remove_concats(g)))
        state.log.append(f"remove_concats: elided {n} concat(s)")


@register_pass
class SplitPass(Pass):
    """§II.A operation splitting, automated and overlap-aware: greedily
    split the peak-defining conv pair into row bands while the planned peak
    improves, evaluating every candidate with the DMO planner so the chosen
    splits are the ones that compose with the diagonal relaxation (banded
    O_s). Applied to the input graph (splitting through aggregated views is
    not defined). ``split="auto"`` skips graphs above ``split_ops_limit`` —
    auto_split re-plans every candidate, which is expensive on the big
    connected graphs where it never fires anyway."""
    name = "split"

    def run(self, state: PipelineState) -> None:
        opt = state.options
        g = state.variants[0][1]
        if opt.split == "off":
            state.log.append("split: disabled")
            return
        if _has_aliases(g):
            # split_pair's tensor remapping resolves aliases to their
            # storage owner, which collapses a reshape's input and output
            # into one self-producing tensor — not a valid rewrite
            state.log.append("split: skipped (aliased tensors)")
            return
        if opt.split == "auto" and len(g.ops) > opt.split_ops_limit:
            state.log.append(
                f"split: skipped ({len(g.ops)} ops > {opt.split_ops_limit})")
            return
        sg, rc, slog = auto_split(g, max_parts=opt.split_max_parts,
                                  method=opt.method, profile=opt.profile)
        if not slog:
            state.log.append("split: no profitable split")
            return
        state.variants.append(("split", sg))
        state.recompute_elems += rc
        state.log += [f"split: {entry}" for entry in slog]


def _has_aliases(g: Graph) -> bool:
    """Any alias (reshape or view) — the split gate: split_pair's tensor
    remapping resolves aliases to their storage owner, which is not a valid
    rewrite (serialisation handles aliases fine since ``serialise._deps``
    became view-aware)."""
    return any(t.alias_of is not None for t in g.tensors)


def _chain_scratch_bytes(g: Graph, members: List[Op]) -> int:
    """Conservative VMEM-scratch estimate for one candidate fused chain:
    the blocked program's packing (chain-internal scratch rows times the
    chain's widest tile-rounded image row) when the chain is dtype-uniform,
    else the flat byte packing. An estimate only — the backend derives the
    exact packing from the legalised layouts at lowering time — but close
    enough to refuse chains no executor could ever launch."""
    internal = {op.output.storage() for op in members[:-1]}
    dbs = {s.dtype_bytes
           for op in members
           for s in [op.output.storage()]
           + [t.storage() for t in op.inputs]
           if s.kind != "weight"}
    if len(dbs) == 1:
        db = next(iter(dbs))
        sub, lanes = P.TPU_TILES.get(db, (8, 128))
        # batched chains stage every image's rows at once (op-major stages)
        _, total = P.fused_slots(
            members, lambda s: int(s.shape[-3]) * s.batch, round_to=sub)
        width = max(int(s.shape[-2]) * int(s.shape[-1]) for s in internal)
        return total * P._round_up(width, lanes) * db
    _, total = P.fused_slots(members, lambda s: s.nbytes,
                             align=max(s.dtype_bytes for s in internal))
    return total


@register_pass
class FusePass(Pass):
    """Fused band-chain super-kernels: group each split region's band chain
    (producer bands → consumer bands → the reassembling concat, recovered
    from the ``split_src``/``band_pad`` provenance SplitPass stamps) into a
    fused unit the Pallas layer lowers to ONE kernel whose chain-internal
    tensors live in VMEM scratch. The fused variant re-kinds those tensors
    to ``scratch`` so they drop out of arena placement entirely — the
    planned banded peak falls below the O_s-only split peak. Chains whose
    estimated scratch exceeds the VMEM budget are left unfused (no executor
    could launch them); the plain split variant always remains a planning
    candidate."""
    name = "fuse"

    def run(self, state: PipelineState) -> None:
        opt = state.options
        if opt.fuse == "off":
            state.log.append("fuse: disabled")
            return
        from repro.core.splitting import find_band_chains, fuse_chains
        for label, g in list(state.variants):
            if label != "split":
                continue
            chains = find_band_chains(g)
            if not chains:
                state.log.append("fuse: no fusable band chains")
                continue
            budget = self._budget(opt)
            keep: List[List[Op]] = []
            skipped = 0
            for ch in chains:
                est = _chain_scratch_bytes(g, ch)
                if est <= budget:
                    keep.append(ch)
                else:
                    skipped += 1
                    state.log.append(
                        f"fuse: chain {ch[-1].name!r} refused — estimated "
                        f"scratch {est} bytes exceeds the {budget}-byte "
                        "VMEM budget (left unfused)")
            if not keep:
                continue
            fg = fuse_chains(g, keep)
            if fg is None:
                continue
            n_members = sum(len(ch) for ch in keep)
            state.variants.append(("fuse", fg))
            state.log.append(
                f"fuse: {len(keep)} chain(s), {n_members} band ops -> "
                f"{len(keep)} fused kernel(s)"
                + (f"; {skipped} over-budget chain(s) left unfused"
                   if skipped else ""))

    @staticmethod
    def _budget(opt: CompileOptions) -> int:
        if opt.fuse_vmem_budget is not None:
            return int(opt.fuse_vmem_budget)
        env = os.environ.get("REPRO_DMO_VMEM_BUDGET", "").strip()
        if env:
            return int(env)
        from repro.core.exec.pallas_backend import DEFAULT_VMEM_BUDGET
        return DEFAULT_VMEM_BUDGET


@register_pass
class SerialisePass(Pass):
    """§II.B: candidate execution orders (eager / lazy / memory-greedy) per
    variant; the plan pass keeps the best plan over all of them. Since
    ``serialise._deps`` became view-aware, concat-removal variants (whose
    branch ops write into aggregated views) are reordered too instead of
    pinning construction order."""
    name = "serialise"

    def run(self, state: PipelineState) -> None:
        for i, (label, g) in enumerate(state.variants):
            if order_pinned(g):
                # a fused chain's members must stay contiguous in execution
                # order (one kernel per chain, stage weights consecutive) —
                # fused variants keep construction order
                state.log.append(f"serialise[{label}]: skipped "
                                 "(fused chains pin the order)")
                continue
            orders = candidate_orders(g)
            if len(orders) > 1:
                state.orders[i] = orders
                state.log.append(f"serialise[{label}]: {len(orders)} "
                                 "candidate orders")


def _fixed_plan_grid(state: PipelineState) -> List[Tuple[str, P.Plan]]:
    """plan_dmo over every (variant, order) pair — the fixed-order candidate
    grid both OrderSearchPass and PlanPass rank. The non-overlapping
    baseline of the input graph is itself a candidate, so the eventual
    winner is never worse than it."""
    opt = state.options
    cands: List[Tuple[str, P.Plan]] = []
    if state.baseline is not None:
        cands.append(("input", state.baseline))
    for i, (label, g) in enumerate(state.variants):
        # construction order is always a candidate (None); serialise orders
        # augment it, minus exact duplicates
        orders = [None] + [o for o in state.orders.get(i, [])
                           if list(o) != list(g.ops)]
        for order in orders:
            cands.append((label, P.plan_dmo(
                g, order, method=opt.method, profile=opt.profile)))
    return cands


@register_pass
class OrderSearchPass(Pass):
    """Joint execution-order x overlap search (beyond-paper): ILS over the
    product of dependency-respecting linearisations (``serialise.OrderMoves``
    legality, seeded from the serialise heuristics) and insertion-order
    placement, under the same wall budget the placement-only refinement used
    to get. Runs on the *winning* variant of the fixed-order grid — so split
    variants re-enter the joint search whenever splitting wins, while fused
    variants search placement only (chains pin their order). The fixed-order
    candidates stay in ``state.fixed_plans`` as PlanPass's guaranteed
    fallback: order search can never regress a model."""
    name = "order_search"

    def run(self, state: PipelineState) -> None:
        opt = state.options
        if opt.order_search == "off":
            state.log.append("order_search: disabled")
            return
        budget = (auto_budget_s(state.original)
                  if opt.budget_s == "auto" else float(opt.budget_s))
        if budget <= 0 and opt.order_search == "on":
            budget = 1.0  # forced on: minimal search budget
        if budget <= 0:
            state.log.append("order_search: skipped (no search budget)")
            return
        state.fixed_plans = _fixed_plan_grid(state)
        label, fixed = min(state.fixed_plans, key=lambda c: c[1].peak_bytes)
        g = fixed.graph
        vi = next((i for i, (_, vg) in enumerate(state.variants)
                   if vg is g), 0)
        pinned = order_pinned(g)
        seeds = [list(fixed.order), list(g.ops)] + \
            [list(o) for o in state.orders.get(vi, [])]
        plan, stats = P.plan_joint(
            g, seeds, method=opt.method, profile=opt.profile,
            budget_s=budget, seed=opt.seed,
            allow_order_moves=not pinned)
        stats["fixed_peak"] = fixed.peak_bytes
        stats["budget_s"] = budget
        state.joint = (label, plan)
        state.order_stats = stats
        state.log.append(
            f"order_search: joint ILS ({budget:.1f}s"
            f"{', autoscaled' if opt.budget_s == 'auto' else ''}) on "
            f"{label}: fixed={fixed.peak_bytes} -> joint={plan.peak_bytes}"
            + (" [order pinned: placement moves only]" if pinned else
               f" [{stats['order_accepts']} order moves accepted"
               + (", winning order changed" if stats["order_changed"]
                  else "") + "]"))


@register_pass
class PlanPass(Pass):
    """DMO planning over every (variant, order) pair; keeps the lowest-peak
    plan. The baseline is itself a candidate, so the result is never worse
    than the non-overlapping plan of the input graph. Split variants plan
    with the full relaxation like every other variant — band ops carry
    their own banded O_s (explicit band pads), which is how splitting and
    diagonal overlap compose. ``budget_s > 0`` adds an ILS ``plan_search``
    refinement on the winning variant."""
    name = "plan"

    def run(self, state: PipelineState) -> None:
        opt = state.options
        # fixed-order grid: reuse OrderSearchPass's if it ran (nothing is
        # planned twice), else compute it here
        cands = (list(state.fixed_plans) if state.fixed_plans is not None
                 else _fixed_plan_grid(state))
        if state.joint is not None:
            # the joint search's winner competes as one more candidate; on a
            # tie min() keeps the earlier fixed-order plan, which is exactly
            # the never-regress fallback to the serialised order
            cands.append(state.joint)
        label, best = min(cands, key=lambda c: c[1].peak_bytes)
        budget = (auto_budget_s(state.original)
                  if opt.budget_s == "auto" else opt.budget_s)
        if budget > 0 and state.joint is None:
            # order_search off/skipped: the historical placement-only ILS
            # refinement of the winning fixed order
            sp = P.plan_search(best.graph, best.order,
                               method=opt.method, budget_s=budget,
                               seed=opt.seed, profile=opt.profile)
            state.log.append(
                f"plan: ILS search ({budget:.1f}s"
                f"{', autoscaled' if opt.budget_s == 'auto' else ''}) "
                f"-> {sp.peak_bytes}")
            if sp.peak_bytes < best.peak_bytes:
                best = sp
        state.plan, state.winner = best, label
        state.log.append(
            f"plan: {len(cands)} candidate(s), best={best.strategy} "
            f"on {label}, peak={best.peak_bytes}")


#: Numeric verification replays every op row-by-row in NumPy — cap the work.
_NUMERIC_ELEM_LIMIT = 300_000


def _numeric_verifiable(g: Graph) -> bool:
    if X.executability(g) is not None:
        return False
    return sum(t.elems for t in g.arena_tensors()) <= _NUMERIC_ELEM_LIMIT


@register_pass
class VerifyPass(Pass):
    """Plan safety: always the formal no-clobber constraint check; plus the
    bit-exact arena-vs-private-buffers execution (:func:`verify_plan`) when
    the winning graph is executable by the NumPy arena interpreter
    (``verify="numeric"`` forces it and raises when it is not). A winning
    *split* variant is additionally cross-checked against its **unsplit**
    reference — band ops share the source op's weights and calibration, so
    the banded execution must reproduce the original graph's outputs.
    Compiling for the ``pallas`` backend adds a further tier: the plan is
    executed by the pallas backend (interpret mode) and cross-checked
    output-for-output against the numpy arena execution (fp32 tolerance
    where XLA reassociates the accumulation order)."""
    name = "verify"

    def run(self, state: PipelineState) -> None:
        if state.plan is None or state.options.verify == "off":
            return
        state.plan.validate()
        state.verified = "constraints"
        mode = state.options.verify
        if mode == "constraints":
            return
        if not _numeric_verifiable(state.plan.graph):
            if mode == "numeric":
                raise ValueError(
                    "verify='numeric' requested but the winning graph is not "
                    "executable by the arena interpreter (unsupported op "
                    "kind, aggregated views, unsupported arena dtype, or "
                    "too large)")
            state.log.append("verify: constraints only (graph not "
                             "numerically executable)")
            return
        # one reference + one numpy arena execution serve both tiers: the
        # bit-exact numeric check here, and (for backend="pallas") the
        # cross-check below against the same data — no redundant runs.
        # int8 graphs calibrate once (a float reference run) and share the
        # QuantSpec across the reference and every backend.
        opt = state.options
        g = state.plan.graph
        weights = X.synth_weights(g, opt.seed)
        quant = (X.calibrate(g, opt.seed, weights)
                 if X.needs_quant(g) else None)
        inputs = (X.quant_inputs(g, quant, opt.seed) if quant is not None
                  else X.random_inputs(g, opt.seed))
        ref = run_reference(g, inputs, state.plan.order, weights=weights,
                            quant=quant)
        got_np = X.get_backend("numpy").execute(state.plan, inputs, weights,
                                                quant=quant)
        X.compare_outputs(ref, got_np, exact=True, label="numpy arena")
        state.verified = "numeric"
        state.log.append("verify: arena execution bit-exact"
                         + (" (int8 quantised tier)" if quant else ""))
        if state.winner in ("split", "fuse") and g is not state.original \
                and _numeric_verifiable(state.original):
            # split (and fused-split) graphs compute the same network as
            # their unsplit reference (band ops share the source op's
            # weight draw, and calibration pools band ranges — fusion only
            # re-kinds chain internals to scratch, same op sequence), so
            # the arena execution must reproduce the *original* graph's
            # outputs too: f32 bit-exact (band arithmetic replays the
            # reference loop order), int8 to <= 1 LSB (a valid-padded pair
            # can leave intermediate rows no band recomputes, nudging the
            # pooled calibration range)
            w0 = X.synth_weights(state.original, opt.seed)
            q0 = (X.calibrate(state.original, opt.seed, w0)
                  if X.needs_quant(state.original) else None)
            in0 = (X.quant_inputs(state.original, q0, opt.seed)
                   if q0 is not None
                   else X.random_inputs(state.original, opt.seed))
            ref0 = run_reference(state.original, in0, weights=w0, quant=q0)
            X.compare_outputs(ref0, got_np, exact=(quant is None),
                              label="split bands vs unsplit reference")
            state.log.append(
                "verify: split-band execution matches the unsplit "
                "reference" + (" (<= 1 LSB)" if quant else " (bit-exact)"))
        if opt.backend == "pallas":
            # the flat byte program is the lowering reference; the
            # row-blocked program is what compiled mode executes — verify
            # both against the numpy arena semantics
            got_fl = X.get_backend("pallas", layout="flat").execute(
                state.plan, inputs, weights, quant=quant)
            X.compare_outputs(got_np, got_fl, exact=False,
                              label="pallas flat vs numpy")
            tiers = "flat"
            try:
                got_blk = X.get_backend("pallas", layout="blocks").execute(
                    state.plan, inputs, weights, quant=quant)
            except ValueError:
                # mixed-dtype plans have no single-typed row-blocked arena
                state.log.append("verify: row-blocked tier skipped "
                                 "(plan not legalisable)")
            else:
                X.compare_outputs(got_np, got_blk, exact=False,
                                  label="pallas row-blocked vs numpy")
                tiers = "flat + row-blocked"
                # streaming runs the same kernel bodies over DMA'd live
                # windows, so it must agree with the VMEM-resident blocked
                # program bit-for-bit — and with numpy to fp32 tolerance
                try:
                    got_st = X.get_backend(
                        "pallas", mode="streaming", interpret=True).execute(
                        state.plan, inputs, weights, quant=quant)
                except ValueError as e:
                    # live window over the VMEM budget — a real refusal,
                    # not a verification failure
                    state.log.append(f"verify: streaming tier skipped ({e})")
                else:
                    X.compare_outputs(got_blk, got_st, exact=True,
                                      label="pallas streaming vs row-blocked")
                    X.compare_outputs(got_np, got_st, exact=False,
                                      label="pallas streaming vs numpy")
                    tiers += " + streaming"
            state.verified = "numeric+pallas"
            state.log.append("verify: pallas arena execution matches "
                             f"numpy backend ({tiers})")


# ---------------------------------------------------------------------------
# The entrypoint + plan cache (memory tier + optional content-addressed disk
# tier, so benchmark reruns start warm across processes)
# ---------------------------------------------------------------------------

_PLAN_CACHE: Dict[Tuple[str, str], CompiledPlan] = {}
_CACHE_STATS = {"hits": 0, "misses": 0, "disk_hits": 0, "disk_misses": 0}
#: Incremented once per actual pipeline execution (never on a cache hit).
PIPELINE_RUNS = 0
#: Part of the disk key (with the source fingerprint below): a key collision
#: with an older build would silently serve stale plans to benchmark reruns.
_DISK_SCHEMA = "v1"
_CODE_FINGERPRINT: Optional[str] = None


def _code_version() -> str:
    """Content hash of the planning code (repro/core + overlap sources),
    folded into the disk-cache key so ANY planner/pass-chain edit — released
    or just saved in a dev checkout — invalidates persisted plans instead of
    serving results computed by old code."""
    global _CODE_FINGERPRINT
    if _CODE_FINGERPRINT is None:
        h = hashlib.sha256()
        root = pathlib.Path(__file__).resolve().parent
        try:
            for p in sorted(root.rglob("*.py")):
                h.update(p.name.encode())
                h.update(p.read_bytes())
        except OSError:
            pass  # zip/frozen installs: schema tag still guards
        _CODE_FINGERPRINT = h.hexdigest()[:16]
    return _CODE_FINGERPRINT


def _disk_cache_dir() -> pathlib.Path:
    return pathlib.Path(os.environ.get(
        "REPRO_DMO_CACHE_DIR",
        os.path.join(os.path.expanduser("~"), ".cache", "repro-dmo")))


def _disk_enabled(explicit: Optional[bool]) -> bool:
    if explicit is not None:
        return explicit
    return os.environ.get("REPRO_DMO_DISK_CACHE", "").lower() in (
        "1", "true", "yes", "on")


def _disk_path(key: Tuple[str, str]) -> pathlib.Path:
    h = hashlib.sha256(
        f"{_DISK_SCHEMA}:{_code_version()}:{key[0]}:{key[1]}".encode())
    return _disk_cache_dir() / f"{h.hexdigest()}.pkl"


def _disk_load(key: Tuple[str, str]) -> Optional[CompiledPlan]:
    path = _disk_path(key)
    try:
        with open(path, "rb") as f:
            entry = pickle.load(f)
    except Exception:
        # any unreadable/stale entry (corrupt file, renamed classes from an
        # un-bumped schema, ...) must degrade to a cold miss, never crash
        _CACHE_STATS["disk_misses"] += 1
        return None
    if not isinstance(entry, CompiledPlan):
        _CACHE_STATS["disk_misses"] += 1
        return None
    _CACHE_STATS["disk_hits"] += 1
    return entry


def _disk_store(key: Tuple[str, str], entry: CompiledPlan) -> None:
    path = _disk_path(key)
    tmp = path.with_suffix(f".tmp.{os.getpid()}")
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        with open(tmp, "wb") as f:
            pickle.dump(entry, f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)  # atomic: concurrent benchmark shards race here
    except Exception:
        # a cold cache is never an error — unpicklable op params (free-form
        # dicts), full disks, permissions: all degrade to not-persisted
        try:
            tmp.unlink(missing_ok=True)
        except OSError:
            pass


def cache_info() -> Dict[str, Any]:
    return {"size": len(_PLAN_CACHE), "disk_dir": str(_disk_cache_dir()),
            **_CACHE_STATS}


def cache_clear(disk: bool = False) -> None:
    """Clear the in-memory tier and reset counters; ``disk=True`` also
    deletes the persisted entries under the disk cache dir."""
    _PLAN_CACHE.clear()
    for k in _CACHE_STATS:
        _CACHE_STATS[k] = 0
    if disk:
        try:
            # *.tmp.<pid> are orphans of interrupted _disk_store writes
            for pattern in ("*.pkl", "*.tmp.*"):
                for p in _disk_cache_dir().glob(pattern):
                    p.unlink(missing_ok=True)
        except OSError:
            pass


def compile(graph: Graph, *, profile: str = "paper",
            method: str = "algorithmic", budget_s: Union[float, str] = 0.0,
            seed: int = 0, order_search: str = "auto",
            passes: Optional[Sequence[str]] = None,
            split: str = "auto", split_max_parts: int = 8,
            split_ops_limit: int = 150, fuse: str = "auto",
            fuse_vmem_budget: Optional[int] = None, verify: str = "auto",
            backend: str = "numpy", batch: int = 1, cache: bool = True,
            disk_cache: Optional[bool] = None) -> CompiledPlan:
    """Compile ``graph`` to an arena plan through the registered pass chain.

    Args:
        graph: tensor-op graph (see :mod:`repro.core.graph`).
        profile: overlap profile — ``"paper"`` (only the op kinds the paper
            derives O_s for) or ``"extended"``.
        method: O_s calculator (``analytic``/``algorithmic``/``trace``/``auto``).
        budget_s: wall-clock budget for the ILS search refinement (0 = off,
            fully deterministic pipeline), or ``"auto"`` to derive the budget
            from the graph's op/tensor count (:func:`auto_budget_s`).
        seed: RNG seed for every stochastic search stage (the joint order
            search and plan_search). Part of the plan-cache key: a cached
            plan is never returned for different search settings.
        order_search: joint execution-order x overlap search mode —
            ``"auto"`` runs the joint ILS over (linearisation, placement)
            whenever a search budget is set, ``"on"`` forces it (1 s floor
            budget), ``"off"`` restores the placement-only ILS refinement
            of the fixed serialised order.
        passes: pass names to run, in order (default:
            :func:`default_passes`). Unknown names raise.
        split: operation-splitting mode (``auto``/``on``/``off``);
            ``split_ops_limit`` is the op-count gate for ``auto``.
        fuse: band-chain fusion mode (``auto``/``on``/``off``): group each
            split region's band chain into one fused super-kernel whose
            intermediates live in VMEM scratch instead of the arena.
            ``fuse_vmem_budget`` (bytes) overrides the per-chain scratch
            gate (default: ``REPRO_DMO_VMEM_BUDGET`` env, else 16 MiB);
            over-budget chains are left unfused.
        verify: verification mode (``auto``/``constraints``/``numeric``/``off``).
        batch: leading batch axis to compile the plan for (default 1). The
            graph is rewritten through :func:`repro.core.graph.with_batch`
            before any pass runs; every pass, the planner, the legaliser and
            the verify tiers then operate on the batched graph, and the
            batch is folded into the plan-cache key (memory + disk).
        backend: executor backend the plan is compiled for (``"numpy"`` or
            ``"pallas"``); ``"pallas"`` adds a verify tier cross-checking
            *both* pallas arena programs — the flat byte arena and the
            row-blocked (tiled) arena of
            :func:`repro.core.planner.legalise_for_blocks`, the program
            compiled mode executes — against the numpy backend, and
            ``CompiledPlan.execute()`` runs on this backend by default
            (interpret vs compiled mode follows ``REPRO_DMO_INTERPRET``).
        cache: look up / populate the content-addressed plan cache.
        disk_cache: persist/look up plans on disk under
            ``$REPRO_DMO_CACHE_DIR`` (default ``~/.cache/repro-dmo``) so
            reruns in fresh processes start warm. ``None`` defers to the
            ``REPRO_DMO_DISK_CACHE`` env toggle (default off).
            ``cache=False`` disables both tiers; combining it with an
            explicit ``disk_cache=True`` raises.

    Returns:
        A :class:`CompiledPlan`. Cache hits return the memoised result
        (``cache_hit=True``) without re-running any pass — its graph/plan
        objects belong to the first structurally identical compile (see the
        :class:`CompiledPlan` cache-hit note).
    """
    if profile not in ("paper", "extended"):
        raise ValueError(f"unknown overlap profile {profile!r} "
                         "(expected 'paper' or 'extended')")
    if method not in ("auto", "analytic", "algorithmic", "trace"):
        raise ValueError(f"unknown O_s method {method!r}")
    if split not in ("auto", "on", "off"):
        raise ValueError(f"unknown split mode {split!r}")
    if fuse not in ("auto", "on", "off"):
        raise ValueError(f"unknown fuse mode {fuse!r}")
    if verify not in ("auto", "constraints", "numeric", "off"):
        raise ValueError(f"unknown verify mode {verify!r}")
    if order_search not in ("auto", "on", "off"):
        raise ValueError(f"unknown order_search mode {order_search!r}")
    if backend not in X.available_backends():
        raise ValueError(f"unknown executor backend {backend!r}; "
                         f"available: {X.available_backends()}")
    if budget_s != "auto" and not (isinstance(budget_s, (int, float))
                                   and not isinstance(budget_s, bool)
                                   and budget_s >= 0):
        raise ValueError(f"budget_s must be >= 0 or 'auto', got {budget_s!r}")
    if disk_cache and not cache:
        raise ValueError("disk_cache=True requires cache=True "
                         "(cache=False disables all caching)")
    if not isinstance(batch, int) or isinstance(batch, bool) or batch < 1:
        raise ValueError(f"batch must be an int >= 1, got {batch!r}")
    if batch > 1:
        from repro.core.graph import with_batch
        graph = with_batch(graph, batch)
    opts = CompileOptions(profile=profile, method=method, budget_s=budget_s,
                          seed=seed, order_search=order_search, split=split,
                          split_max_parts=split_max_parts,
                          split_ops_limit=split_ops_limit, fuse=fuse,
                          fuse_vmem_budget=fuse_vmem_budget, verify=verify,
                          backend=backend, batch=batch)
    names = tuple(passes) if passes is not None else default_passes()
    unknown = [n for n in names if n not in _PASSES]
    if unknown:
        raise ValueError(f"unknown pass(es) {unknown}; "
                         f"available: {available_passes()}")
    t0 = time.perf_counter()
    key = (graph_signature(graph), opts.key() + repr(names))
    use_disk = cache and _disk_enabled(disk_cache)
    if cache and key in _PLAN_CACHE:
        _CACHE_STATS["hits"] += 1
        entry = _PLAN_CACHE[key]
        if use_disk and not _disk_path(key).exists():
            _disk_store(key, entry)  # explicit persist of a warm entry
        return dataclasses.replace(entry, cache_hit=True,
                                   log=list(entry.log),
                                   compile_s=time.perf_counter() - t0)
    _CACHE_STATS["misses"] += 1
    if use_disk:
        entry = _disk_load(key)
        if entry is not None:
            _PLAN_CACHE[key] = entry
            return dataclasses.replace(entry, cache_hit=True,
                                       log=list(entry.log),
                                       compile_s=time.perf_counter() - t0)

    global PIPELINE_RUNS
    PIPELINE_RUNS += 1
    state = PipelineState(original=graph, options=opts,
                          variants=[("input", graph)])
    for n in names:
        _PASSES[n].run(state)
    if state.plan is None:  # "plan" not in the chain: fall back to baseline
        if state.baseline is None:
            state.baseline = P.plan_original(graph)
        state.plan = state.baseline
        state.winner = "input"
        if "verify" in names:  # honour the verify contract for the fallback
            _PASSES["verify"].run(state)
    if state.baseline is None:
        state.baseline = state.plan
    result = CompiledPlan(
        original=graph, graph=state.plan.graph, plan=state.plan,
        baseline=state.baseline, passes=names, log=state.log, key=key[0],
        winner=state.winner, verified=state.verified,
        recompute_elems=(state.recompute_elems
                         if state.winner in ("split", "fuse") else 0),
        compile_s=time.perf_counter() - t0, backend=backend,
        order_stats=state.order_stats)
    if cache:
        _PLAN_CACHE[key] = result
        if use_disk:
            _disk_store(key, result)
        # hand out a copy of the mutable log so caller edits can't poison
        # the cached entry (the hit path copies symmetrically)
        return dataclasses.replace(result, log=list(result.log))
    return result


# ---------------------------------------------------------------------------
# Batch sweeps + multi-process compilation (the serving-runtime front door)
# ---------------------------------------------------------------------------


def peak_vs_batch(graph: Graph, batches: Sequence[int] = (1, 2, 4, 8),
                  **compile_kwargs) -> List[Dict[str, Any]]:
    """Compile ``graph`` at every batch in ``batches`` and tabulate the
    memory-vs-batch trade curve a server picks its batch variant from. Each
    compile runs the full pass chain — ``Plan.validate`` re-checks the
    no-clobber constraints at every swept batch — and hits the plan cache on
    reruns. Returns one row per batch: byte peak, per-image peak, padded
    (row-blocked) peak when the plan legalises, and the ratio to ``batch *
    peak(1)``. The ratio is <= 1.0 whenever batch 1 and batch b compile
    the same graph variant (the scaled batch-1 candidate inside
    ``plan_dmo`` guarantees it); it can exceed 1.0 slightly when the VMEM
    budget refuses a fused chain only at the larger batch (batched scratch
    is b x bigger), forcing the bands back into the arena — e.g.
    mobilenet_v2_1.0_224 at batch 8 (+2.5%)."""
    rows: List[Dict[str, Any]] = []
    peak1: Optional[int] = None
    for b in sorted(set(int(x) for x in batches)):
        cp = compile(graph, batch=b, **compile_kwargs)
        if b == 1:
            peak1 = cp.peak_bytes
        bp = cp.legalised()
        rows.append({
            "batch": b,
            "peak_bytes": cp.peak_bytes,
            "per_image_bytes": -(-cp.peak_bytes // b),
            "baseline_bytes": cp.baseline_bytes,
            "saving_pct": round(cp.saving_pct, 2),
            "padded_peak_bytes": (bp.padded_peak_bytes
                                  if bp is not None else None),
            "peak_ratio_vs_b1": (round(cp.peak_bytes / (b * peak1), 4)
                                 if peak1 else None),
            "verified": cp.verified,
        })
    return rows


def _compile_many_worker(job: Tuple[Graph, int, Dict[str, Any]]
                         ) -> Dict[str, Any]:
    """One (graph, batch) compile in a worker process. Module-level (spawn
    pickling); reports per-job disk-cache deltas so the parent can prove
    cross-process sharing."""
    graph, batch, kwargs = job
    before = dict(_CACHE_STATS)
    t0 = time.perf_counter()
    cp = compile(graph, batch=batch, **kwargs)
    return {
        "graph": graph.name,
        "batch": batch,
        "peak_bytes": cp.peak_bytes,
        "baseline_bytes": cp.baseline_bytes,
        "saving_pct": round(cp.saving_pct, 2),
        "verified": cp.verified,
        "cache_hit": cp.cache_hit,
        "disk_hits": _CACHE_STATS["disk_hits"] - before["disk_hits"],
        "disk_misses": _CACHE_STATS["disk_misses"] - before["disk_misses"],
        "wall_s": round(time.perf_counter() - t0, 4),
    }


def compile_many(graphs: Sequence[Graph], batches: Sequence[int] = (1,),
                 workers: int = 2, **compile_kwargs) -> List[Dict[str, Any]]:
    """Fan the ``graphs x batches`` compile grid across ``workers``
    processes sharing the content-addressed disk plan-cache (process-safe:
    :func:`_disk_store` writes via temp file + atomic ``os.replace``, so
    concurrent writers of one key race benignly to an identical entry).

    ``disk_cache=True`` is the default here — it is the only channel worker
    processes share results through; pass ``disk_cache=False`` to measure
    cold compiles. ``workers <= 1`` runs inline (no subprocess), which the
    deterministic tests use. Returns one picklable summary dict per (graph,
    batch) job, in grid order."""
    kwargs = dict(compile_kwargs)
    kwargs.setdefault("disk_cache", True)
    jobs = [(g, int(b), kwargs) for g in graphs for b in batches]
    if workers <= 1:
        return [_compile_many_worker(j) for j in jobs]
    import multiprocessing as mp
    ctx = mp.get_context("spawn")
    with ctx.Pool(processes=min(workers, len(jobs) or 1)) as pool:
        return pool.map(_compile_many_worker, jobs)
