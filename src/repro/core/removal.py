"""Operation removal (paper §II.C): concat/pack elision.

Element re-arrangement ops like concat define the peak memory of models such
as SqueezeNet — two copies of the same elements (the branch outputs and the
aggregated tensor) are live at once. If upstream ops can write *directly
into* the aggregated tensor, the copies disappear. TFLite Micro cannot (its
offset function is contiguous-only); the paper notes it "could be added with
a small change to the memory offset function". Here the graph IR supports
it natively: a concat input becomes a *view* into the concat output
(``Tensor.alias_of`` + ``alias_offset``), its producer writes straight into
the aggregated allocation, and the concat op disappears.

The paper also notes this changes the producers' ``O_s`` computation (their
write stride changes); we take the conservative route the paper implies:
producers that write into an aggregated view get ``O_s = 0`` (the overlap
relaxation is dropped for them — see ``_compute_overlaps``' alias check).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.graph import Graph, Op, Tensor


def removable(g: Graph, op: Op) -> bool:
    """A concat is removable when each input is produced by exactly one op,
    consumed only by this concat, and is not itself a view."""
    if op.kind != "concat":
        return False
    for t in op.inputs:
        s = t.storage()
        if s.kind in ("input", "weight") or t.alias_of is not None:
            return False
        consumers = [o for o in g.ops
                     if s in [x.storage() for x in o.inputs]]
        if consumers != [op]:
            return False
    return True


def remove_concats(g: Graph) -> Graph:
    """Return a new graph with every removable concat elided."""
    ng = Graph(g.name + "_noconcat")
    ng.batch = g.batch
    mapping: Dict[Tensor, Tensor] = {}

    def map_t(t: Tensor) -> Tensor:
        s = t.storage()
        if s not in mapping:
            mapping[s] = ng.tensor(s.name, s.shape, s.dtype_bytes, s.kind)
        return mapping[s]

    to_remove = [op for op in g.ops if removable(g, op)]
    view_of: Dict[Tensor, tuple] = {}   # branch storage -> (concat out, off)
    for op in to_remove:
        out = map_t(op.output)
        axis = op.params.get("axis", -1)
        ndim = len(op.output.shape)
        if axis < 0:
            axis += ndim
        # element offset of each branch within the aggregated tensor: exact
        # for the outermost axis; inner-axis concats are strided views (the
        # "offset function change") — the view still owns no storage.
        off = 0
        inner = 1
        for d in range(axis + 1, ndim):
            inner *= op.output.shape[d]
        for t in op.inputs:
            s = t.storage()
            view_of[s] = (out, off * inner if axis == 0 else 0)
            off += t.shape[axis]

    for op in g.ops:
        if op in to_remove:
            continue
        ins: List[Tensor] = []
        for t in op.inputs:
            s = t.storage()
            if s in view_of:
                parent, off = view_of[s]
                v = ng.tensor(f"{s.name}_view", s.shape, s.dtype_bytes,
                              "intermediate", alias_of=parent)
                ins.append(v)
            else:
                ins.append(map_t(t))
        outs: List[Tensor] = []
        for t in op.outputs:
            s = t.storage()
            if s in view_of:
                parent, off = view_of[s]
                v = ng.tensor(f"{s.name}_view", s.shape, s.dtype_bytes,
                              "intermediate", alias_of=parent)
                outs.append(v)
            else:
                outs.append(map_t(t))
        ng.add(Op(op.kind, ins, outs, dict(op.params), op.name))
    ng.validate()
    return ng
