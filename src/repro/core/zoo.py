"""The paper's eleven evaluated models, rebuilt shape-for-shape (§IV).

Graphs carry exact tensor shapes (batch 1, NHWC) and dtype widths; weights
are excluded from the arena exactly as in the paper. Activations are fused
into the producing conv (TFLite convention), so they do not create tensors —
explicit ``elementwise`` ops appear only where a real intermediate exists
(residual adds, pre-activation relus).

Builders: MobileNet v1 (4 variants), MobileNet v2 (2 variants), Inception v4,
Inception-ResNet v2, NasNet Mobile, DenseNet 121, ResNet 50 v2.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.core.graph import Graph, Tensor, conv_out_dim


def _make_divisible(v: float, divisor: int = 8, min_value: Optional[int] = None) -> int:
    if min_value is None:
        min_value = divisor
    new_v = max(min_value, int(v + divisor / 2) // divisor * divisor)
    if new_v < 0.9 * v:
        new_v += divisor
    return new_v


def _name(base: str, dtype_bytes: int) -> str:
    """Graph name with the dtype tag every builder shares (8-bit models are
    the paper's flagship rows and, since the dtype-aware executor layer,
    runnable — the tag keeps reports/benchmarks self-describing)."""
    return base + ("_8bit" if dtype_bytes == 1 else "")


class _B:
    """Builder helper around a Graph, NHWC batch-1."""

    def __init__(self, name: str, dtype_bytes: int = 4):
        self.g = Graph(_name(name, dtype_bytes))
        self.db = dtype_bytes

    def input(self, h: int, w: int, c: int, name: str = "input") -> Tensor:
        return self.g.tensor(name, (h, w, c), self.db, "input")

    def conv(self, x: Tensor, oc: int, k=3, s: int = 1,
             padding: str = "same", name: str = "") -> Tensor:
        kh, kw = (k, k) if isinstance(k, int) else k
        h, w, _ = x.shape
        oh, ow = conv_out_dim(h, kh, s, padding), conv_out_dim(w, kw, s, padding)
        return self.g.op("conv2d", [x], (oh, ow, oc),
                         dict(kernel=(kh, kw), stride=(s, s), padding=padding),
                         name=name)

    def dw(self, x: Tensor, k: int = 3, s: int = 1, padding: str = "same",
           mult: int = 1, name: str = "") -> Tensor:
        h, w, c = x.shape
        oh, ow = conv_out_dim(h, k, s, padding), conv_out_dim(w, k, s, padding)
        return self.g.op("depthwise_conv2d", [x], (oh, ow, c * mult),
                         dict(kernel=(k, k), stride=(s, s), padding=padding,
                              multiplier=mult), name=name)

    def sep(self, x: Tensor, oc: int, k: int = 3, s: int = 1,
            padding: str = "same", name: str = "") -> Tensor:
        return self.conv(self.dw(x, k, s, padding, name=name + "_dw"), oc, 1, 1,
                         "same", name=name + "_pw")

    def pool(self, x: Tensor, k: int, s: int, padding: str = "valid",
             mode: str = "avg", name: str = "") -> Tensor:
        h, w, c = x.shape
        oh, ow = conv_out_dim(h, k, s, padding), conv_out_dim(w, k, s, padding)
        return self.g.op("pool", [x], (oh, ow, c),
                         dict(kernel=(k, k), stride=(s, s), padding=padding,
                              mode=mode), name=name)

    def add(self, a: Tensor, b: Tensor, name: str = "") -> Tensor:
        return self.g.op("elementwise", [a, b], a.shape, dict(fn="add"), name=name)

    def relu(self, x: Tensor, name: str = "") -> Tensor:
        return self.g.op("elementwise", [x], x.shape, dict(fn="relu"), name=name)

    def concat(self, xs: Sequence[Tensor], name: str = "") -> Tensor:
        h, w, _ = xs[0].shape
        c = sum(t.shape[-1] for t in xs)
        return self.g.op("concat", list(xs), (h, w, c), dict(axis=-1), name=name)

    def head(self, x: Tensor, classes: int = 1000) -> Graph:
        h, w, c = x.shape
        x = self.g.op("mean", [x], (c,), dict(axes=(0, 1)), name="gap")
        x = self.g.op("fully_connected", [x], (classes,), name="logits")
        self.g.op("softmax", [x], (classes,), name="prob", out_kind="output")
        self.g.validate()
        return self.g


# ---------------------------------------------------------------------------
# MobileNet v1 / v2
# ---------------------------------------------------------------------------


def mobilenet_v1(alpha: float = 1.0, res: int = 224, dtype_bytes: int = 4,
                 external_input: bool = False) -> Graph:
    """``external_input``: model input lives outside the arena (e.g. a
    camera DMA buffer) — the convention of the paper's §II.A example."""
    b = _B(f"mobilenet_v1_{alpha}_{res}", dtype_bytes)
    c = lambda ch: max(8, int(ch * alpha))
    x = b.input(res, res, 3)
    if external_input:
        x.kind = "weight"
    x = b.conv(x, c(32), 3, 2, name="conv1")
    plan = [(1, 64), (2, 128), (1, 128), (2, 256), (1, 256), (2, 512),
            (1, 512), (1, 512), (1, 512), (1, 512), (1, 512), (2, 1024), (1, 1024)]
    for i, (s, ch) in enumerate(plan):
        x = b.dw(x, 3, s, name=f"dw{i + 1}")
        x = b.conv(x, c(ch), 1, 1, name=f"pw{i + 1}")
    return b.head(x)


def mobilenet_v2(alpha: float = 1.0, res: int = 224, dtype_bytes: int = 4) -> Graph:
    b = _B(f"mobilenet_v2_{alpha}_{res}", dtype_bytes)
    x = b.input(res, res, 3)
    first = _make_divisible(32 * alpha)
    x = b.conv(x, first, 3, 2, name="conv1")
    # (expansion t, channels c, repeats n, first stride s)
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    blk = 0
    for t, ch, n, s0 in cfg:
        oc = _make_divisible(ch * alpha)
        for i in range(n):
            s = s0 if i == 0 else 1
            inp = x
            ic = x.shape[-1]
            h = x
            if t != 1:
                h = b.conv(h, ic * t, 1, 1, name=f"b{blk}_expand")
            h = b.dw(h, 3, s, name=f"b{blk}_dw")
            h = b.conv(h, oc, 1, 1, name=f"b{blk}_project")
            if s == 1 and ic == oc:
                h = b.add(h, inp, name=f"b{blk}_add")
            x = h
            blk += 1
    last = _make_divisible(1280 * alpha) if alpha > 1.0 else 1280
    x = b.conv(x, last, 1, 1, name="conv_last")
    return b.head(x)


# ---------------------------------------------------------------------------
# ResNet 50 v2 (pre-activation)
# ---------------------------------------------------------------------------


def resnet50_v2(res: int = 224, dtype_bytes: int = 4) -> Graph:
    b = _B("resnet50_v2", dtype_bytes)
    x = b.input(res, res, 3)
    x = b.conv(x, 64, 7, 2, name="conv1")
    x = b.pool(x, 3, 2, "same", "max", name="pool1")
    stages = [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]
    bi = 0
    for width, blocks, stride0 in stages:
        for i in range(blocks):
            s = stride0 if i == 0 else 1
            pre = b.relu(x, name=f"r{bi}_preact")           # BN folded, relu real
            if i == 0:
                shortcut = b.conv(pre, width * 4, 1, s, name=f"r{bi}_short")
            else:
                shortcut = x
            h = b.conv(pre, width, 1, s, name=f"r{bi}_c1")
            h = b.conv(h, width, 3, 1, name=f"r{bi}_c2")
            h = b.conv(h, width * 4, 1, 1, name=f"r{bi}_c3")
            x = b.add(h, shortcut, name=f"r{bi}_add")
            bi += 1
    x = b.relu(x, name="postact")
    return b.head(x)


# ---------------------------------------------------------------------------
# DenseNet 121
# ---------------------------------------------------------------------------


def densenet121(res: int = 224, dtype_bytes: int = 4, growth: int = 32) -> Graph:
    b = _B("densenet121", dtype_bytes)
    x = b.input(res, res, 3)
    x = b.conv(x, 64, 7, 2, name="conv1")
    x = b.pool(x, 3, 2, "same", "max", name="pool1")
    li = 0
    for bi, layers in enumerate([6, 12, 24, 16]):
        for _ in range(layers):
            h = b.relu(x, name=f"d{li}_preact")
            h = b.conv(h, 4 * growth, 1, 1, name=f"d{li}_c1")
            h = b.conv(h, growth, 3, 1, name=f"d{li}_c2")
            x = b.concat([x, h], name=f"d{li}_cat")
            li += 1
        if bi < 3:  # transition
            h = b.relu(x, name=f"t{bi}_preact")
            h = b.conv(h, x.shape[-1] // 2, 1, 1, name=f"t{bi}_c")
            x = b.pool(h, 2, 2, "valid", "avg", name=f"t{bi}_pool")
    x = b.relu(x, name="postact")
    return b.head(x)


# ---------------------------------------------------------------------------
# Inception v4 & Inception-ResNet v2 (Szegedy et al., 2017)
# ---------------------------------------------------------------------------


def _inception_stem(b: _B, x: Tensor) -> Tensor:
    x = b.conv(x, 32, 3, 2, "valid", name="stem_c1")          # 149
    x = b.conv(x, 32, 3, 1, "valid", name="stem_c2")          # 147
    x = b.conv(x, 64, 3, 1, "same", name="stem_c3")           # 147
    p = b.pool(x, 3, 2, "valid", "max", name="stem_p1")       # 73
    c = b.conv(x, 96, 3, 2, "valid", name="stem_c4")          # 73
    x = b.concat([p, c], name="stem_cat1")                     # 73x160
    a = b.conv(x, 64, 1, 1, name="stem_a1")
    a = b.conv(a, 96, 3, 1, "valid", name="stem_a2")          # 71
    d = b.conv(x, 64, 1, 1, name="stem_b1")
    d = b.conv(d, 64, (1, 7), 1, "same", name="stem_b2")
    d = b.conv(d, 64, (7, 1), 1, "same", name="stem_b3")
    d = b.conv(d, 96, 3, 1, "valid", name="stem_b4")          # 71
    x = b.concat([a, d], name="stem_cat2")                     # 71x192
    p = b.pool(x, 3, 2, "valid", "max", name="stem_p2")       # 35
    c = b.conv(x, 192, 3, 2, "valid", name="stem_c5")         # 35
    return b.concat([p, c], name="stem_cat3")                  # 35x384


def inception_v4(res: int = 299, dtype_bytes: int = 4) -> Graph:
    b = _B("inception_v4", dtype_bytes)
    x = b.input(res, res, 3)
    x = _inception_stem(b, x)

    def block_a(x, i):
        b1 = b.conv(x, 96, 1, 1, name=f"a{i}_b1")
        b2 = b.conv(b.conv(x, 64, 1, 1, name=f"a{i}_b2a"), 96, 3, 1, name=f"a{i}_b2b")
        b3 = b.conv(b.conv(b.conv(x, 64, 1, 1, name=f"a{i}_b3a"), 96, 3, 1,
                           name=f"a{i}_b3b"), 96, 3, 1, name=f"a{i}_b3c")
        b4 = b.conv(b.pool(x, 3, 1, "same", "avg", name=f"a{i}_p"), 96, 1, 1,
                    name=f"a{i}_b4")
        return b.concat([b1, b2, b3, b4], name=f"a{i}_cat")

    for i in range(4):
        x = block_a(x, i)
    # reduction A
    r1 = b.conv(x, 384, 3, 2, "valid", name="ra_1")
    r2 = b.conv(b.conv(b.conv(x, 192, 1, 1, name="ra_2a"), 224, 3, 1,
                       name="ra_2b"), 256, 3, 2, "valid", name="ra_2c")
    r3 = b.pool(x, 3, 2, "valid", "max", name="ra_p")
    x = b.concat([r1, r2, r3], name="ra_cat")                  # 17x1024

    def block_b(x, i):
        b1 = b.conv(x, 384, 1, 1, name=f"ib{i}_b1")
        b2 = b.conv(x, 192, 1, 1, name=f"ib{i}_b2a")
        b2 = b.conv(b2, 224, (1, 7), 1, name=f"ib{i}_b2b")
        b2 = b.conv(b2, 256, (7, 1), 1, name=f"ib{i}_b2c")
        b3 = b.conv(x, 192, 1, 1, name=f"ib{i}_b3a")
        b3 = b.conv(b3, 192, (7, 1), 1, name=f"ib{i}_b3b")
        b3 = b.conv(b3, 224, (1, 7), 1, name=f"ib{i}_b3c")
        b3 = b.conv(b3, 224, (7, 1), 1, name=f"ib{i}_b3d")
        b3 = b.conv(b3, 256, (1, 7), 1, name=f"ib{i}_b3e")
        b4 = b.conv(b.pool(x, 3, 1, "same", "avg", name=f"ib{i}_p"), 128, 1, 1,
                    name=f"ib{i}_b4")
        return b.concat([b1, b2, b3, b4], name=f"ib{i}_cat")

    for i in range(7):
        x = block_b(x, i)
    # reduction B
    r1 = b.conv(b.conv(x, 192, 1, 1, name="rb_1a"), 192, 3, 2, "valid", name="rb_1b")
    r2 = b.conv(x, 256, 1, 1, name="rb_2a")
    r2 = b.conv(r2, 256, (1, 7), 1, name="rb_2b")
    r2 = b.conv(r2, 320, (7, 1), 1, name="rb_2c")
    r2 = b.conv(r2, 320, 3, 2, "valid", name="rb_2d")
    r3 = b.pool(x, 3, 2, "valid", "max", name="rb_p")
    x = b.concat([r1, r2, r3], name="rb_cat")                  # 8x1536

    def block_c(x, i):
        b1 = b.conv(x, 256, 1, 1, name=f"c{i}_b1")
        h = b.conv(x, 384, 1, 1, name=f"c{i}_b2a")
        b2 = b.concat([b.conv(h, 256, 3, 1, name=f"c{i}_b2b"),
                       b.conv(h, 256, 3, 1, name=f"c{i}_b2c")], name=f"c{i}_cat2")
        h = b.conv(b.conv(x, 384, 1, 1, name=f"c{i}_b3a"), 448, 3, 1, name=f"c{i}_b3b")
        h = b.conv(h, 512, 3, 1, name=f"c{i}_b3c")
        b3 = b.concat([b.conv(h, 256, 3, 1, name=f"c{i}_b3d"),
                       b.conv(h, 256, 3, 1, name=f"c{i}_b3e")], name=f"c{i}_cat3")
        b4 = b.conv(b.pool(x, 3, 1, "same", "avg", name=f"c{i}_p"), 256, 1, 1,
                    name=f"c{i}_b4")
        return b.concat([b1, b2, b3, b4], name=f"c{i}_cat")

    for i in range(3):
        x = block_c(x, i)
    return b.head(x)


def inception_resnet_v2(res: int = 299, dtype_bytes: int = 4) -> Graph:
    # Keras Applications variant: *sequential* stem (conv/conv/conv/pool/
    # conv/conv/pool), which is where the paper's 34.4 % saving lives.
    b = _B("inception_resnet_v2", dtype_bytes)
    x = b.input(res, res, 3)
    x = b.conv(x, 32, 3, 2, "valid", name="stem_c1")          # 149
    x = b.conv(x, 32, 3, 1, "valid", name="stem_c2")          # 147
    x = b.conv(x, 64, 3, 1, "same", name="stem_c3")           # 147  (2x input)
    x = b.pool(x, 3, 2, "valid", "max", name="stem_p1")       # 73
    x = b.conv(x, 80, 1, 1, name="stem_c4")
    x = b.conv(x, 192, 3, 1, "valid", name="stem_c5")         # 71
    x = b.pool(x, 3, 2, "valid", "max", name="stem_p2")       # 35x192
    # mixed_5b (Inception-A): -> 35x320
    b1 = b.conv(x, 96, 1, 1, name="m5b_b1")
    b2 = b.conv(b.conv(x, 48, 1, 1, name="m5b_b2a"), 64, 5, 1, name="m5b_b2b")
    b3 = b.conv(b.conv(b.conv(x, 64, 1, 1, name="m5b_b3a"), 96, 3, 1,
                       name="m5b_b3b"), 96, 3, 1, name="m5b_b3c")
    b4 = b.conv(b.pool(x, 3, 1, "same", "avg", name="m5b_p"), 64, 1, 1,
                name="m5b_b4")
    x = b.concat([b1, b2, b3, b4], name="m5b_cat")             # 35x320

    def block35(x, i):  # Inception-ResNet-A
        b1 = b.conv(x, 32, 1, 1, name=f"m35_{i}_b1")
        b2 = b.conv(b.conv(x, 32, 1, 1, name=f"m35_{i}_b2a"), 32, 3, 1,
                    name=f"m35_{i}_b2b")
        b3 = b.conv(b.conv(b.conv(x, 32, 1, 1, name=f"m35_{i}_b3a"), 48, 3, 1,
                           name=f"m35_{i}_b3b"), 64, 3, 1, name=f"m35_{i}_b3c")
        up = b.conv(b.concat([b1, b2, b3], name=f"m35_{i}_cat"), x.shape[-1],
                    1, 1, name=f"m35_{i}_up")
        return b.add(x, up, name=f"m35_{i}_add")

    for i in range(10):
        x = block35(x, i)
    r1 = b.conv(x, 384, 3, 2, "valid", name="ra_1")
    r2 = b.conv(b.conv(b.conv(x, 256, 1, 1, name="ra_2a"), 256, 3, 1,
                       name="ra_2b"), 384, 3, 2, "valid", name="ra_2c")
    r3 = b.pool(x, 3, 2, "valid", "max", name="ra_p")
    x = b.concat([r1, r2, r3], name="ra_cat")                  # 17x1152

    def block17(x, i):
        b1 = b.conv(x, 192, 1, 1, name=f"m17_{i}_b1")
        b2 = b.conv(x, 128, 1, 1, name=f"m17_{i}_b2a")
        b2 = b.conv(b2, 160, (1, 7), 1, name=f"m17_{i}_b2b")
        b2 = b.conv(b2, 192, (7, 1), 1, name=f"m17_{i}_b2c")
        up = b.conv(b.concat([b1, b2], name=f"m17_{i}_cat"), x.shape[-1], 1, 1,
                    name=f"m17_{i}_up")
        return b.add(x, up, name=f"m17_{i}_add")

    for i in range(20):
        x = block17(x, i)
    r1 = b.conv(b.conv(x, 256, 1, 1, name="rb_1a"), 384, 3, 2, "valid", name="rb_1b")
    r2 = b.conv(b.conv(x, 256, 1, 1, name="rb_2a"), 288, 3, 2, "valid", name="rb_2b")
    r3 = b.conv(b.conv(b.conv(x, 256, 1, 1, name="rb_3a"), 288, 3, 1,
                       name="rb_3b"), 320, 3, 2, "valid", name="rb_3c")
    r4 = b.pool(x, 3, 2, "valid", "max", name="rb_p")
    x = b.concat([r1, r2, r3, r4], name="rb_cat")              # 8x2144

    def block8(x, i):
        b1 = b.conv(x, 192, 1, 1, name=f"m8_{i}_b1")
        b2 = b.conv(x, 192, 1, 1, name=f"m8_{i}_b2a")
        b2 = b.conv(b2, 224, (1, 3), 1, name=f"m8_{i}_b2b")
        b2 = b.conv(b2, 256, (3, 1), 1, name=f"m8_{i}_b2c")
        up = b.conv(b.concat([b1, b2], name=f"m8_{i}_cat"), x.shape[-1], 1, 1,
                    name=f"m8_{i}_up")
        return b.add(x, up, name=f"m8_{i}_add")

    for i in range(10):
        x = block8(x, i)
    x = b.conv(x, 1536, 1, 1, name="conv_final")
    return b.head(x)


# ---------------------------------------------------------------------------
# NasNet Mobile (NasNet-A 4 @ 1056) — faithful cell topology, separable convs
# ---------------------------------------------------------------------------


def nasnet_mobile(res: int = 224, dtype_bytes: int = 4) -> Graph:
    b = _B("nasnet_mobile", dtype_bytes)
    penultimate = 44  # filters: 44 * 24 = 1056 at the last cell

    def fit(x: Tensor, h: int, w: int, c: int, name: str) -> Tensor:
        """1x1 conv (with stride if spatial mismatch) to align shapes."""
        s = x.shape[-3] // h
        return b.conv(x, c, 1, max(1, s), name=name)

    def normal_cell(prev: Tensor, cur: Tensor, filters: int, name: str) -> Tensor:
        p = fit(prev, cur.shape[-3], cur.shape[-2], filters, f"{name}_fitp")
        h = b.conv(cur, filters, 1, 1, name=f"{name}_fith")
        y1 = b.add(b.sep(h, filters, 5, 1, name=f"{name}_s1"),
                   b.sep(p, filters, 3, 1, name=f"{name}_s2"), name=f"{name}_a1")
        y2 = b.add(b.sep(p, filters, 5, 1, name=f"{name}_s3"),
                   b.sep(p, filters, 3, 1, name=f"{name}_s4"), name=f"{name}_a2")
        y3 = b.add(b.pool(h, 3, 1, "same", "avg", name=f"{name}_p1"), p,
                   name=f"{name}_a3")
        y4 = b.add(b.pool(p, 3, 1, "same", "avg", name=f"{name}_p2"),
                   b.pool(p, 3, 1, "same", "avg", name=f"{name}_p3"),
                   name=f"{name}_a4")
        y5 = b.add(b.sep(h, filters, 3, 1, name=f"{name}_s5"), h, name=f"{name}_a5")
        return b.concat([p, y1, y2, y3, y4, y5], name=f"{name}_cat")

    def reduction_cell(prev: Tensor, cur: Tensor, filters: int, name: str) -> Tensor:
        p = fit(prev, cur.shape[-3], cur.shape[-2], filters, f"{name}_fitp")
        h = b.conv(cur, filters, 1, 1, name=f"{name}_fith")
        z1 = b.add(b.sep(h, filters, 5, 2, name=f"{name}_s1"),
                   b.sep(p, filters, 7, 2, name=f"{name}_s2"), name=f"{name}_a1")
        z2 = b.add(b.pool(h, 3, 2, "same", "max", name=f"{name}_p1"),
                   b.sep(p, filters, 7, 2, name=f"{name}_s3"), name=f"{name}_a2")
        z3 = b.add(b.pool(h, 3, 2, "same", "avg", name=f"{name}_p2"),
                   b.sep(p, filters, 5, 2, name=f"{name}_s4"), name=f"{name}_a3")
        z4 = b.add(b.pool(z1, 3, 1, "same", "max", name=f"{name}_p3"),
                   b.sep(z1, filters, 3, 1, name=f"{name}_s5"), name=f"{name}_a4")
        z5 = b.add(b.pool(h, 3, 2, "same", "avg", name=f"{name}_p4"),
                   z1, name=f"{name}_a5")
        return b.concat([z2, z3, z4, z5], name=f"{name}_cat")

    x = b.input(res, res, 3)
    x = b.conv(x, 32, 3, 2, "valid", name="stem_conv")        # 111
    prev, cur = x, x
    cur = reduction_cell(prev, cur, penultimate // 4, "stem_r1")
    prev, cur = x, cur
    nxt = reduction_cell(prev, cur, penultimate // 2, "stem_r2")
    prev, cur = cur, nxt
    f = penultimate
    for stage in range(3):
        for i in range(4):
            nxt = normal_cell(prev, cur, f, f"n{stage}_{i}")
            prev, cur = cur, nxt
        if stage < 2:
            f *= 2
            nxt = reduction_cell(prev, cur, f, f"red{stage}")
            prev, cur = cur, nxt
    cur = b.relu(cur, name="postact")
    return b.head(cur)


# ---------------------------------------------------------------------------
# SqueezeNet 1.1 — the paper's §II.C example (concat-dominated peak)
# ---------------------------------------------------------------------------


def squeezenet(res: int = 224, dtype_bytes: int = 4) -> Graph:
    b = _B("squeezenet", dtype_bytes)

    def fire(x, squeeze, expand, name):
        s = b.conv(x, squeeze, 1, 1, name=f"{name}_sq")
        e1 = b.conv(s, expand, 1, 1, name=f"{name}_e1")
        e3 = b.conv(s, expand, 3, 1, name=f"{name}_e3")
        return b.concat([e1, e3], name=f"{name}_cat")

    x = b.input(res, res, 3)
    x = b.conv(x, 64, 3, 2, "valid", name="conv1")            # 111
    x = b.pool(x, 3, 2, "valid", "max", name="pool1")         # 55
    x = fire(x, 16, 64, "fire2")
    x = fire(x, 16, 64, "fire3")
    x = b.pool(x, 3, 2, "valid", "max", name="pool3")         # 27
    x = fire(x, 32, 128, "fire4")
    x = fire(x, 32, 128, "fire5")
    x = b.pool(x, 3, 2, "valid", "max", name="pool5")         # 13
    x = fire(x, 48, 192, "fire6")
    x = fire(x, 48, 192, "fire7")
    x = fire(x, 64, 256, "fire8")
    x = fire(x, 64, 256, "fire9")
    x = b.conv(x, 1000, 1, 1, name="conv10")
    return b.head(x)


# ---------------------------------------------------------------------------
# Registry: the 11 rows of Table III
# ---------------------------------------------------------------------------

TABLE3_MODELS = {
    "mobilenet_v1_1.0_224": (lambda: mobilenet_v1(1.0, 224, 4), 4704, 3136),
    "mobilenet_v1_1.0_224_8bit": (lambda: mobilenet_v1(1.0, 224, 1), 1176, 784),
    "mobilenet_v1_0.25_224": (lambda: mobilenet_v1(0.25, 224, 4), 1176, 786),
    "mobilenet_v1_0.25_128_8bit": (lambda: mobilenet_v1(0.25, 128, 1), 96, 64),
    "mobilenet_v2_0.35_224": (lambda: mobilenet_v2(0.35, 224, 4), 2940, 2352),
    "mobilenet_v2_1.0_224": (lambda: mobilenet_v2(1.0, 224, 4), 5880, 4704),
    "inception_v4": (lambda: inception_v4(299, 4), 10879, 10079),
    "inception_resnet_v2": (lambda: inception_resnet_v2(299, 4), 8399, 5504),
    "nasnet_mobile": (lambda: nasnet_mobile(224, 4), 4540, 4540),
    "densenet_121": (lambda: densenet121(224, 4), 8624, 8232),
    "resnet_50_v2": (lambda: resnet50_v2(224, 4), 10976, 10976),
}

#: The paper's flagship 8-bit rows (Table III measures its headline savings
#: on these). Since the dtype-aware executor layer they are *executable*,
#: not just plannable — table3_memory_savings executes and parity-checks
#: them against the quantised reference.
TABLE3_8BIT_MODELS = ("mobilenet_v1_1.0_224_8bit",
                      "mobilenet_v1_0.25_128_8bit")


def executable_models() -> dict:
    """The Table III rows whose (untransformed) graphs the arena executor
    backends accept — i.e. the rows that can be run, not only planned."""
    from repro.core import exec as X
    return {name: spec for name, spec in TABLE3_MODELS.items()
            if X.executable(spec[0]())}
