"""Graph serialisation (paper §II.B).

Connected graphs (Inception, DenseNet, NasNet, ...) admit many valid
execution orders; the order changes which tensors are live simultaneously and
therefore the peak arena size. Finding the optimal order is NP-hard; the
paper evaluates an *eager* and a *lazy* heuristic order per model and keeps
the better plan. Both are implemented here, plus a memory-greedy order
(beyond-paper: pick the ready op that minimises live bytes after execution).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Set

from repro.core.graph import Graph, Op, Tensor


def _deps(graph: Graph) -> Dict[Op, Set[Op]]:
    """Producer dependencies, *view-aware*.

    After §II.C concat removal an op's output may be a view into an
    aggregated tensor: several ops then write disjoint regions of ONE
    storage. A storage-keyed producer map keeps only the last such writer,
    under-constraining every reader of the aggregate — which is why removal
    graphs used to be pinned to construction order. Here a reader of an
    exactly-produced tensor (or view) depends on its producer, and a reader
    that resolves through storage depends on *every* writer into that
    storage (branch writers stay mutually unordered — they touch disjoint
    regions — so removal variants admit real re-serialisation)."""
    producer: Dict[int, Op] = {}          # id(exact output tensor) -> op
    writers: Dict[Tensor, List[Op]] = {}  # storage -> every op writing into it
    for op in graph.ops:
        for t in op.outputs:
            producer[id(t)] = op
            writers.setdefault(t.storage(), []).append(op)
    deps: Dict[Op, Set[Op]] = {}
    for op in graph.ops:
        d: Set[Op] = set()
        for t in op.inputs:
            if id(t) in producer:
                d.add(producer[id(t)])
            if t.storage() in writers:
                d.update(writers[t.storage()])
        d.discard(op)
        deps[op] = d
    return deps


def eager_order(graph: Graph) -> List[Op]:
    """FIFO topological order: run each op as soon as its inputs exist
    (breadth-first, construction order as tie-break)."""
    deps = _deps(graph)
    done: Set[Op] = set()
    order: List[Op] = []
    pending = list(graph.ops)
    while pending:
        for op in pending:
            if deps[op] <= done:
                order.append(op)
                done.add(op)
                pending.remove(op)
                break
        else:  # pragma: no cover - cyclic graph
            raise ValueError("graph has a cycle")
    return order


def lazy_order(graph: Graph) -> List[Op]:
    """Depth-first from the model outputs: each value is computed as late as
    its deepest consumer chain requires (post-order DFS)."""
    deps = _deps(graph)
    consumers: Dict[Op, int] = {op: 0 for op in graph.ops}
    for op in graph.ops:
        for d in deps[op]:
            consumers[d] += 1
    roots = [op for op in graph.ops if consumers[op] == 0]
    order: List[Op] = []
    seen: Set[Op] = set()

    def visit(op: Op) -> None:
        if op in seen:
            return
        seen.add(op)
        for d in sorted(deps[op], key=graph.ops.index):
            visit(d)
        order.append(op)

    for r in roots:
        visit(r)
    return order


def memory_greedy_order(graph: Graph) -> List[Op]:
    """Beyond-paper heuristic: among ready ops, run the one minimising the
    total bytes live after it executes (ties: construction order)."""
    deps = _deps(graph)
    remaining_uses: Dict[Tensor, int] = {}
    for op in graph.ops:
        for t in op.inputs:
            s = t.storage()
            if s.kind != "weight":
                remaining_uses[s] = remaining_uses.get(s, 0) + 1
    live: Set[Tensor] = {
        t.storage() for t in graph.tensors if t.kind == "input"
    }
    done: Set[Op] = set()
    order: List[Op] = []
    pending = list(graph.ops)
    while pending:
        ready = [op for op in pending if deps[op] <= done]
        if not ready:  # pragma: no cover
            raise ValueError("graph has a cycle")

        def after_bytes(op: Op) -> int:
            uses = dict(remaining_uses)
            nxt = set(live)
            for t in op.outputs:
                s = t.storage()
                if s.kind != "weight":
                    nxt.add(s)
            for t in op.inputs:
                s = t.storage()
                if s in uses:
                    uses[s] -= 1
                    if uses[s] == 0 and s.kind not in ("input", "output"):
                        nxt.discard(s)
            return sum(t.nbytes for t in nxt)

        best = min(ready, key=lambda op: (after_bytes(op), pending.index(op)))
        order.append(best)
        done.add(best)
        pending.remove(best)
        for t in best.outputs:
            s = t.storage()
            if s.kind != "weight":
                live.add(s)
        for t in best.inputs:
            s = t.storage()
            if s in remaining_uses:
                remaining_uses[s] -= 1
                if remaining_uses[s] == 0 and s.kind not in ("input", "output"):
                    live.discard(s)
    return order


def candidate_orders(graph: Graph) -> List[List[Op]]:
    """The paper's eager & lazy orders (+ the memory-greedy extension)."""
    orders = [eager_order(graph), lazy_order(graph)]
    try:
        orders.append(memory_greedy_order(graph))
    except Exception:  # pragma: no cover - defensive
        pass
    # dedupe
    uniq: List[List[Op]] = []
    for o in orders:
        if o not in uniq:
            uniq.append(o)
    return uniq
