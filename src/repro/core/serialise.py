"""Graph serialisation (paper §II.B).

Connected graphs (Inception, DenseNet, NasNet, ...) admit many valid
execution orders; the order changes which tensors are live simultaneously and
therefore the peak arena size. Finding the optimal order is NP-hard; the
paper evaluates an *eager* and a *lazy* heuristic order per model and keeps
the better plan. Both are implemented here, plus a memory-greedy order
(beyond-paper: pick the ready op that minimises live bytes after execution)
and :class:`OrderMoves`, the move-legality oracle the joint
execution-order x overlap search (``planner.plan_joint``) walks the space of
dependency-respecting linearisations with.
"""
from __future__ import annotations

import heapq
import random
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.graph import Graph, Op, Tensor


def _deps(graph: Graph) -> Dict[Op, Set[Op]]:
    """Producer dependencies, *view-aware*.

    After §II.C concat removal an op's output may be a view into an
    aggregated tensor: several ops then write disjoint regions of ONE
    storage. A storage-keyed producer map keeps only the last such writer,
    under-constraining every reader of the aggregate — which is why removal
    graphs used to be pinned to construction order. Here a reader of an
    exactly-produced tensor (or view) depends on its producer, and a reader
    that resolves through storage depends on *every* writer into that
    storage (branch writers stay mutually unordered — they touch disjoint
    regions — so removal variants admit real re-serialisation)."""
    producer: Dict[int, Op] = {}          # id(exact output tensor) -> op
    writers: Dict[Tensor, List[Op]] = {}  # storage -> every op writing into it
    for op in graph.ops:
        for t in op.outputs:
            producer[id(t)] = op
            writers.setdefault(t.storage(), []).append(op)
    deps: Dict[Op, Set[Op]] = {}
    for op in graph.ops:
        d: Set[Op] = set()
        for t in op.inputs:
            if id(t) in producer:
                d.add(producer[id(t)])
            if t.storage() in writers:
                d.update(writers[t.storage()])
        d.discard(op)
        deps[op] = d
    return deps


def _consumers(deps: Dict[Op, Set[Op]]) -> Dict[Op, List[Op]]:
    """Invert a dependency map: op -> ops that depend on it."""
    out: Dict[Op, List[Op]] = {op: [] for op in deps}
    for op, d in deps.items():
        for dep in d:
            out[dep].append(op)
    return out


def eager_order(graph: Graph) -> List[Op]:
    """FIFO topological order: run each op as soon as its inputs exist
    (breadth-first, construction order as tie-break).

    Kahn's algorithm with a construction-index min-heap: the historical
    pending-list rescan picked the *first* ready op in construction order,
    which is exactly the minimum construction index among ready ops — so the
    ready-heap produces the bit-identical order in O(E log V) instead of
    O(V^2 * E)."""
    deps = _deps(graph)
    consumers = _consumers(deps)
    idx = {op: i for i, op in enumerate(graph.ops)}
    indeg = {op: len(deps[op]) for op in graph.ops}
    ready = [idx[op] for op in graph.ops if indeg[op] == 0]
    heapq.heapify(ready)
    order: List[Op] = []
    while ready:
        op = graph.ops[heapq.heappop(ready)]
        order.append(op)
        for c in consumers[op]:
            indeg[c] -= 1
            if indeg[c] == 0:
                heapq.heappush(ready, idx[c])
    if len(order) != len(graph.ops):  # pragma: no cover - cyclic graph
        raise ValueError("graph has a cycle")
    return order


def lazy_order(graph: Graph) -> List[Op]:
    """Depth-first from the model outputs: each value is computed as late as
    its deepest consumer chain requires (post-order DFS)."""
    deps = _deps(graph)
    idx = {op: i for i, op in enumerate(graph.ops)}
    consumers: Dict[Op, int] = {op: 0 for op in graph.ops}
    for op in graph.ops:
        for d in deps[op]:
            consumers[d] += 1
    roots = [op for op in graph.ops if consumers[op] == 0]
    order: List[Op] = []
    seen: Set[Op] = set()

    def visit(op: Op) -> None:
        if op in seen:
            return
        seen.add(op)
        for d in sorted(deps[op], key=idx.__getitem__):
            visit(d)
        order.append(op)

    for r in roots:
        visit(r)
    return order


def memory_greedy_order(graph: Graph) -> List[Op]:
    """Beyond-paper heuristic: among ready ops, run the one minimising the
    total bytes live after it executes (ties: construction order).

    The ready set is maintained Kahn-style (indegree counting) instead of
    rescanning the whole pending list each step; the construction-index
    tie-break is order-identical to the historical ``pending.index``
    tie-break, since removal preserves the relative construction order of
    the remaining ops."""
    deps = _deps(graph)
    consumers = _consumers(deps)
    idx = {op: i for i, op in enumerate(graph.ops)}
    indeg = {op: len(deps[op]) for op in graph.ops}
    ready: Set[Op] = {op for op in graph.ops if indeg[op] == 0}
    remaining_uses: Dict[Tensor, int] = {}
    for op in graph.ops:
        for t in op.inputs:
            s = t.storage()
            if s.kind != "weight":
                remaining_uses[s] = remaining_uses.get(s, 0) + 1
    live: Set[Tensor] = {
        t.storage() for t in graph.tensors if t.kind == "input"
    }
    order: List[Op] = []
    while ready:

        def after_bytes(op: Op) -> int:
            uses = dict(remaining_uses)
            nxt = set(live)
            for t in op.outputs:
                s = t.storage()
                if s.kind != "weight":
                    nxt.add(s)
            for t in op.inputs:
                s = t.storage()
                if s in uses:
                    uses[s] -= 1
                    if uses[s] == 0 and s.kind not in ("input", "output"):
                        nxt.discard(s)
            return sum(t.nbytes for t in nxt)

        best = min(ready, key=lambda op: (after_bytes(op), idx[op]))
        order.append(best)
        ready.discard(best)
        for c in consumers[best]:
            indeg[c] -= 1
            if indeg[c] == 0:
                ready.add(c)
        for t in best.outputs:
            s = t.storage()
            if s.kind != "weight":
                live.add(s)
        for t in best.inputs:
            s = t.storage()
            if s in remaining_uses:
                remaining_uses[s] -= 1
                if remaining_uses[s] == 0 and s.kind not in ("input", "output"):
                    live.discard(s)
    if len(order) != len(graph.ops):  # pragma: no cover - cyclic graph
        raise ValueError("graph has a cycle")
    return order


def candidate_orders(graph: Graph) -> List[List[Op]]:
    """The paper's eager & lazy orders (+ the memory-greedy extension)."""
    orders = [eager_order(graph), lazy_order(graph)]
    try:
        orders.append(memory_greedy_order(graph))
    except Exception:  # pragma: no cover - defensive
        pass
    # dedupe
    uniq: List[List[Op]] = []
    for o in orders:
        if o not in uniq:
            uniq.append(o)
    return uniq


class OrderMoves:
    """Move-legality oracle over dependency-respecting linearisations.

    The joint execution-order x overlap search (``planner.plan_joint``)
    perturbs a topological order with adjacent transpositions and block
    moves; whether a move is legal is decided here, against the same
    view-aware :func:`_deps` precedence relation every serialisation
    heuristic uses — aggregated-view writers stay ordered before their
    readers, so a legal move can never produce an order that clobbers a
    §II.C removal region."""

    def __init__(self, graph: Graph):
        self.graph = graph
        self.deps = _deps(graph)
        self.idx = {op: i for i, op in enumerate(graph.ops)}

    def signature(self, order: Sequence[Op]) -> Tuple[int, ...]:
        """Hashable identity of an order (construction indices) — the
        memoisation key that makes repeated search neighbourhoods free."""
        return tuple(self.idx[op] for op in order)

    def is_topological(self, order: Sequence[Op]) -> bool:
        if sorted(self.signature(order)) != list(range(len(self.graph.ops))):
            return False
        pos = {op: i for i, op in enumerate(order)}
        return all(pos[d] < pos[op]
                   for op in order for d in self.deps[op])

    # -- adjacent transposition ---------------------------------------------
    def legal_swap(self, order: Sequence[Op], i: int) -> bool:
        """May ``order[i]`` and ``order[i+1]`` exchange places?"""
        return order[i] not in self.deps[order[i + 1]]

    def legal_swaps(self, order: Sequence[Op]) -> List[int]:
        return [i for i in range(len(order) - 1)
                if self.legal_swap(order, i)]

    def swap(self, order: Sequence[Op], i: int) -> List[Op]:
        new = list(order)
        new[i], new[i + 1] = new[i + 1], new[i]
        return new

    # -- block move ----------------------------------------------------------
    def legal_block_move(self, order: Sequence[Op], i: int, j: int) -> bool:
        """May ``order[i]`` be re-inserted at position ``j``? Moving later
        requires nothing it hops over to depend on it; moving earlier
        requires it to depend on nothing it hops over."""
        op = order[i]
        if j > i:
            return all(op not in self.deps[order[k]]
                       for k in range(i + 1, j + 1))
        return all(order[k] not in self.deps[op] for k in range(j, i))

    def block_move(self, order: Sequence[Op], i: int, j: int) -> List[Op]:
        new = list(order)
        new.insert(j, new.pop(i))
        return new

    # -- sampling ------------------------------------------------------------
    def random_topological(self, rng: random.Random,
                           order: Optional[Sequence[Op]] = None) -> List[Op]:
        """A uniformly-perturbed dependency-respecting linearisation: Kahn
        with the ready op drawn at random. Used by the search restarts and
        by the any-linearisation safety property tests."""
        ops = list(order if order is not None else self.graph.ops)
        consumers = _consumers(self.deps)
        indeg = {op: len(self.deps[op]) for op in ops}
        ready = [op for op in ops if indeg[op] == 0]
        out: List[Op] = []
        while ready:
            op = ready.pop(rng.randrange(len(ready)))
            out.append(op)
            for c in consumers[op]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    ready.append(c)
        if len(out) != len(ops):  # pragma: no cover - cyclic graph
            raise ValueError("graph has a cycle")
        return out
