"""Operation splitting (paper §II.A) — automated.

A pair of conv-family ops with a large intermediate can be split into
``parts`` row bands executed sequentially: each band recomputes a small halo
of the intermediate but the full intermediate never exists at once. The
paper demonstrates this manually on MobileNet v1 (96 → 66 KB, 6144 elements
recomputed) and calls automating it future work; :func:`auto_split` is that
automation — it repeatedly splits the peak-defining pair while the planned
peak improves, accounting the recompute penalty.

Splitting extends the producer/consumer scopes, so DMO overlap is disabled
across split ops (exactly the incompatibility the paper notes).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.graph import Graph, Op, Tensor, pad_amount
from repro.core.planner import Plan, plan_original

_SPLITTABLE = ("conv2d", "depthwise_conv2d", "pool")


def _rows_needed(op: Op, o0: int, o1: int) -> Tuple[int, int]:
    """Input row range feeding output rows [o0, o1) of a conv-family op."""
    ih = op.inputs[0].shape[0]
    oh = op.output.shape[0]
    kh = op.params["kernel"][0]
    sh = op.params.get("stride", (1, 1))[0]
    dh = op.params.get("dilation", (1, 1))[0]
    ph = (pad_amount(ih, oh, kh, sh, dh)
          if op.params.get("padding", "same") == "same" else 0)
    lo = max(0, o0 * sh - ph)
    hi = min(ih, (o1 - 1) * sh - ph + (kh - 1) * dh + 1)
    return lo, hi


def split_pair(g: Graph, ia: int, parts: int
               ) -> Optional[Tuple[Graph, int]]:
    """Split ops (ia, ia+1) into ``parts`` row-band pairs.

    Returns (new graph, recomputed intermediate elements), or None if the
    pair is not splittable (wrong kinds, intermediate multiply consumed...).
    """
    ops = g.ops
    if ia + 1 >= len(ops):
        return None
    a, b = ops[ia], ops[ia + 1]
    if a.kind not in _SPLITTABLE or b.kind not in _SPLITTABLE:
        return None
    mid = a.output.storage()
    consumers = [op for op in ops if mid in
                 [t.storage() for t in op.inputs]]
    if consumers != [b] or b.inputs[0].storage() is not mid:
        return None
    oh_b = b.output.shape[0]
    if oh_b < parts or oh_b % parts:
        return None

    ng = Graph(g.name + f"_split{ia}x{parts}")
    mapping = {}

    def map_t(t: Tensor) -> Tensor:
        s = t.storage()
        if s not in mapping:
            mapping[s] = ng.tensor(s.name, s.shape, s.dtype_bytes, s.kind)
        return mapping[s]

    recompute = 0
    band = oh_b // parts
    for i, op in enumerate(ops):
        if i == ia:
            continue
        if i == ia + 1:
            t0 = map_t(a.inputs[0])
            pieces = []
            w_mid, c_mid = a.output.shape[1], a.output.shape[2]
            for p in range(parts):
                o0, o1 = p * band, (p + 1) * band
                m0, m1 = _rows_needed(b, o0, o1)
                mid_p = ng.tensor(f"{mid.name}_p{p}",
                                  (m1 - m0, w_mid, c_mid), mid.dtype_bytes)
                ng.add(Op(a.kind, [t0], [mid_p],
                          dict(a.params, row_range=(m0, m1)),
                          f"{a.name}_p{p}"))
                out_p = ng.tensor(f"{b.output.name}_p{p}",
                                  (o1 - o0, *b.output.shape[1:]),
                                  b.output.dtype_bytes)
                ng.add(Op(b.kind, [mid_p], [out_p],
                          dict(b.params, padding="valid",
                               row_range=(o0, o1)), f"{b.name}_p{p}"))
                pieces.append(out_p)
                recompute += (m1 - m0) * w_mid * c_mid
            out = map_t(b.output)
            ng.add(Op("concat", pieces, [out], dict(axis=0),
                      f"{b.name}_cat"))
            recompute -= mid.elems
            continue
        new_ins = [map_t(t) for t in op.inputs]
        new_outs = [map_t(t) for t in op.outputs]
        ng.add(Op(op.kind, new_ins, new_outs, dict(op.params), op.name))
    return ng, max(0, recompute)


def auto_split(g: Graph, max_parts: int = 8, rounds: int = 3
               ) -> Tuple[Graph, int, List[str]]:
    """Greedy: while the planned peak improves, split the pair whose live
    set defines the peak. Returns (graph, total recompute elems, log)."""
    log: List[str] = []
    total_rc = 0
    cur = g
    for _ in range(rounds):
        base = plan_original(cur).peak_bytes
        scopes = cur.scopes()
        # find the op step with the largest live-byte sum
        peak_step, peak_live = 0, 0
        for i in range(len(cur.ops)):
            live = sum(t.nbytes for t, (s, e) in scopes.items() if s <= i <= e)
            if live > peak_live:
                peak_step, peak_live = i, live
        best = None
        for ia in (peak_step - 1, peak_step):
            for parts in (2, 4, max_parts):
                if parts < 2:
                    continue
                r = split_pair(cur, ia, parts)
                if r is None:
                    continue
                ng, rc = r
                peak = plan_original(ng).peak_bytes
                if peak < base and (best is None or peak < best[0]):
                    best = (peak, ng, rc, ia, parts)
        if best is None:
            break
        peak, cur, rc, ia, parts = best
        total_rc += rc
        log.append(f"split ops {ia},{ia + 1} into {parts}: "
                   f"{base / 1024:.0f} -> {peak / 1024:.0f} KB "
                   f"(+{rc} recomputed elems)")
    return cur, total_rc, log
