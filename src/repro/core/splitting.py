"""Operation splitting (paper §II.A) — automated, overlap-aware.

A pair of conv-family ops with a large intermediate can be split into
``parts`` row bands executed sequentially: each band recomputes a small halo
of the intermediate but the full intermediate never exists at once. The
paper demonstrates this manually on MobileNet v1 (96 → 66 KB, 6144 elements
recomputed) and calls automating it future work; :func:`auto_split` is that
automation — it repeatedly splits the peak-defining pair while the planned
peak improves, accounting the recompute penalty.

Band semantics: every band op carries

- ``row_range=(r0, r1)`` — the output rows of its reference op it computes
  (band-local after re-splitting an already-banded op);
- ``band_pad=(ph, pw)`` — the *explicit* leading pads of the band-local
  loop nest (:func:`repro.core.graph.op_pads`): output-local row ``o``
  reads input-local rows ``o*sh - ph + fy*dh``. A consumer band's ``ph``
  is its share of the pair's SAME padding (``ph`` rows on the first band,
  0 once the halo starts inside the intermediate); a producer band's
  ``ph`` is *negative* — its output rows start ``m0*sh - ph`` rows deep in
  the full input it reads. Carrying the pads explicitly (instead of the
  old ``padding="valid"`` re-labelling) is what keeps the edge bands'
  declared shapes consistent under SAME padding — the valid-conv reading
  made the first/last bands ``ph`` rows short;
- ``split_src=<op name>`` — weight/calibration provenance: all bands of
  one reference op share its weight draw and pool their activation ranges
  (:func:`repro.core.exec.ops.synth_weights` /
  :func:`~repro.core.exec.ops.calibrate`), so a split graph computes the
  *same network* as its unsplit reference, band for band.

With those params a band is an ordinary conv/pool over its band shapes, so
the O_s calculators, the executor backends and the row-blocked legaliser
all handle bands through the one shared geometry helper — splitting and
diagonal overlap compose (the paper's §II.A + §III future-work item), and
:func:`auto_split` evaluates candidates with the overlap-aware planner.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.graph import Graph, Op, Tensor, op_pads
from repro.core.planner import plan_dmo, plan_original

_SPLITTABLE = ("conv2d", "depthwise_conv2d", "pool")

#: Op kinds a fused band-chain super-kernel can run as an in-VMEM stage
#: (the kinds `_BODIES` implements over the routed memory layer).
FUSABLE_KINDS = ("conv2d", "depthwise_conv2d", "pool", "elementwise",
                 "concat")


def _rows_needed(op: Op, o0: int, o1: int) -> Tuple[int, int]:
    """Input row range feeding output rows [o0, o1) of a conv-family op
    (band-local when ``op`` is itself already banded)."""
    ih = op.inputs[0].shape[0]
    kh = op.params["kernel"][0]
    sh = op.params.get("stride", (1, 1))[0]
    dh = op.params.get("dilation", (1, 1))[0]
    ph = op_pads(op)[0]
    lo = max(0, o0 * sh - ph)
    hi = min(ih, (o1 - 1) * sh - ph + (kh - 1) * dh + 1)
    return lo, hi


def split_pair(g: Graph, ia: int, parts: int
               ) -> Optional[Tuple[Graph, int]]:
    """Split ops (ia, ia+1) into ``parts`` row-band pairs.

    Returns (new graph, recomputed intermediate elements), or None if the
    pair is not splittable (wrong kinds, intermediate multiply consumed...).
    """
    ops = g.ops
    if ia < 0 or ia + 1 >= len(ops):
        return None
    a, b = ops[ia], ops[ia + 1]
    if a.kind not in _SPLITTABLE or b.kind not in _SPLITTABLE:
        return None
    mid = a.output.storage()
    consumers = [op for op in ops if mid in
                 [t.storage() for t in op.inputs]]
    if consumers != [b] or b.inputs[0].storage() is not mid:
        return None
    oh_b = b.output.shape[0]
    if oh_b < parts or oh_b % parts:
        return None

    ng = Graph(g.name + f"_split{ia}x{parts}")
    ng.batch = g.batch
    mapping = {}

    def map_t(t: Tensor) -> Tensor:
        s = t.storage()
        if s not in mapping:
            mapping[s] = ng.tensor(s.name, s.shape, s.dtype_bytes, s.kind)
        return mapping[s]

    ph_a, pw_a = op_pads(a)
    ph_b, pw_b = op_pads(b)
    sh_a = a.params.get("stride", (1, 1))[0]
    sh_b = b.params.get("stride", (1, 1))[0]
    # re-splitting an already-banded op keeps the *reference* op's
    # weight/calibration group, so sub-bands still share its draw
    src_a = a.params.get("split_src", a.name)
    src_b = b.params.get("split_src", b.name)
    band = oh_b // parts
    halo_rows = 0      # intermediate rows produced across all bands
    covered_hi = None  # union of the bands' halo row ranges (they ascend)
    covered = 0
    for i, op in enumerate(ops):
        if i == ia:
            continue
        if i == ia + 1:
            t0 = map_t(a.inputs[0])
            pieces = []
            w_mid, c_mid = a.output.shape[1], a.output.shape[2]
            for p in range(parts):
                o0, o1 = p * band, (p + 1) * band
                m0, m1 = _rows_needed(b, o0, o1)
                if m1 <= m0:
                    return None  # a band reading pure padding: degenerate
                mid_p = ng.tensor(f"{mid.name}_p{p}",
                                  (m1 - m0, w_mid, c_mid), mid.dtype_bytes)
                ng.add(Op(a.kind, [t0], [mid_p],
                          dict(a.params, row_range=(m0, m1),
                               band_pad=(ph_a - m0 * sh_a, pw_a),
                               split_src=src_a),
                          f"{a.name}_p{p}"))
                out_p = ng.tensor(f"{b.output.name}_p{p}",
                                  (o1 - o0, *b.output.shape[1:]),
                                  b.output.dtype_bytes)
                ng.add(Op(b.kind, [mid_p], [out_p],
                          dict(b.params, row_range=(o0, o1),
                               band_pad=(ph_b + m0 - o0 * sh_b, pw_b),
                               split_src=src_b),
                          f"{b.name}_p{p}"))
                pieces.append(out_p)
                halo_rows += m1 - m0
                covered += m1 - max(m0, covered_hi if covered_hi is not None
                                    else m0)
                covered_hi = m1
            out = map_t(b.output)
            ng.add(Op("concat", pieces, [out], dict(axis=0),
                      f"{b.name}_cat"))
            continue
        new_ins = [map_t(t) for t in op.inputs]
        new_outs = [map_t(t) for t in op.outputs]
        ng.add(Op(op.kind, new_ins, new_outs, dict(op.params), op.name))
    # recompute = rows produced more than once (the bands' halo total minus
    # the union of rows they cover — NOT minus the full intermediate, which
    # over-credited rows no band ever produces, e.g. a valid-padded pair's
    # bottom leftover rows)
    recompute = (halo_rows - covered) * a.output.shape[1] * a.output.shape[2]
    return ng, max(0, recompute)


def _is_band(op: Op) -> bool:
    """A row band produced by :func:`split_pair`: conv-family op carrying
    the explicit band provenance params."""
    return (op.kind in _SPLITTABLE and "row_range" in op.params
            and "band_pad" in op.params and "split_src" in op.params)


def find_band_chains(g: Graph) -> List[List[Op]]:
    """Discover fusable band chains in a split graph.

    A chain is the whole split region of :func:`split_pair`: every producer
    band, every consumer band, and the axis-0 concat that reassembles them —
    discovered backwards from each concat through band provenance. A chain
    qualifies for fusion only when its internal tensors (every member output
    except the concat's) are consumed exclusively inside the chain (so they
    can become VMEM scratch, invisible to the arena) and the members sit
    contiguously in graph order ending at the concat (so the fused kernel
    replaces a contiguous run of ops and the surrounding execution order is
    untouched). Returns chains as member-op lists in graph order, concat
    last.
    """
    producers: Dict[Tensor, Op] = {}
    consumers: Dict[Tensor, List[Op]] = {}
    index: Dict[int, int] = {}
    for i, op in enumerate(g.ops):
        index[id(op)] = i
        for t in op.outputs:
            producers[t.storage()] = op
        for t in op.inputs:
            s = t.storage()
            if s.kind != "weight":
                consumers.setdefault(s, []).append(op)
    aliased = {t.alias_of.storage() for t in g.tensors
               if t.alias_of is not None}
    chains: List[List[Op]] = []
    for cat in g.ops:
        if cat.kind != "concat" or cat.params.get("axis", -1) != 0:
            continue
        # transitive closure of band producers behind the concat
        members: Dict[int, Op] = {id(cat): cat}
        frontier = [t.storage() for t in cat.inputs]
        while frontier:
            s = frontier.pop()
            p = producers.get(s)
            if p is None or not _is_band(p) or id(p) in members:
                continue  # external chain input: stays in the arena
            members[id(p)] = p
            frontier.extend(t.storage() for t in p.inputs
                            if t.storage().kind != "weight")
        if len(members) < 3:  # at least one producer/consumer band pair
            continue
        internal = {t.storage() for op in members.values() if op is not cat
                    for t in op.outputs}
        idxs = sorted(index[id(op)] for op in members.values())
        if not (
            # contiguous run ending at the concat
            idxs == list(range(idxs[0], idxs[-1] + 1))
            and idxs[-1] == index[id(cat)]
            and all(op.kind in FUSABLE_KINDS for op in members.values())
            # internal tensors: chain-private, unaliased plain intermediates
            and all(s.kind == "intermediate" and s.alias_of is None
                    and s not in aliased
                    and all(id(c) in members for c in consumers.get(s, []))
                    for s in internal)
            # in-VMEM stages need batch-1 HWC geometry (the scratch buffer
            # is a rows x rowlen 2-D block, one image row per scratch row)
            and all(len(s.shape) == 3 for s in internal)
        ):
            continue
        chains.append([g.ops[i] for i in idxs])
    return chains


def fuse_chains(g: Graph, chains: Optional[List[List[Op]]] = None
                ) -> Optional[Graph]:
    """Rebuild ``g`` with each band chain marked for fused execution.

    Chain-internal tensors are re-kinded ``"scratch"`` — they drop out of
    :meth:`Graph.arena_tensors`/:meth:`Graph.scopes` and therefore out of
    arena placement entirely — and every member op gains
    ``fuse_chain=<concat name>`` / ``fuse_stage=<k>`` params, which the
    Pallas layer uses to emit ONE kernel per chain (stage order = graph
    order). Op sequence, kinds, names and numeric semantics are unchanged,
    so weight synthesis and calibration stay position-for-position aligned
    with the unfused graph. Returns ``None`` when there is nothing to fuse.
    """
    if chains is None:
        chains = find_band_chains(g)
    if not chains:
        return None
    chain_of: Dict[int, Tuple[str, int]] = {}
    internal: set = set()
    for ch in chains:
        cat = ch[-1]
        for j, op in enumerate(ch):
            chain_of[id(op)] = (cat.name, j)
            if op is not cat:
                internal.update(t.storage() for t in op.outputs)

    ng = Graph(g.name + "_fused")
    ng.batch = g.batch
    mapping: Dict[Tensor, Tensor] = {}

    def map_t(t: Tensor) -> Tensor:
        if t in mapping:
            return mapping[t]
        if t.alias_of is not None:
            base = map_t(t.alias_of)
            nt = ng.tensor(t.name, t.shape, t.dtype_bytes, t.kind,
                           alias_of=base)
        else:
            kind = "scratch" if t in internal else t.kind
            nt = ng.tensor(t.name, t.shape, t.dtype_bytes, kind)
        mapping[t] = nt
        return nt

    for op in g.ops:
        params = dict(op.params)
        if id(op) in chain_of:
            cname, stage = chain_of[id(op)]
            params.update(fuse_chain=cname, fuse_stage=stage)
        ng.add(Op(op.kind, [map_t(t) for t in op.inputs],
                  [map_t(t) for t in op.outputs], params, op.name))
    ng.validate()
    return ng


def chain_members(g: Graph) -> Dict[str, List[Op]]:
    """Fused chains of a graph, keyed by chain name, members in graph order
    (``fuse_stage`` ascending — asserted, since the Pallas layer relies on
    graph order matching stage order)."""
    out: Dict[str, List[Op]] = {}
    for op in g.ops:
        c = op.params.get("fuse_chain")
        if c is not None:
            out.setdefault(c, []).append(op)
    for name, ops in out.items():
        stages = [op.params["fuse_stage"] for op in ops]
        assert stages == list(range(len(ops))), \
            f"chain {name!r}: graph order disagrees with stage order"
    return out


def order_pinned(g: Graph) -> bool:
    """Does this graph refuse re-serialisation and order-search moves?

    Fused band chains pin execution order: each chain lowers to ONE Pallas
    kernel whose members must stay contiguous with ``fuse_stage`` ascending
    (see :func:`chain_members`), so both :class:`pipeline.SerialisePass` and
    the joint execution-order search leave fused variants in construction
    order. Plain *split* variants are NOT pinned — band ops are ordinary
    graph ops with explicit pads, so split variants re-enter the joint
    search like any other graph."""
    return any("fuse_chain" in op.params for op in g.ops)


def auto_split(g: Graph, max_parts: int = 8, rounds: int = 3,
               overlap: bool = True, method: str = "algorithmic",
               profile: str = "paper") -> Tuple[Graph, int, List[str]]:
    """Greedy: while the planned peak improves, split the pair whose live
    set defines the peak. Returns (graph, total recompute elems, log).

    ``overlap=True`` (the default) evaluates every candidate with the
    overlap-aware DMO planner, so the chosen splits are the ones that
    compose best with the diagonal relaxation — the banded O_s lets each
    halo tuck into its band output's tail. ``overlap=False`` keeps the
    paper's conservative route (splitting and overlap priced separately).
    """
    plan = ((lambda gr: plan_dmo(gr, method=method, profile=profile))
            if overlap else plan_original)
    log: List[str] = []
    total_rc = 0
    cur = g
    for _ in range(rounds):
        base = plan(cur).peak_bytes
        scopes = cur.scopes()
        # find the op step with the largest live-byte sum
        peak_step, peak_live = 0, 0
        for i in range(len(cur.ops)):
            live = sum(t.nbytes for t, (s, e) in scopes.items() if s <= i <= e)
            if live > peak_live:
                peak_step, peak_live = i, live
        best = None
        # ia >= 0: when op 0 defines the peak, probing ia = -1 would
        # Python-wrap split_pair to the bogus (last, first) pair
        for ia in (i for i in (peak_step - 1, peak_step) if i >= 0):
            # dict.fromkeys: dedupe the candidate list when max_parts is 2
            # or 4 (each duplicate re-plans the whole graph)
            for parts in dict.fromkeys((2, 4, max_parts)):
                if parts < 2:
                    continue
                r = split_pair(cur, ia, parts)
                if r is None:
                    continue
                ng, rc = r
                peak = plan(ng).peak_bytes
                if peak < base and (best is None or peak < best[0]):
                    best = (peak, ng, rc, ia, parts)
        if best is None:
            break
        peak, cur, rc, ia, parts = best
        total_rc += rc
        log.append(f"split ops {ia},{ia + 1} into {parts}: "
                   f"{base / 1024:.0f} -> {peak / 1024:.0f} KB "
                   f"(+{rc} recomputed elems)")
    return cur, total_rc, log
