"""Tensor-operation graph IR for diagonal memory optimisation.

This is the framework's analogue of a TFLite flatbuffer: a list of tensor
operations over shaped tensors, enough to (a) compute per-op safe buffer
overlaps ``O_s`` and (b) plan a flat tensor arena.

Only *intermediate* tensors participate in arena planning; weight/constant
tensors live in flash/HBM and are excluded, exactly as in the paper.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Tensors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class Tensor:
    """A tensor value flowing through the graph.

    ``kind`` is one of ``input`` (model input), ``intermediate``, ``output``
    (model output), ``weight`` (excluded from the arena) or ``scratch``
    (chain-internal value of a fused band chain: materialised only inside
    the fused kernel's VMEM scratch, never placed in the arena — see
    :mod:`repro.core.pipeline` FusePass).
    """

    name: str
    shape: Tuple[int, ...]
    dtype_bytes: int = 4
    kind: str = "intermediate"
    #: Alias-of: reshape/squeeze outputs share storage with their input.
    alias_of: Optional["Tensor"] = None
    #: Leading batch axis. ``shape`` stays the per-image shape (so op params
    #: like concat axes and band row ranges keep their meaning); a batched
    #: tensor stores ``batch`` images back to back, image ``b`` at byte
    #: offset ``b * image_nbytes`` of its storage. Weight tensors are never
    #: batched (``batch == 1`` always).
    batch: int = 1

    @property
    def image_elems(self) -> int:
        """Elements of ONE image (``prod(shape)``, batch excluded)."""
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def image_nbytes(self) -> int:
        return self.image_elems * self.dtype_bytes

    @property
    def elems(self) -> int:
        return self.batch * self.image_elems

    @property
    def nbytes(self) -> int:
        return self.elems * self.dtype_bytes

    def storage(self) -> "Tensor":
        """Resolve alias chains to the tensor that owns the storage."""
        t = self
        while t.alias_of is not None:
            t = t.alias_of
        return t

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Tensor({self.name}, {self.shape}, {self.dtype_bytes}B, {self.kind})"


# ---------------------------------------------------------------------------
# Operations
# ---------------------------------------------------------------------------

#: Op kinds with reference-implementation access-pattern models.
OP_KINDS = (
    "conv2d",          # params: stride (sh, sw), padding 'same'|'valid', dilation
    "depthwise_conv2d",  # params: stride, padding, channel multiplier
    "pool",            # params: pool kernel, stride, padding, avg|max
    "elementwise",     # unary or binary same-shape (relu, add, mul, ...)
    "softmax",
    "fully_connected",  # matmul against weights
    "matmul",          # generic matmul between two intermediates
    "concat",          # params: axis
    "pad",             # params: paddings per dim
    "mean",            # global spatial reduction
    "reshape",         # aliasing no-op
    "embedding_lookup",  # gather rows from a weight table
    "custom",          # anything else: O_s = 0 (fully conservative)
)


@dataclasses.dataclass(eq=False)
class Op:
    kind: str
    inputs: List[Tensor]
    outputs: List[Tensor]
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    name: str = ""

    def __post_init__(self) -> None:
        if self.kind not in OP_KINDS:
            raise ValueError(f"unknown op kind {self.kind!r}")
        if not self.name:
            self.name = self.kind

    @property
    def output(self) -> Tensor:
        return self.outputs[0]

    def intermediate_inputs(self) -> List[Tensor]:
        return [t for t in self.inputs if t.kind != "weight"]

    def __repr__(self) -> str:  # pragma: no cover
        return f"Op({self.name}:{self.kind})"


# ---------------------------------------------------------------------------
# Graph
# ---------------------------------------------------------------------------


class Graph:
    """An ordered tensor-op graph (execution order = list order).

    Use :mod:`repro.core.serialise` to re-order connected graphs; for the
    sequential models the construction order is the execution order.
    """

    def __init__(self, name: str = "graph"):
        self.name = name
        self.ops: List[Op] = []
        self._tensors: Dict[str, Tensor] = {}
        #: Batch size every non-weight tensor carries (see
        #: :func:`with_batch`). Builders construct batch-1 graphs.
        self.batch: int = 1

    # -- construction -------------------------------------------------------
    def tensor(
        self,
        name: str,
        shape: Sequence[int],
        dtype_bytes: int = 4,
        kind: str = "intermediate",
        alias_of: Optional[Tensor] = None,
    ) -> Tensor:
        if name in self._tensors:
            raise ValueError(f"duplicate tensor name {name!r}")
        # graph rewrites (remove_concats / split / fuse) rebuild tensors
        # through here: they inherit the graph's batch so a batched graph's
        # transforms stay batched (weights are always shared across images)
        batch = self.batch if kind != "weight" else 1
        t = Tensor(name, tuple(int(s) for s in shape), dtype_bytes, kind,
                   alias_of, batch=batch)
        self._tensors[name] = t
        return t

    def add(self, op: Op) -> Tensor:
        self.ops.append(op)
        return op.outputs[0]

    def op(
        self,
        kind: str,
        inputs: Sequence[Tensor],
        out_shape: Sequence[int],
        params: Optional[Dict[str, Any]] = None,
        name: str = "",
        dtype_bytes: Optional[int] = None,
        out_kind: str = "intermediate",
    ) -> Tensor:
        """Convenience: create the output tensor and append the op."""
        inputs = list(inputs)
        db = dtype_bytes if dtype_bytes is not None else inputs[0].dtype_bytes
        oname = name or f"{kind}_{len(self.ops)}"
        alias = inputs[0].storage() if kind == "reshape" else None
        out = self.tensor(f"{oname}_out", out_shape, db, out_kind, alias_of=alias)
        self.add(Op(kind, inputs, [out], dict(params or {}), oname))
        return out

    # -- queries ------------------------------------------------------------
    @property
    def tensors(self) -> List[Tensor]:
        return list(self._tensors.values())

    def arena_tensors(self) -> List[Tensor]:
        """Tensors that occupy the arena: everything except weights and
        fused-chain scratch, with aliases resolved to their storage owner."""
        seen: List[Tensor] = []
        for t in self._tensors.values():
            s = t.storage()
            if s.kind not in ("weight", "scratch") and s not in seen:
                seen.append(s)
        return seen

    def data_tensors(self) -> List[Tensor]:
        """All value-carrying storage tensors — arena tensors *plus* fused-
        chain scratch (everything except weights). Calibration iterates
        these: scratch tensors still need activation ranges even though
        they never occupy the arena."""
        seen: List[Tensor] = []
        for t in self._tensors.values():
            s = t.storage()
            if s.kind != "weight" and s not in seen:
                seen.append(s)
        return seen

    def scopes(self, order: Optional[Sequence[Op]] = None) -> Dict[Tensor, Tuple[int, int]]:
        """Liveness scope [first_def_or_use, last_use] per storage tensor.

        Model inputs are live from step 0; model outputs are live to the end.
        Indices refer to positions in ``order`` (default: self.ops).
        """
        order = list(order if order is not None else self.ops)
        n = len(order)
        first: Dict[Tensor, int] = {}
        last: Dict[Tensor, int] = {}
        for i, op in enumerate(order):
            for t in op.inputs:
                s = t.storage()
                if s.kind in ("weight", "scratch"):
                    continue
                first.setdefault(s, 0 if s.kind == "input" else i)
                last[s] = i
            for t in op.outputs:
                s = t.storage()
                if s.kind == "scratch":
                    continue
                first.setdefault(s, i)
                last.setdefault(s, i)
                if s.kind == "output":
                    last[s] = n - 1
        # model inputs never consumed / outputs never produced still get scopes
        for t in self.arena_tensors():
            first.setdefault(t, 0)
            last.setdefault(t, n - 1 if t.kind == "output" else first[t])
        return {t: (first[t], last[t]) for t in first}

    def producers(self) -> Dict[Tensor, Op]:
        prod: Dict[Tensor, Op] = {}
        for op in self.ops:
            for t in op.outputs:
                prod[t.storage()] = op
        return prod

    def validate(self) -> None:
        """Basic well-formedness: every non-input intermediate is produced
        before it is consumed (in list order)."""
        produced = {t.storage() for op in self.ops for t in op.outputs}
        available = {
            t.storage()
            for t in self._tensors.values()
            if t.kind in ("input", "weight")
        }
        for op in self.ops:
            for t in op.inputs:
                s = t.storage()
                if s not in available and s not in produced:
                    raise ValueError(f"{op}: input {s.name} never produced")
        # order check
        avail = {
            t.storage()
            for t in self._tensors.values()
            if t.kind in ("input", "weight")
        }
        for op in self.ops:
            for t in op.inputs:
                if t.storage() not in avail:
                    raise ValueError(
                        f"{op}: input {t.name} consumed before production"
                    )
            for t in op.outputs:
                avail.add(t.storage())

    def peak_bytes_lower_bound(self) -> int:
        """max over ops of (sum of live tensor sizes) — the no-overlap floor."""
        scopes = self.scopes()
        peak = 0
        for i in range(len(self.ops)):
            live = sum(t.nbytes for t, (a, b) in scopes.items() if a <= i <= b)
            peak = max(peak, live)
        return peak

    def __repr__(self) -> str:  # pragma: no cover
        return f"Graph({self.name}, {len(self.ops)} ops, {len(self._tensors)} tensors)"


def with_batch(graph: Graph, batch: int) -> Graph:
    """A deep copy of ``graph`` with every non-weight tensor carrying a
    leading ``batch`` axis (weights are shared across the batch and stay
    batch-1). Per-image shapes, op params and execution order are untouched
    — the batch axis is an attribute, not a literal shape dim, so band row
    ranges, concat axes and overlap geometry keep their per-image meaning.
    ``batch == 1`` returns the input graph unchanged (no copy), keeping
    batch-1 compiles bit-identical to the pre-batch pipeline."""
    b = int(batch)
    if b < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if b == 1 and graph.batch == 1:
        return graph
    g = Graph(graph.name)
    g.batch = b
    mapped: Dict[int, Tensor] = {}

    def conv(t: Optional[Tensor]) -> Optional[Tensor]:
        if t is None:
            return None
        nt = mapped.get(id(t))
        if nt is None:
            nt = Tensor(t.name, t.shape, t.dtype_bytes, t.kind,
                        conv(t.alias_of),
                        batch=1 if t.kind == "weight" else b)
            mapped[id(t)] = nt
        return nt

    for t in graph._tensors.values():
        g._tensors[t.name] = conv(t)
    for op in graph.ops:
        g.ops.append(Op(op.kind, [conv(t) for t in op.inputs],
                        [conv(t) for t in op.outputs],
                        dict(op.params), op.name))
    return g


# ---------------------------------------------------------------------------
# Conv helpers shared by builders and overlap calculators
# ---------------------------------------------------------------------------


def conv_out_dim(in_dim: int, k: int, stride: int, padding: str, dilation: int = 1) -> int:
    eff_k = (k - 1) * dilation + 1
    if padding == "same":
        return -(-in_dim // stride)  # ceil
    if padding == "valid":
        return (in_dim - eff_k) // stride + 1
    raise ValueError(padding)


def pad_amount(in_dim: int, out_dim: int, k: int, stride: int, dilation: int = 1) -> int:
    """Leading pad, eq. (5)/(6) of the paper (TF SAME convention)."""
    total = max(0, (out_dim - 1) * stride + (k - 1) * dilation + 1 - in_dim)
    return total // 2


def band_range(op: Op) -> Optional[Tuple[int, int]]:
    """The nominal output-row range ``[r0, r1)`` a row-banded conv-family op
    computes (operation splitting, §II.A), or ``None`` for unbanded ops."""
    rr = op.params.get("row_range")
    return (int(rr[0]), int(rr[1])) if rr is not None else None


def op_pads(op: Op) -> Tuple[int, int]:
    """Leading ``(ph, pw)`` pads of a conv-family op — the one geometry
    source every O_s calculator, executor backend and legaliser shares.

    Row-banded ops (those carrying ``row_range``) use their explicit
    ``band_pad``: output-local row ``o`` reads input-local rows
    ``o*sh - ph + fy*dh``, exactly the plain-conv loop nest, so a band is an
    ordinary conv over its band shapes once this pad is substituted. ``ph``
    may be *negative* for a producer band (its output rows start deep inside
    the full input it reads). Unbanded ops derive pads from the ``padding``
    mode as before."""
    bp = op.params.get("band_pad")
    if bp is not None:
        return int(bp[0]), int(bp[1])
    ih, iw = op.inputs[0].shape[-3], op.inputs[0].shape[-2]
    oh, ow = op.output.shape[-3], op.output.shape[-2]
    kh, kw = op.params["kernel"]
    sh, sw = op.params.get("stride", (1, 1))
    dh, dw = op.params.get("dilation", (1, 1))
    if op.params.get("padding", "same") == "same":
        return pad_amount(ih, oh, kh, sh, dh), pad_amount(iw, ow, kw, sw, dw)
    return 0, 0
