"""Tensor-arena planners (paper §II.D + §IV).

Strategies:

- ``naive``          — classic greedy heap in execution order (allocate at
                       first use, free at last use, lowest-address-first).
                       This is the "Original" column of Table III.
- ``modified_heap``  — the paper's heuristic ordering: repeatedly allocate,
                       out of the frontier of unallocated tensors whose scope
                       overlaps an allocated one, the tensor that heap-packs
                       lowest. Forwards or backwards.
- ``dmo``            — modified heap, *backwards* (reverse execution order),
                       with the diagonal overlap relaxation: an op's input may
                       overlap the tail of the op's output by ``O_s`` bytes.

All planners return a :class:`Plan` mapping storage tensors to byte offsets,
with the peak arena size and a safety validator.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

from repro.core.graph import Graph, Op, Tensor, op_pads
from repro.core import overlap as overlap_mod

OverlapFn = Callable[[Op, int], int]


def _default_overlap(method: str = "auto", profile: str = "paper") -> OverlapFn:
    return lambda op, idx: overlap_mod.safe_overlap(op, idx, method=method,
                                                    profile=profile)


@dataclasses.dataclass(frozen=True)
class TensorLayout:
    """Byte-granular placement of one arena tensor view: the dtype width, the
    byte offset the planner chose for its storage, and the (derived) element
    offset. This is the layout contract between the planner and the executor
    backends — kernels index the flat *byte* arena with it, so mixed-dtype
    plans (int8 next to f32) need no implicit element size."""

    name: str
    shape: Tuple[int, ...]
    dtype_bytes: int
    byte_offset: int

    @property
    def elem_offset(self) -> int:
        return self.byte_offset // self.dtype_bytes

    @property
    def elems(self) -> int:
        n = 1
        for s in self.shape:
            n *= int(s)
        return n

    @property
    def nbytes(self) -> int:
        return self.elems * self.dtype_bytes


@dataclasses.dataclass(frozen=True)
class OpLayout:
    """Lowering record for one executed op: the op plus the layout of every
    data input (``None`` for non-arena weight inputs) and of the output."""

    op: Op
    inputs: Tuple[Optional[TensorLayout], ...]
    output: TensorLayout


@dataclasses.dataclass
class Plan:
    graph: Graph
    order: List[Op]
    offsets: Dict[Tensor, int]
    overlaps: Dict[Tuple[int, int], int]  # (op index, input index) -> O_s bytes
    strategy: str = ""

    def __getstate__(self):
        # derived state (the memoised default-tiling legalisation) must not
        # inflate pickled plans (disk plan cache)
        d = dict(self.__dict__)
        d.pop("_block_cache", None)
        d.pop("_window_cache", None)
        return d

    @property
    def peak_bytes(self) -> int:
        return max((off + t.nbytes for t, off in self.offsets.items()), default=0)

    def peak_bytes_by_dtype(self) -> Dict[int, int]:
        """Arena peak extent per dtype width (bytes): for each dtype, the
        highest end offset of any tensor of that width. Sums need not equal
        ``peak_bytes`` — dtypes share the one arena and may interleave."""
        out: Dict[int, int] = {}
        for t, off in self.offsets.items():
            out[t.dtype_bytes] = max(out.get(t.dtype_bytes, 0), off + t.nbytes)
        return out

    _DTYPE_NAMES = {1: "i8", 2: "f16", 4: "f32"}

    def dtype_peaks_report(self) -> str:
        """Human-readable per-dtype peaks, e.g. ``"i8:64KB"`` or
        ``"i8:1KB+f32:12KB"`` (the single formatter the benchmarks share)."""
        return "+".join(
            f"{self._DTYPE_NAMES.get(db, f'{db}B')}:{peak / 1024:.0f}KB"
            for db, peak in sorted(self.peak_bytes_by_dtype().items()))

    def offset_of(self, t: Tensor) -> int:
        return self.offsets[t.storage()]

    def _layout(self, t: Tensor) -> TensorLayout:
        s = t.storage()
        off = self.offsets[s]
        assert off % s.dtype_bytes == 0, \
            f"{s.name}: byte offset {off} not {s.dtype_bytes}-byte aligned"
        return TensorLayout(s.name, tuple(t.shape), s.dtype_bytes, off)

    def op_layouts(self) -> List[OpLayout]:
        """Flat-arena lowering metadata, one :class:`OpLayout` per executed op
        in order. Layouts carry per-tensor ``dtype_bytes`` alongside byte and
        element offsets, so backends execute mixed-dtype plans over a single
        flat byte arena. Aliases resolve to their storage owner, weight inputs
        (which live outside the arena) yield ``None``, and aliasing no-ops
        (``reshape``) are omitted — they move no bytes. Every offset is
        asserted ``dtype_bytes``-aligned (the placement invariant
        :func:`_lowest_feasible` maintains)."""
        out: List[OpLayout] = []
        for op in self.order:
            if op.kind == "reshape":
                continue
            ins: List[Optional[TensorLayout]] = []
            for t in op.inputs:
                if t.storage().kind == "weight":
                    ins.append(None)
                    continue
                ins.append(self._layout(t))
            out.append(OpLayout(op, tuple(ins), self._layout(op.output)))
        return out

    def validate(self, granularity: int = 1) -> None:
        """Assert no live value can be clobbered under the overlap rules.

        ``granularity`` is the clobber unit in bytes: 1 checks the paper's
        byte-granular invariant; a unit > 1 additionally requires every
        offset to be unit-aligned, rounds sizes up to whole units, and
        rounds an overlap's required input/output distance (``|out| -
        O_s``) *up* to whole units — the conservative direction for a
        runtime that clobbers whole blocks. Note this pads *byte* sizes,
        i.e. it models densely packed tensors; :class:`BlockPlan` overrides
        with the exact per-tensor row footprints."""
        g = max(1, int(granularity))
        pad = lambda n: -(-n // g) * g
        scopes = self.graph.scopes(self.order)
        tensors = list(self.offsets)
        if g > 1:
            for t in tensors:
                if self.offsets[t] % g:
                    raise AssertionError(
                        f"{t.name}: offset {self.offsets[t]} not aligned to "
                        f"the {g}-byte row")
        for i, a in enumerate(tensors):
            sa, ea = scopes[a]
            xa, na = self.offsets[a], pad(a.nbytes)
            for b in tensors[i + 1:]:
                sb, eb = scopes[b]
                if ea < sb or eb < sa:
                    continue  # time-disjoint
                xb, nb = self.offsets[b], pad(b.nbytes)
                if xa + na <= xb or xb + nb <= xa:
                    continue  # space-disjoint
                os_ = self._allowed_overlap(a, b, scopes)
                if os_ is None:
                    raise AssertionError(
                        f"plan clobbers: {a.name}@{xa} vs {b.name}@{xb}")
                inp, outp = os_
                xi, xo = self.offsets[inp], self.offsets[outp]
                dist = pad(outp.nbytes - os_bytes(self, inp, outp))
                if xi < xo + dist:
                    raise AssertionError(
                        f"overlap beyond O_s: {inp.name}@{xi} vs {outp.name}@{xo}")

    def _allowed_overlap(self, a: Tensor, b: Tensor, scopes):
        """If (a, b) are an (input, output) pair of some op with a recorded
        O_s, return them ordered (input, output); else None."""
        for (oi, ii), _ in self.overlaps.items():
            op = self.order[oi]
            inp = op.inputs[ii].storage()
            outp = op.output.storage()
            if {inp, outp} == {a, b}:
                return inp, outp
        return None

    def report(self) -> str:
        lines = [f"# plan {self.strategy}: peak {self.peak_bytes} bytes"]
        scopes = self.graph.scopes(self.order)
        for t in sorted(self.offsets, key=lambda t: self.offsets[t]):
            s, e = scopes[t]
            lines.append(
                f"  {t.name:32s} off={self.offsets[t]:>10d} size={t.nbytes:>10d}"
                f" scope=[{s},{e}]")
        return "\n".join(lines)


def os_bytes(plan: Plan, inp: Tensor, outp: Tensor) -> int:
    for (oi, ii), v in plan.overlaps.items():
        op = plan.order[oi]
        if op.inputs[ii].storage() is inp and op.output.storage() is outp:
            return v
    return 0


# ---------------------------------------------------------------------------
# Row-blocked (tiled) layout legalisation
# ---------------------------------------------------------------------------

#: Per-dtype-width VMEM tile (sublanes, lanes): the minor arena axis must be
#: a lanes multiple and row offsets land on sublane-tile boundaries — the
#: (8, 128) f32 / (32, 128) int8 native TPU tilings.
TPU_TILES: Dict[int, Tuple[int, int]] = {4: (8, 128), 2: (16, 128),
                                         1: (32, 128)}

#: Op kinds whose kernels stream output rows (and therefore read/write the
#: arena one whole row at a time — the shapes the row-granular O_s covers).
_ROW_STREAMING_KINDS = frozenset({"conv2d", "depthwise_conv2d", "pool"})


def pack_geometry(rowlen: int, arena_rowlen: int) -> Tuple[int, int]:
    """Packed addressing geometry ``(cols_per_row, row_span)`` for an image
    row of ``rowlen`` elements in an arena of ``arena_rowlen``-element rows:
    narrow image rows pack ``cols_per_row`` per arena row; an image row wider
    than the arena row spans ``row_span`` consecutive arena rows. Exactly one
    of the two factors exceeds 1 (both are 1 when ``rowlen`` fills the arena
    row)."""
    if rowlen <= arena_rowlen:
        return max(1, arena_rowlen // rowlen), 1
    return 1, -(-rowlen // arena_rowlen)


def _ar_of(r: int, c: int, k: int) -> int:
    """First arena row (block-relative) holding image row ``r`` under the
    packed geometry ``(c, k)``."""
    return r // c if c > 1 else r * k


def _ar_top(r: int, c: int, k: int) -> int:
    """Last arena row (block-relative) image row ``r`` touches."""
    return r // c if c > 1 else (r + 1) * k - 1


@dataclasses.dataclass(frozen=True)
class BlockLayout:
    """Row-blocked placement of one arena tensor: the tensor occupies
    ``rows`` consecutive arena rows starting at ``row_offset``, using the
    first ``rowlen`` elements of each row. Conv/pool operands keep image-row
    structure; on a legacy layout that is one image row per arena row
    (``rows = H``, ``rowlen = W*C``), on a packed layout ``cols_per_row``
    narrow image rows share each arena row (``rows = ceil(H/c)``, ``rowlen =
    c*(W*C)``) or one wide image row spans ``row_span`` arena rows (``rows =
    H*k``, ``rowlen`` = the full arena row). Every other tensor packs
    densely (``rowlen`` = the full arena row). The tail of each row — and of
    the final dense row — is tiling padding, accounted by
    :meth:`BlockPlan.padded_peak_bytes`."""

    name: str
    shape: Tuple[int, ...]
    dtype_bytes: int
    row_offset: int
    rows: int
    rowlen: int              # elements of each arena row this tensor uses
    cols_per_row: int = 1    # image rows packed per arena row
    row_span: int = 1        # arena rows spanned by one image row
    #: Leading batch axis: the block holds ``batch`` per-image sub-blocks of
    #: ``rows // batch`` arena rows each, back to back (each image is packed
    #: and padded independently, so image ``b`` starts at its own arena row
    #: — the per-image addressability the batched lowering relies on).
    batch: int = 1

    @property
    def elems(self) -> int:
        n = self.batch
        for s in self.shape:
            n *= int(s)
        return n

    @property
    def image_rows(self) -> int:
        """Arena rows of ONE image's sub-block."""
        return self.rows // self.batch

    def image_row_offset(self, b: int) -> int:
        """First arena row of image ``b``'s sub-block."""
        return self.row_offset + b * self.image_rows

    @property
    def image_rowlen(self) -> int:
        """Elements of one *image* row (= ``W*C`` for image layouts; the
        used row length for dense/legacy ones)."""
        if self.cols_per_row > 1:
            return self.rowlen // self.cols_per_row
        if self.row_span > 1:
            return int(self.shape[-2]) * int(self.shape[-1])
        return self.rowlen

    def addr(self, r: int, col: int) -> Tuple[int, int]:
        """(block-relative arena row, lane offset) of image-row element
        ``(r, col)`` — the packed addressing every kernel route uses."""
        if self.cols_per_row > 1:
            rl = self.rowlen // self.cols_per_row
            return r // self.cols_per_row, (r % self.cols_per_row) * rl + col
        if self.row_span > 1:
            return r * self.row_span + col // self.rowlen, col % self.rowlen
        return r, col

    def image_addr(self, ar: int, lane: int) -> Tuple[int, int]:
        """Inverse of :meth:`addr`: the ``(image_row, col)`` stored at
        block-relative arena row ``ar``, lane ``lane``."""
        if self.cols_per_row > 1:
            rl = self.rowlen // self.cols_per_row
            return ar * self.cols_per_row + lane // rl, lane % rl
        if self.row_span > 1:
            return (ar // self.row_span,
                    (ar % self.row_span) * self.rowlen + lane)
        return ar, lane


@dataclasses.dataclass
class BlockPlan(Plan):
    """A byte :class:`Plan` legalised onto the row-blocked arena grid.

    Still a valid byte-granular plan — ``offsets`` hold the (row-aligned)
    byte offsets and ``overlaps`` the row-rounded effective O_s, so the
    numpy backend and ``validate()`` work unchanged — plus the block-level
    contract the compiled Pallas program lowers from: per-tensor
    :class:`BlockLayout` records over a shared ``(total_rows, arena_rowlen)``
    arena. ``validate()`` additionally re-checks the no-clobber invariant at
    *row* granularity (a blocked kernel clobbers whole rows)."""

    source: Optional[Plan] = None      #: the byte-granular plan legalised
    tiling: Tuple[int, int] = (8, 128)  #: (sublanes, lanes) for the dtype
    arena_rowlen: int = 128            #: arena row length in elements
    total_rows: int = 0                #: arena rows (sublane-rounded)
    layouts: Dict[Tensor, "BlockLayout"] = dataclasses.field(
        default_factory=dict)
    row_overlaps: Dict[Tuple[int, int], int] = dataclasses.field(
        default_factory=dict)          #: (op idx, input idx) -> O_s in rows
    packing: str = "legacy"            #: "legacy" | "packed" row layout
    legacy_padded_bytes: int = 0       #: one-image-row-per-arena-row peak
    legacy_window_rows: int = 0        #: legacy streaming max_window_rows

    @property
    def dtype_bytes(self) -> int:
        return (next(iter(self.layouts.values())).dtype_bytes
                if self.layouts else 4)

    @property
    def row_bytes(self) -> int:
        return self.arena_rowlen * self.dtype_bytes

    @property
    def padded_peak_bytes(self) -> int:
        """The arena footprint a row-blocked runtime actually allocates:
        every reserved row at full (lane-tiled) width."""
        return self.total_rows * self.row_bytes

    @property
    def padding_overhead_pct(self) -> float:
        """Tiling cost: legalised (row-blocked) peak over the byte-granular
        source peak, as +%."""
        base = (self.source or self).peak_bytes
        if base == 0:
            return 0.0
        return 100.0 * (self.padded_peak_bytes / base - 1.0)

    @property
    def row_align(self) -> int:
        """Row-offset alignment of this layout's placements: the sublane
        tile on legacy layouts; packed layouts place at a finer 8-row grain
        (a whole sublane tile of slack per int8 tensor would give back much
        of the packing win — DMA and in-kernel ``pl.dslice`` addressing take
        arbitrary row offsets)."""
        sub = self.tiling[0]
        return min(sub, 8) if self.packing == "packed" else sub

    @property
    def legacy_padding_overhead_pct(self) -> float:
        """The one-image-row-per-arena-row (legacy) layout's padding
        overhead over the byte-granular source peak — what
        :attr:`padding_overhead_pct` was before packing. Equal to the packed
        overhead when the never-regress fallback kept the legacy layout."""
        base = (self.source or self).peak_bytes
        legacy = self.legacy_padded_bytes or self.padded_peak_bytes
        if base == 0:
            return 0.0
        return 100.0 * (legacy / base - 1.0)

    def layout_of(self, t: Tensor) -> "BlockLayout":
        return self.layouts[t.storage()]

    def validate(self, granularity: Optional[int] = None) -> None:
        """Byte-granular check plus the exact block-footprint check: live
        tensors never share an arena *row* beyond their row-granular O_s
        distance. The generic ``Plan.validate(granularity)`` pads byte
        sizes, which under-counts image-layout footprints (H arena rows at
        ``rowlen < arena_rowlen`` hold fewer bytes than they reserve), so
        this override walks the real :class:`BlockLayout` row extents."""
        super().validate()
        if granularity is not None:
            super().validate(granularity)
        self._validate_rows()

    def _os_rows(self, inp: Tensor, outp: Tensor) -> int:
        for (oi, ii), v in self.row_overlaps.items():
            op = self.order[oi]
            if op.inputs[ii].storage() is inp \
                    and op.output.storage() is outp:
                return v
        return 0

    def _validate_rows(self) -> None:
        """No-clobber at arena-row granularity over the BlockLayout
        footprints (a blocked kernel clobbers whole reserved rows)."""
        scopes = self.graph.scopes(self.order)
        lays = self.layouts
        tensors = list(lays)
        for i, a in enumerate(tensors):
            sa, ea = scopes[a]
            xa, na = lays[a].row_offset, lays[a].rows
            for b in tensors[i + 1:]:
                sb, eb = scopes[b]
                if ea < sb or eb < sa:
                    continue  # time-disjoint
                xb, nb = lays[b].row_offset, lays[b].rows
                if xa + na <= xb or xb + nb <= xa:
                    continue  # row-disjoint
                os_ = self._allowed_overlap(a, b, scopes)
                if os_ is None:
                    raise AssertionError(
                        f"block plan clobbers rows: {a.name}@r{xa} "
                        f"vs {b.name}@r{xb}")
                inp, outp = os_
                xi = lays[inp].row_offset
                xo = lays[outp].row_offset
                dist = lays[outp].rows - self._os_rows(inp, outp)
                if xi < xo + dist:
                    raise AssertionError(
                        f"row overlap beyond O_s: {inp.name}@r{xi} "
                        f"vs {outp.name}@r{xo} (need distance {dist})")

    def window_schedule(self) -> "WindowSchedule":
        """The streaming live-window schedule for this legalisation
        (memoised — reports, the streaming backend and the benchmarks all
        ask for the same schedule)."""
        cached = self.__dict__.get("_window_cache")
        if cached is None:
            cached = window_schedule(self)
            self.__dict__["_window_cache"] = cached
        return cached

    def report(self) -> str:
        base = (self.source or self).peak_bytes
        ws = self.window_schedule()
        lines = [super().report(),
                 f"  row-blocked: {self.total_rows} rows x "
                 f"{self.arena_rowlen} elems ({self.padded_peak_bytes} bytes,"
                 f" tile {self.tiling[0]}x{self.tiling[1]}) = "
                 f"+{self.padding_overhead_pct:.1f}% padding over "
                 f"byte-granular peak {base}"]
        if self.packing == "packed":
            lines.append(
                f"  packed rows: +{self.padding_overhead_pct:.1f}% vs "
                f"legacy +{self.legacy_padding_overhead_pct:.1f}% "
                f"({self.legacy_padded_bytes} bytes, "
                f"max window {self.legacy_window_rows} rows)")
        lines.append("  " + ws.summary())
        return "\n".join(lines)


def _min_row_distance(op: Op, ci: int = 1, ki: int = 1,
                      co: int = 1, ko: int = 1) -> int:
    """Smallest safe input/output *arena-row* distance for a row-streaming
    op: writing output image row ``i`` (which clobbers the whole arena rows
    it touches, padding and co-packed neighbours included) must leave every
    input row that rows ``> i`` still read intact. Exact by enumeration over
    output rows — the analytic byte O_s rounded to rows can overstate the
    safe overlap when the output's dense rows are narrower than the input's
    (e.g. width-strided convs), so the legaliser takes the max of both
    distances. ``(ci, ki)`` / ``(co, ko)`` are the operands' packed
    ``(cols_per_row, row_span)`` geometries; the defaults reproduce the
    legacy one-image-row-per-arena-row distance exactly."""
    if op.kind not in _ROW_STREAMING_KINDS:
        return 0
    ih = op.inputs[0].shape[-3]
    oh = op.output.shape[-3]
    kh = op.params["kernel"][0]
    sh = op.params.get("stride", (1, 1))[0]
    dh = op.params.get("dilation", (1, 1))[0]
    ph = op_pads(op)[0]  # band-aware: banded ops enumerate band-local rows
    d = 0
    for nxt in range(1, oh):
        lo = None
        for fy in range(kh):
            iy = nxt * sh - ph + fy * dh
            if 0 <= iy < ih:
                lo = iy
                break
        if lo is None:
            continue
        d = max(d, _ar_top(nxt - 1, co, ko) - _ar_of(lo, ci, ki) + 1)
    return d


def _image_layouts(plan: Plan) -> Dict[Tensor, Tuple[int, int]]:
    """Storage tensors that must keep one *image* row per arena row (they
    feed or come out of a row-streaming kernel): storage -> (H, W*C)."""
    image: Dict[Tensor, Tuple[int, int]] = {}
    for op in plan.order:
        if op.kind not in _ROW_STREAMING_KINDS:
            continue
        for t in (op.inputs[0], op.output):
            shp = tuple(t.shape)
            lead = 1
            for s in shp[:-3]:
                lead *= int(s)
            if len(shp) < 3 or lead != 1:
                raise ValueError(
                    f"{op.name}: operand {t.name} shape {shp} has no "
                    "batch-1 HWC row structure to block")
            s = t.storage()
            rows_used = (int(shp[-3]), int(shp[-2]) * int(shp[-1]))
            if image.setdefault(s, rows_used) != rows_used:
                raise ValueError(
                    f"{s.name}: conflicting image-row layouts "
                    f"{image[s]} vs {rows_used} (aggregated views cannot "
                    "be row-blocked)")
    return image


def _legalise_at(plan: Plan, sub: int, lanes: int, db: int,
                 image: Dict[Tensor, Tuple[int, int]], arena_rowlen: int,
                 packed: bool) -> BlockPlan:
    """One legalisation at a fixed ``arena_rowlen``. ``packed=False`` is the
    legacy layout (one image row per arena row, sublane-aligned placement,
    byte O_s distance rounded to whole rows — bit-identical to the pre-
    packing legaliser); ``packed=True`` derives per-tensor
    ``(cols_per_row, row_span)`` geometry from :func:`pack_geometry`, the
    O_s distance in packed arena-row units, and places at the finer packed
    row alignment."""
    tensors = list(plan.offsets)
    row_bytes = arena_rowlen * db

    # Per-image geometry times the batch: each image's sub-block is packed
    # and padded independently (rows = batch * per-image rows), so image b
    # of any operand starts at its own arena row — the addressability the
    # batched per-image lowering and the batched row O_s both rely on.
    rows: Dict[Tensor, int] = {}
    img_rows: Dict[Tensor, int] = {}
    rowlen: Dict[Tensor, int] = {}
    addr: Dict[Tensor, Tuple[int, int]] = {}
    for t in tensors:
        if t in image:
            h, rl = image[t]
            c, k = pack_geometry(rl, arena_rowlen) if packed else (1, 1)
            addr[t] = (c, k)
            img_rows[t] = -(-h // c) if c > 1 else h * k
            rowlen[t] = c * rl if k == 1 else arena_rowlen
        else:
            addr[t] = (1, 1)
            img_rows[t] = -(-t.image_elems // arena_rowlen)
            rowlen[t] = arena_rowlen
        rows[t] = t.batch * img_rows[t]

    # row-granular O_s per recorded overlap: the *per-image* byte distance
    # re-derived in (packed) arena-row units, stiffened by the exact
    # row-streaming bound, then scaled to the batch exactly like
    # :func:`batched_os_bytes` — D_B = D_1 + (B-1) * max(0, out - in)
    # per-image arena rows (batch-major per-image execution over the
    # per-image-padded sub-blocks)
    row_overlaps: Dict[Tuple[int, int], int] = {}
    for (oi, ii), v in plan.overlaps.items():
        op = plan.order[oi]
        outp = op.output.storage()
        inp = op.inputs[ii].storage()
        B = outp.batch
        v1 = v  # per-image byte O_s (undo the batched_os_bytes scaling)
        if B > 1:
            v1 = max(0, v - (B - 1) * min(inp.image_nbytes,
                                          outp.image_nbytes))
        if not packed:
            dist = -(-(outp.image_nbytes - v1) // row_bytes)
            dist = max(dist, _min_row_distance(op))
        else:
            co, ko = addr[outp]
            # last clobber-endangered element -> its last packed arena row
            last = -(-(outp.image_nbytes - v1) // db) - 1
            if outp in image:
                h, rl = image[outp]
                dist = _ar_top(min(last // rl, h - 1), co, ko) + 1
            else:
                dist = last // arena_rowlen + 1
            ci, ki = addr.get(inp, (1, 1))
            dist = max(dist, _min_row_distance(op, ci, ki, co, ko))
        if B > 1:
            dist += (B - 1) * max(0, img_rows[outp] - img_rows.get(inp, 0))
        row_overlaps[(oi, ii)] = max(0, rows[outp] - dist)

    align = min(sub, 8) if packed else sub
    scopes = plan.graph.scopes(plan.order)
    placed: Dict[Tensor, int] = {}
    for t in sorted(tensors, key=lambda t: (plan.offsets[t], -t.nbytes)):
        placed[t] = _lowest_feasible(t, placed, scopes, plan.order,
                                     row_overlaps, sizes=rows, align=align)
    total = max((placed[t] + rows[t] for t in tensors), default=0)
    total = -(-total // sub) * sub

    layouts = {
        t: BlockLayout(t.name, tuple(t.shape), db, placed[t], rows[t],
                       rowlen[t], cols_per_row=addr[t][0],
                       row_span=addr[t][1], batch=t.batch)
        for t in tensors
    }
    # the legalised plan re-expressed in bytes: offsets are row-aligned and
    # each O_s is the row-rounded effective overlap (>= 0), so byte-level
    # validate()/numpy execution see a normal — just padded — plan
    offsets = {t: placed[t] * row_bytes for t in tensors}
    overlaps: Dict[Tuple[int, int], int] = {}
    for (oi, ii), os_rows in row_overlaps.items():
        outp = plan.order[oi].output.storage()
        dist_b = (rows[outp] - os_rows) * row_bytes
        overlaps[(oi, ii)] = max(0, outp.nbytes - dist_b)
    return BlockPlan(plan.graph, list(plan.order), offsets, overlaps,
                     plan.strategy + "+blocks", source=plan,
                     tiling=(sub, lanes), arena_rowlen=arena_rowlen,
                     total_rows=total, layouts=layouts,
                     row_overlaps=row_overlaps,
                     packing="packed" if packed else "legacy")


def _packed_candidates(image: Dict[Tensor, Tuple[int, int]], lanes: int,
                       legacy_rowlen: int) -> List[int]:
    """Candidate packed arena rowlens: each distinct image rowlen rounded to
    lanes (packing is densest when the arena row is a small multiple of the
    image rows it holds), the 1.5x points between them (two narrow rows plus
    half a wider one — the winner on layer pyramids whose widths halve), the
    lane tile and its double, and the legacy rowlen itself (pure re-derive:
    span-free, but packed O_s and alignment). Wider-than-legacy rows can
    only add padding, so candidates cap at ``legacy_rowlen``."""
    rls = sorted({used for _, used in image.values()})
    cands = {-(-rl // lanes) * lanes for rl in rls}
    cands |= {-(-(3 * rl) // (2 * lanes)) * lanes for rl in rls}
    cands |= {legacy_rowlen, lanes, 2 * lanes}
    return sorted(c for c in cands if 0 < c <= legacy_rowlen)


def _best_packed(plan: Plan, sub: int, lanes: int, db: int,
                 image: Dict[Tensor, Tuple[int, int]], legacy_rowlen: int,
                 legacy_bp: BlockPlan, force: bool) -> Optional[BlockPlan]:
    """Sweep the packed candidate rowlens and return the best packed
    legalisation, or ``None`` when none beats the legacy layout (the
    never-regress fallback). "Beats" is lexicographic (padded peak, max
    streaming window): a candidate must not regress either metric vs legacy
    and must strictly improve at least one. ``force=True`` (the
    ``packing="packed"`` override) returns the best candidate even when
    legacy wins."""
    if not image:
        return None
    legacy_padded = legacy_bp.padded_peak_bytes
    legacy_win = legacy_bp.window_schedule().max_window_rows
    best: Optional[BlockPlan] = None
    best_key = None
    for rowlen in _packed_candidates(image, lanes, legacy_rowlen):
        bp = _legalise_at(plan, sub, lanes, db, image, rowlen, packed=True)
        key = (bp.padded_peak_bytes, bp.window_schedule().max_window_rows)
        if not force and (key[0] > legacy_padded or key[1] > legacy_win):
            continue
        if best_key is None or key < best_key:
            best, best_key = bp, key
    if best is None:
        return None
    if not force and best_key >= (legacy_padded, legacy_win):
        return None
    best.legacy_padded_bytes = legacy_padded
    best.legacy_window_rows = legacy_win
    return best


def legalise_for_blocks(plan: Plan,
                        tiling: Optional[Mapping[int, Tuple[int, int]]] = None,
                        packing: str = "auto") -> BlockPlan:
    """Legalise a byte-granular plan onto the row-blocked arena grid.

    Every arena tensor gets a ``(rows, rowlen)`` block shape and an aligned
    row offset (per-dtype tiles: (8, 128) f32, (32, 128) int8); each op's
    diagonal distance is re-derived at row granularity — the byte distance
    ``|out| - O_s`` rounded *up* to whole rows (the ``dmo_arena_dwconv``
    rule), stiffened by the exact row-streaming bound of
    :func:`_min_row_distance`. Placement re-runs the lowest-feasible-offset
    allocator in row units over the same liveness scopes, inserting tensors
    in the source plan's (byte-offset) order, so the legalised plan keeps
    the source's packing structure.

    ``packing`` selects the row layout family:

    - ``"legacy"`` — one image row per lane-tiled arena row whose length is
      set by the widest image row (the pre-packing layout, bit-identical);
    - ``"packed"`` — pack ``cols_per_row`` narrow image rows per arena row
      (or span wide rows over ``row_span`` arena rows) at the best candidate
      rowlen, cutting the lane-padding tax;
    - ``"auto"`` (default) — packed when it beats legacy on (padded peak,
      max streaming window), else the legacy layout: never regress.

    Raises ``ValueError`` for plans no row-blocked arena can express
    (mixed-dtype plans — one typed 2-D buffer has one element size —
    unsupported dtype widths, or aggregated concat-removal views), and
    ``AssertionError`` when the *source* plan is itself unsafe: the
    legaliser re-places tensors, so it must refuse to silently repair a
    clobbering layout."""
    if packing not in ("auto", "packed", "legacy"):
        raise ValueError(f"unknown packing {packing!r}: "
                         "expected auto|packed|legacy")
    if tiling is None:
        # memoised per plan: executors, reports and benchmarks all legalise
        # the same plan, and the candidate sweep + O(T^2) validates per call
        # would otherwise skew execution timings
        cached = plan.__dict__.get("_block_cache")
        if cached is not None and packing in cached:
            return cached[packing]
    tiles = dict(TPU_TILES) if tiling is None else dict(tiling)
    tensors = list(plan.offsets)
    widths = {t.dtype_bytes for t in tensors}
    if len(widths) > 1:
        raise ValueError(
            f"mixed-dtype plan ({sorted(widths)}-byte tensors) cannot be "
            "row-blocked: a typed (rows, rowlen) arena has one element size")
    db = widths.pop() if widths else 4
    if db not in tiles:
        raise ValueError(f"no block tiling for {db}-byte tensors "
                         f"(tilings: {sorted(tiles)})")
    if any(t.alias_of is not None and t.elems != t.storage().elems
           for t in plan.graph.tensors):
        raise ValueError("aggregated views (strided offsets) cannot be "
                         "row-blocked")
    plan.validate()
    sub, lanes = tiles[db]
    image = _image_layouts(plan)

    # legacy arena row length: every image row must fit one arena row
    need = max([lanes] + [used for _, used in image.values()])
    legacy_rowlen = -(-need // lanes) * lanes

    bp = _legalise_at(plan, sub, lanes, db, image, legacy_rowlen,
                      packed=False)
    if packing != "legacy":
        packed_bp = _best_packed(plan, sub, lanes, db, image, legacy_rowlen,
                                 bp, force=(packing == "packed"))
        if packed_bp is not None:
            bp = packed_bp
    bp.validate()
    if tiling is None:
        plan.__dict__.setdefault("_block_cache", {})[packing] = bp
    return bp


# ---------------------------------------------------------------------------
# Streaming live-window schedules
# ---------------------------------------------------------------------------


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def staged_slots(in_rows: Sequence[int], out_rows: int, sub: int,
                 ) -> Tuple[Tuple[int, ...], int, int]:
    """Scratch packing for a staged (whole-tensor) streaming op: operand
    blocks packed back-to-back, output last, total rounded up to the
    sublane tile. Returns ``(input slot row offsets, output slot row
    offset, total scratch rows)``. Blocks pack *tight* — the arena-side DMA
    offsets stay tile-aligned (placement guarantees it) and that is the
    side alignment matters on — so a staged op costs the sum of its block
    heights, not the span between scattered placements. The kernel layer
    and the planner both derive the packing from this one function, so the
    scratch a kernel allocates always matches the resident rows the
    schedule reports."""
    offs: List[int] = []
    cur = 0
    for r in in_rows:
        offs.append(cur)
        cur += int(r)
    out_slot = cur
    cur += int(out_rows)
    return tuple(offs), out_slot, _round_up(cur, sub)


def fused_slots(members: Sequence[Op], size_of, align: int = 1,
                round_to: int = 1, include_io: bool = False,
                ) -> Tuple[Dict[Tensor, int], int]:
    """Scratch-slot packing for one fused band chain.

    The chain's internal tensors (every member output except the last
    member's — the terminal, arena-written concat) live only inside the
    fused kernel's VMEM scratch. This runs the lowest-feasible-offset
    allocator over *member-local* liveness scopes (units are whatever
    ``size_of`` returns — rows for the blocked/streaming programs, bytes
    for the flat one), so a mid band's slot is reused as soon as its
    consumer band has read it, while the per-band outputs accumulate until
    the concat. ``include_io=True`` additionally packs the chain's external
    inputs and its terminal output into the scratch (the streaming program
    stages *everything* in VMEM: inputs are DMA'd up front, the output is
    DMA'd back at the end) — and since an external input dies at its last
    in-chain read, the output slot can reuse its space.

    Slots pack tight (like :func:`staged_slots` — the arena-side DMA
    offsets are the aligned side); only the total is rounded up to
    ``round_to``. Returns ``(slot offset per tensor, total scratch
    units)``. The kernel layer, the window schedule and the FusePass budget
    estimate all derive the packing from this one function."""
    n = len(members)
    internal = {op.output.storage() for op in members[:-1]}
    first: Dict[Tensor, int] = {}
    last: Dict[Tensor, int] = {}
    tensors: List[Tensor] = []

    def touch(s: Tensor, i: int) -> None:
        if s not in first:
            first[s] = i
            tensors.append(s)
        last[s] = max(last.get(s, i), i)

    for i, op in enumerate(members):
        for t in op.inputs:
            s = t.storage()
            if s.kind == "weight":
                continue
            if s in internal:
                touch(s, i)
            elif include_io:
                touch(s, 0)        # resident from the up-front DMA
                last[s] = max(last[s], i)
        s = op.output.storage()
        if s in internal:
            touch(s, i)
        elif include_io:
            touch(s, i)
            last[s] = n - 1        # held until the write-back DMA
    scopes = {s: (first[s], last[s]) for s in tensors}
    sizes = {s: int(size_of(s)) for s in tensors}
    placed: Dict[Tensor, int] = {}
    for s in tensors:              # first-touch (production) order
        placed[s] = _lowest_feasible(s, placed, scopes, list(members), {},
                                     sizes=sizes, align=align)
    total = max((placed[s] + sizes[s] for s in tensors), default=0)
    return placed, _round_up(total, max(1, round_to))


def _roll_geometry(op: Op) -> Tuple[int, int, int, int]:
    """(kh, sh, dh, ph) of a row-streaming op, band-aware."""
    kh = op.params["kernel"][0]
    sh = op.params.get("stride", (1, 1))[0]
    dh = (op.params.get("dilation", (1, 1))[0]
          if op.kind != "pool" else 1)
    ph = op_pads(op)[0]
    return kh, sh, dh, ph


def tile_rows(co: int, ko: int, sub: int) -> int:
    """Output *image* rows per streaming grid tile under the packed output
    geometry ``(co, ko)``: the smallest multiple of ``cols_per_row`` that
    covers ``sub`` image rows, so every arena row's lane phases complete
    within one tile while the per-tile *input* span (and with it the rolling
    window) stays at its legacy size instead of scaling with the packing
    factor. ``sub`` image rows on a legacy layout."""
    if co > 1:
        return -(-sub // co) * co
    return max(1, sub // ko)


def tile_arena_rows(co: int, ko: int, sub: int) -> int:
    """Arena rows one streaming output tile occupies (sublane-rounded):
    ``sub`` unless one image row spans more than a sublane tile."""
    tr = tile_rows(co, ko, sub)
    return _round_up(_ar_top(tr - 1, co, ko) + 1, sub)


def rolling_starts(op: Op, xi: int, xo: int, ih: int, oh: int, sub: int,
                   total_rows: int,
                   in_addr: Tuple[int, int] = (1, 1),
                   out_addr: Tuple[int, int] = (1, 1),
                   ) -> Tuple[Tuple[int, ...], int]:
    """Per-tile input-window fetch starts for a row-streaming op.

    The op walks output rows in tiles of ``sub`` rows (the dtype's sublane
    tile). The tile covering output rows ``[a, b)`` needs the input rows
    its taps may touch — ``iy = oy*sh - ph + fy*dh`` clamped exactly like
    the kernels clamp it — a contiguous input band whose height is bounded
    by ``tile*stride + kernel halo``, independent of where the placement
    put the operands. Output rows live in their own scratch tile, so the
    resident window is ``win_in + sub`` rows however far apart input and
    output were placed.

    Fetches are fixed-size (``win_in`` rows, sublane-rounded) starting at
    ``starts[t]`` (arena rows, aligned), clamped so the fetch never runs
    past the arena; over-fetched rows are never read unmasked (reads
    outside the valid input rows are the kernels' clamped+masked taps) and
    never written back (write-back covers exactly the computed rows).

    The O_s row invariant makes split input/output staging exact: an op's
    write to output row ``oy`` only ever clobbers arena input rows no
    later tap re-reads (that is what the diagonal distance guarantees), so
    no read inside the op can observe its own writes and staging the input
    band separately from the output tile preserves blocked-mode semantics
    row for row.

    ``ih``/``oh`` are *image* heights; ``in_addr``/``out_addr`` the packed
    ``(cols_per_row, row_span)`` geometries (legacy defaults: one image row
    per arena row, tiles of ``sub`` rows). Returns
    ``(starts per tile, win_in)`` in arena rows."""
    kh, sh, dh, ph = _roll_geometry(op)
    ci, ki = in_addr
    co, ko = out_addr
    tr = tile_rows(co, ko, sub)
    in_arena_rows = -(-ih // ci) if ci > 1 else ih * ki
    need, tiles = 0, []
    for a in range(0, oh, tr):
        b = min(a + tr, oh)
        iy_lo = min(max(a * sh - ph, 0), ih - 1)
        iy_hi = min(max((b - 1) * sh - ph + (kh - 1) * dh, 0), ih - 1)
        s_t = (_ar_of(iy_lo, ci, ki) // sub) * sub
        tiles.append(s_t)
        need = max(need, _ar_top(iy_hi, ci, ki) - s_t + 1)
    win_in = min(_round_up(need, sub), _round_up(in_arena_rows, sub))
    starts = tuple(max(0, min(xi + s_t, total_rows - win_in))
                   for s_t in tiles)
    return starts, win_in


@dataclasses.dataclass(frozen=True)
class OpWindow:
    """One op's live window in the streaming schedule: the contiguous
    arena-row extent ``[lo, hi)`` it may touch, the live-window rows
    (``win_rows``) and the scratch rows its streaming program allocates
    (``resident_rows`` — the rolling input window is double-buffered, so
    resident exceeds the live window by one input-window slot).
    ``starts`` is the per-output-tile fetch start table for rolling
    (conv / depthwise / pool) ops; empty for staged whole-tensor ops."""

    op_name: str
    kind: str
    lo: int
    hi: int
    win_rows: int
    resident_rows: int
    starts: Tuple[int, ...] = ()

    @property
    def rolling(self) -> bool:
        return bool(self.starts)


@dataclasses.dataclass(frozen=True)
class WindowSchedule:
    """The live-window row schedule of a :class:`BlockPlan`: per executed op
    (reshapes excluded, same order the backends lower), the arena rows it
    may touch and the rows its streaming program keeps resident in VMEM,
    plus the whole-program bound ``max_window_rows`` — the quantity that
    replaces ``total_rows`` as the streaming executor's VMEM ceiling."""

    windows: Tuple[OpWindow, ...]
    total_rows: int
    arena_rowlen: int
    dtype_bytes: int

    @property
    def row_bytes(self) -> int:
        return self.arena_rowlen * self.dtype_bytes

    @property
    def max_window_rows(self) -> int:
        return max((w.win_rows for w in self.windows), default=0)

    @property
    def max_resident_bytes(self) -> int:
        """Peak scratch footprint of any one streaming op (all slots,
        double-buffering included)."""
        return max((w.resident_rows * self.row_bytes
                    for w in self.windows), default=0)

    def summary(self) -> str:
        pct = (100.0 * self.max_window_rows / self.total_rows
               if self.total_rows else 0.0)
        return (f"streaming windows: max {self.max_window_rows} rows live "
                f"of {self.total_rows} arena rows ({pct:.1f}%), "
                f"peak scratch {self.max_resident_bytes} bytes")

    def report(self) -> str:
        lines = [f"# window schedule: {self.summary()}"]
        for w in self.windows:
            tag = "roll" if w.rolling else "stage"
            lines.append(
                f"  {w.op_name:32s} {tag:5s} [{w.lo:>5d},{w.hi:>5d}) "
                f"live={w.win_rows:>5d} resident={w.resident_rows:>5d} rows")
        return "\n".join(lines)


def chain_addr_of(bplan: BlockPlan):
    """Packed geometry resolver for fused-chain operands: ``f(tensor
    storage) -> (cols_per_row, row_span)``. Arena tensors answer from their
    :class:`BlockLayout`; chain-internal scratch tensors (no layout) derive
    theirs from :func:`pack_geometry` on their image rowlen — the ONE rule
    the planner's windows, the backend's fused specs and the kernels'
    scratch addressing all share. Legacy layouts keep every operand at
    ``(1, 1)`` (one image row per scratch row)."""
    packed = bplan.packing == "packed"

    def addr_of(s: Tensor) -> Tuple[int, int]:
        lay = bplan.layouts.get(s)
        if lay is not None:
            return lay.cols_per_row, lay.row_span
        if not packed:
            return 1, 1
        rl = int(s.shape[-2]) * int(s.shape[-1])
        return pack_geometry(rl, bplan.arena_rowlen)

    return addr_of


def chain_rows_of(bplan: BlockPlan):
    """Arena/scratch row resolver for fused-chain operands: ``f(tensor
    storage) -> rows``, packed-geometry-aware via :func:`chain_addr_of`."""
    addr_of = chain_addr_of(bplan)

    def rows_of(s: Tensor) -> int:
        lay = bplan.layouts.get(s)
        if lay is not None:
            return lay.rows
        c, k = addr_of(s)
        h = int(s.shape[-3])
        return -(-h // c) if c > 1 else h * k

    return rows_of


def chain_image_rows_of(bplan: BlockPlan):
    """Per-IMAGE row resolver for fused-chain operands: like
    :func:`chain_rows_of` but for one image's sub-block — the unit the
    batched per-image fused lowering stages in VMEM. Identical to
    :func:`chain_rows_of` on batch-1 plans."""
    addr_of = chain_addr_of(bplan)

    def rows_of(s: Tensor) -> int:
        lay = bplan.layouts.get(s)
        if lay is not None:
            return lay.image_rows
        c, k = addr_of(s)
        h = int(s.shape[-3])
        return -(-h // c) if c > 1 else h * k

    return rows_of


def _fused_window(bplan: BlockPlan, members: Sequence[Op],
                  sub: int) -> OpWindow:
    """One staged window for a fused band chain. The streaming fused
    kernel DMAs every external-input block into VMEM up front, runs all
    chain stages inside the scratch buffer and writes only the terminal
    block back — so the resident rows are the ``include_io``
    :func:`fused_slots` packing (chain scratch plus the staged I/O blocks),
    and the row extent spans the external operands' arena placements.
    Chain-internal tensors have no layouts; their scratch rows come from
    the shared :func:`chain_image_rows_of` rule (one arena row per image
    row on legacy layouts, packed geometry on packed ones). A batched
    chain stages ALL images at once (its stages run op-major inside the
    one kernel, so every image of a member's output is live before the
    next member runs) — the VMEM window scales with the batch and the
    budget gate polices that honestly."""
    internal = {op.output.storage() for op in members[:-1]}
    irows_of = chain_image_rows_of(bplan)

    def rows_of(s: Tensor) -> int:
        return irows_of(s) * (s.batch if s.batch > 1 else 1)

    _, total = fused_slots(members, rows_of, round_to=sub, include_io=True)
    ext: List[BlockLayout] = []
    for op in members:
        for t in op.inputs:
            s = t.storage()
            if s.kind != "weight" and s not in internal:
                ext.append(bplan.layouts[s])
    ext.append(bplan.layouts[members[-1].output.storage()])
    lo = min(l.row_offset for l in ext)
    hi = max(l.row_offset + l.rows for l in ext)
    return OpWindow(members[-1].params["fuse_chain"], "fused",
                    (lo // sub) * sub, _round_up(hi, sub),
                    win_rows=total, resident_rows=total)


def window_schedule(bplan: BlockPlan) -> "WindowSchedule":
    """Derive the live-window schedule from a legalised plan.

    Row-streaming ops (conv / depthwise / pool) get a rolling input window
    plus a one-tile output slot via :func:`rolling_starts`; every other
    kind stages whole operand blocks via :func:`staged_slots` (each block
    is contiguous, so a scattered multi-operand extent — e.g. a
    band-reassembling concat — costs only the sum of its block heights,
    not the span between them). A fused band chain contributes ONE staged
    window (at the first member's position, named after the chain) sized by
    :func:`_fused_window`."""
    sub = bplan.tiling[0]
    windows: List[OpWindow] = []
    chains: Dict[str, List[Op]] = {}
    for op in bplan.order:
        cname = op.params.get("fuse_chain")
        if cname is not None:
            chains.setdefault(cname, []).append(op)
    emitted: set = set()
    for op in bplan.order:
        if op.kind == "reshape":
            continue
        batch = op.output.storage().batch
        cname = op.params.get("fuse_chain")
        if cname is not None:
            if cname not in emitted:
                emitted.add(cname)
                windows.append(_fused_window(bplan, chains[cname], sub))
            continue
        # one window per IMAGE (batch-major, same order the backends lower
        # their per-image specs): the streaming VMEM ceiling is per-image,
        # so it does not scale with the batch
        ins = [t for t in op.inputs if t.storage().kind != "weight"]
        lays = [bplan.layout_of(t) for t in ins]
        out = bplan.layout_of(op.output)
        for b in range(batch):
            offs = [l.image_row_offset(b if l.batch == batch else 0)
                    for l in lays]
            out_off = out.image_row_offset(b)
            lo_e = min(offs + [out_off])
            hi_e = max([o + l.image_rows for o, l in zip(offs, lays)]
                       + [out_off + out.image_rows])
            if op.kind in _ROW_STREAMING_KINDS and len(lays) == 1:
                in_addr = (lays[0].cols_per_row, lays[0].row_span)
                out_addr = (out.cols_per_row, out.row_span)
                starts, win_in = rolling_starts(
                    op, offs[0], out_off,
                    int(op.inputs[0].shape[-3]), int(op.output.shape[-3]),
                    sub, bplan.total_rows, in_addr=in_addr,
                    out_addr=out_addr)
                out_ar = tile_arena_rows(*out_addr, sub)
                lo = (min(min(starts), lo_e) // sub) * sub
                hi = _round_up(max(max(s + win_in for s in starts), hi_e),
                               sub)
                windows.append(OpWindow(op.name, op.kind, lo, hi,
                                        win_rows=win_in + out_ar,
                                        resident_rows=2 * win_in + out_ar,
                                        starts=starts))
            else:
                _, _, total = staged_slots([l.image_rows for l in lays],
                                           out.image_rows, sub)
                windows.append(OpWindow(
                    op.name, op.kind, (lo_e // sub) * sub,
                    _round_up(hi_e, sub), win_rows=total,
                    resident_rows=total))
    return WindowSchedule(tuple(windows), bplan.total_rows,
                          bplan.arena_rowlen, bplan.dtype_bytes)


# ---------------------------------------------------------------------------
# Constraint machinery
# ---------------------------------------------------------------------------


def batched_os_bytes(os_image: int, inp: Tensor, outp: Tensor) -> int:
    """Scale a per-image byte ``O_s`` to the batched tensors' layout.

    Batched execution is batch-major and per-image independent: image ``b``
    of the op reads only image ``b`` of the input and writes only image
    ``b`` of the output, images in ascending order. Writing output image
    ``b`` must leave input image ``b`` intact up to the per-image overlap
    (the ordinary per-image condition, worst at the last image when
    ``|out| > |in|``) and must not touch the still-unread input images
    ``> b``. Solving both for the smallest safe input/output distance gives

        ``D_B = (|out| - O_s_1) + (B - 1) * max(0, |out| - |in|)``

    (per-image byte sizes), i.e. the batched overlap

        ``O_s_B = O_s_1 + (B - 1) * min(|in|, |out|)``.

    Valid for any per-image ``O_s_1 >= 0`` — the batched term only relies
    on image ``b`` of the input being dead once image ``b`` is computed.
    Tensors with mismatched batches (e.g. a broadcast operand shared by
    every image, which must survive until the last image) get no batched
    relaxation."""
    B = outp.batch
    if B == 1:
        return os_image
    if inp.batch != B:
        return 0
    return os_image + (B - 1) * min(inp.image_nbytes, outp.image_nbytes)


def _compute_overlaps(order: List[Op], overlap_fn: Optional[OverlapFn],
                      scopes) -> Dict[Tuple[int, int], int]:
    """O_s for every (op, input) pair where the relaxation is legal: the input
    is an intermediate whose *last* use is this op (paper §II.D). Per-image
    overlaps from ``overlap_fn`` are scaled to the batch via
    :func:`batched_os_bytes`."""
    if overlap_fn is None:
        return {}
    out: Dict[Tuple[int, int], int] = {}
    for oi, op in enumerate(order):
        if not op.outputs:
            continue
        if op.output.storage().kind == "scratch":
            # fused-chain internal write: the tensor has no arena placement
            # (and no scope entry) — there is nothing to relax
            continue
        if op.output.alias_of is not None:
            # §II.C removal: this op writes into an aggregated view — its
            # write offsets shift, so the overlap relaxation is dropped
            # (the conservative O_s=0 route the paper describes)
            continue
        for ii, t in enumerate(op.inputs):
            s = t.storage()
            if s.kind in ("weight", "output", "scratch"):
                continue
            if t.alias_of is not None:
                continue
            if scopes[s][1] != oi:  # value needed later: no overwrite allowed
                continue
            if s is op.output.storage():
                continue
            v = batched_os_bytes(overlap_fn(op, ii), s, op.output.storage())
            if v > 0:
                out[(oi, ii)] = v
        # multiple overlappable inputs of one op would collide with each
        # other inside the overlap region; keep only the largest O_s.
        cand = [(k, v) for k, v in out.items() if k[0] == oi]
        if len(cand) > 1:
            cand.sort(key=lambda kv: -kv[1])
            for k, _ in cand[1:]:
                del out[k]
    return out


def _forbidden_intervals(t: Tensor, placed: Dict[Tensor, int], scopes,
                         order: List[Op],
                         overlaps: Dict[Tuple[int, int], int],
                         sizes: Optional[Mapping[Tensor, int]] = None,
                         ) -> List[Tuple[int, int]]:
    """Intervals of start offsets forbidden for tensor ``t``. Offsets, sizes
    and O_s values share one unit: bytes by default, or whatever unit the
    ``sizes`` map (and the matching ``overlaps`` values) are expressed in —
    the row-blocked legaliser passes row counts through the same machinery."""
    size = (lambda x: x.nbytes) if sizes is None else sizes.__getitem__
    # map (input storage, output storage) -> O_s for quick lookup
    relax: Dict[Tuple[Tensor, Tensor], int] = {}
    for (oi, ii), v in overlaps.items():
        op = order[oi]
        relax[(op.inputs[ii].storage(), op.output.storage())] = v
    sa, ea = scopes[t]
    out: List[Tuple[int, int]] = []
    nt = size(t)
    for b, xb in placed.items():
        sb, eb = scopes[b]
        if ea < sb or eb < sa:
            continue
        nb = size(b)
        if (t, b) in relax:        # t is input overlapping output b's tail
            hi = xb + nb - relax[(t, b)]
        elif (b, t) in relax:      # t is the output; b the (placed) input:
            # constraint: xb >= x_t + n_t - O_s  ->  x_t <= xb - n_t + O_s,
            # i.e. forbidden to START in (xb - n_t + O_s, xb + nb) unless
            # fully above b.  Lower edge of forbidden zone:
            hi = xb + nb           # fully-above bound handled below
            lo = xb - nt + relax[(b, t)]
            if lo < hi:
                out.append((lo + 1, xb + nb))
            continue
        else:
            hi = xb + nb
        lo = xb - nt
        if lo < hi:
            out.append((lo + 1, hi))  # forbidden start offsets [lo+1, hi)
    return out


def _lowest_feasible(t: Tensor, placed, scopes, order, overlaps,
                     sizes: Optional[Mapping[Tensor, int]] = None,
                     align: Optional[int] = None) -> int:
    """Lowest conflict-free start offset for ``t``, rounded up to the
    tensor's ``dtype_bytes`` alignment so executor backends can view the byte
    arena at the planned offset (an f32 tensor packed after an odd-sized int8
    tensor must not land on an unaligned byte). All-f32 graphs are unaffected:
    every boundary there is already a multiple of 4. The row-blocked
    legaliser reuses this with ``sizes`` in rows and ``align`` the sublane
    tile, so offsets land on per-dtype tile boundaries."""
    a = align if align is not None else max(1, t.dtype_bytes)
    iv = sorted(_forbidden_intervals(t, placed, scopes, order, overlaps,
                                     sizes))
    x = 0
    for lo, hi in iv:
        if x < lo:
            break
        x = max(x, hi)
        x = -(-x // a) * a  # next aligned start at or above the interval end
    return x


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------


def plan_naive(graph: Graph, order: Optional[Sequence[Op]] = None) -> Plan:
    """Greedy heap in forward execution order, no overlap."""
    order = list(order or graph.ops)
    scopes = graph.scopes(order)
    placed: Dict[Tensor, int] = {}
    overlaps: Dict[Tuple[int, int], int] = {}
    # allocate exactly when the executor would: model inputs up front, then
    # each op's outputs at the moment the op runs (TFLite heap behaviour)
    alloc_order: List[Tensor] = [t for t in scopes if t.kind == "input"]
    for op in order:
        for t in op.outputs:
            s = t.storage()
            if s in scopes and s not in alloc_order:
                alloc_order.append(s)
    for t in scopes:  # stragglers (defensive)
        if t not in alloc_order:
            alloc_order.append(t)
    for t in alloc_order:
        placed[t] = _lowest_feasible(t, placed, scopes, order, overlaps)
    return Plan(graph, order, placed, overlaps, "naive")


def plan_greedy_size(graph: Graph, order: Optional[Sequence[Op]] = None,
                     overlap_fn: Optional[OverlapFn] = None) -> Plan:
    """TFLite-Micro-style greedy pre-allocator: place buffers largest-first at
    the lowest conflict-free offset, optionally with the DMO overlap
    relaxation. Without overlap this is the strongest non-overlapping
    baseline; with overlap it recovers the paper's diagonal cascades on the
    sequential models (big consumer outputs are placed first, and every input
    then tucks into its consumer's tail)."""
    order = list(order or graph.ops)
    scopes = graph.scopes(order)
    overlaps = _compute_overlaps(order, overlap_fn, scopes)
    placed: Dict[Tensor, int] = {}
    for t in sorted(scopes, key=lambda t: (-t.nbytes, scopes[t][0])):
        placed[t] = _lowest_feasible(t, placed, scopes, order, overlaps)
    name = "greedy_size+dmo" if overlap_fn else "greedy_size"
    return Plan(graph, order, placed, overlaps, name)


def plan_reverse_heap(graph: Graph, order: Optional[Sequence[Op]] = None,
                      overlap_fn: Optional[OverlapFn] = None) -> Plan:
    """The paper's §II.D DMO allocator: heap allocation in *reverse execution
    order* (each op's output, then its inputs), so that every input can be
    placed overlapping the tail of its consumer's already-placed output.
    Produces the diagonal cascade of Fig. 2b."""
    order = list(order or graph.ops)
    scopes = graph.scopes(order)
    overlaps = _compute_overlaps(order, overlap_fn, scopes)
    placed: Dict[Tensor, int] = {}
    for op in reversed(order):
        cands = [t.storage() for t in op.outputs]
        cands += sorted((t.storage() for t in op.intermediate_inputs()),
                        key=lambda s: -s.nbytes)
        for s in cands:
            if s.kind == "weight" or s in placed or s not in scopes:
                continue
            placed[s] = _lowest_feasible(s, placed, scopes, order, overlaps)
    for s in scopes:  # unconsumed stragglers
        if s not in placed:
            placed[s] = _lowest_feasible(s, placed, scopes, order, overlaps)
    name = "dmo_reverse" if overlap_fn else "reverse_heap"
    return Plan(graph, order, placed, overlaps, name)


def plan_modified_heap(graph: Graph, order: Optional[Sequence[Op]] = None,
                       overlap_fn: Optional[OverlapFn] = None,
                       direction: str = "backward") -> Plan:
    """The paper's modified heap (§IV), optionally with DMO overlap."""
    order = list(order or graph.ops)
    scopes = graph.scopes(order)
    overlaps = _compute_overlaps(order, overlap_fn, scopes)
    todo = list(scopes.keys())
    if not todo:
        return Plan(graph, order, {}, overlaps, "modified_heap")
    # seed: output buffer (backward) / input buffer (forward) at offset 0
    key = (lambda t: scopes[t][1]) if direction == "backward" else (
        lambda t: -scopes[t][0])
    seed = max(todo, key=lambda t: (key(t), t.nbytes))
    placed: Dict[Tensor, int] = {seed: 0}
    todo.remove(seed)
    while todo:
        frontier = [
            t for t in todo
            if any(scopes[t][0] <= scopes[p][1] and scopes[p][0] <= scopes[t][1]
                   for p in placed)
        ] or todo
        best, best_x = None, None
        for t in frontier:
            x = _lowest_feasible(t, placed, scopes, order, overlaps)
            if best_x is None or x < best_x or (x == best_x and t.nbytes > best.nbytes):
                best, best_x = t, x
        placed[best] = best_x
        todo.remove(best)
    name = "dmo" if overlap_fn is not None else f"modified_heap_{direction}"
    return Plan(graph, order, placed, overlaps, name)


def _plan_scaled_batch1(graph: Graph, order: Optional[Sequence[Op]],
                        method: str, profile: str) -> Optional[Plan]:
    """Batched candidate: plan the per-image (batch-1) graph, then scale
    every byte offset by the batch B. Always valid: for any overlapping
    (input, output) pair the scaled distance is ``B * (|out|_1 - O_s_1)``
    and the batched requirement is ``B*|out|_1 - O_s_1 - (B-1)*min(|in|_1,
    |out|_1)``, so validity reduces to ``O_s_1 <= min(|in|_1, |out|_1)`` —
    true by construction (an overlap of two buffers cannot exceed either
    size) — while disjoint pairs stay disjoint under uniform scaling.
    Guarantees ``peak(B) <= B * peak(1)``: the batch never costs more than
    B independent copies, whatever the heap heuristics do at batch B."""
    from repro.core.graph import with_batch
    B = getattr(graph, "batch", 1)
    if B <= 1:
        return None
    g1 = with_batch(graph, 1)
    order1 = None
    if order is not None:
        pos = {id(op): i for i, op in enumerate(graph.ops)}
        order1 = [g1.ops[pos[id(op)]] for op in order]
    p1 = plan_dmo(g1, order1, method, profile)
    by_name = {t.name: t for t in graph.tensors}
    offsets = {by_name[t.name]: off * B for t, off in p1.offsets.items()}
    fn = _default_overlap(method, profile)
    ord_b = list(order or graph.ops)
    overlaps = _compute_overlaps(ord_b, fn, graph.scopes(ord_b))
    plan = Plan(graph, ord_b, offsets, overlaps,
                p1.strategy + f"+scaled_b{B}")
    try:
        plan.validate()
    except AssertionError:  # pragma: no cover - defensive; see docstring
        return None
    return plan


def plan_dmo(graph: Graph, order: Optional[Sequence[Op]] = None,
             method: str = "auto", profile: str = "paper") -> Plan:
    """Diagonal memory optimisation: the better of the strict reverse-order
    heap (§II.D) and the modified-heap frontier heuristic (§IV), both with
    the O_s overlap relaxation. Batched graphs add the scaled batch-1
    candidate (:func:`_plan_scaled_batch1`), bounding the batched peak by
    ``B x`` the per-image peak."""
    fn = _default_overlap(method, profile)
    plans = [
        plan_greedy_size(graph, order, fn),
        plan_reverse_heap(graph, order, fn),
        plan_modified_heap(graph, order, fn, direction="backward"),
    ]
    scaled = _plan_scaled_batch1(graph, order, method, profile)
    if scaled is not None:
        plans.append(scaled)
    return min(plans, key=lambda p: p.peak_bytes)


def plan_search(graph: Graph, order: Optional[Sequence[Op]] = None,
                method: str = "auto", budget_s: float = 10.0,
                seed: int = 0, with_overlap: bool = True,
                profile: str = "paper") -> Plan:
    """Beyond-paper: iterated local search over the *insertion order* of the
    lowest-feasible-offset allocator (with DMO overlap constraints).

    The buffer-placement problem is NP-hard (paper §IV); greedy orders get
    trapped when an overlap partner is placed before its constraint becomes
    visible. ILS over insertion orders escapes those traps and recovers the
    paper's optimal diagonal cascades (e.g. MobileNet v1's 33.3 %).
    """
    import random
    import time as _time

    order = list(order or graph.ops)
    scopes = graph.scopes(order)
    overlap_fn = (_default_overlap(method, profile)
                  if with_overlap else None)
    overlaps = _compute_overlaps(order, overlap_fn, scopes)
    tensors = list(scopes)

    def evaluate(insertion: List[Tensor]):
        placed: Dict[Tensor, int] = {}
        for t in insertion:
            placed[t] = _lowest_feasible(t, placed, scopes, order, overlaps)
        peak = max((x + t.nbytes for t, x in placed.items()), default=0)
        return peak, placed

    seeds = [
        sorted(tensors, key=lambda t: (-t.nbytes, scopes[t][0])),
        sorted(tensors, key=lambda t: (-t.nbytes, -scopes[t][1])),
        sorted(tensors, key=lambda t: (-scopes[t][1], -t.nbytes)),
        sorted(tensors, key=lambda t: (scopes[t][0], -t.nbytes)),
    ]
    best_peak, best_placed, best_ins = None, None, None
    for ins in seeds:
        p, placed = evaluate(ins)
        if best_peak is None or p < best_peak:
            best_peak, best_placed, best_ins = p, placed, list(ins)

    rng = random.Random(seed)
    cur = list(best_ins)
    cur_peak = best_peak
    t0 = _time.time()
    n = len(tensors)
    while _time.time() - t0 < budget_s and n > 2:
        nxt = list(cur)
        for _ in range(rng.randint(1, 3)):
            i, j = rng.randrange(n), rng.randrange(n)
            if rng.random() < 0.5:
                nxt[i], nxt[j] = nxt[j], nxt[i]
            else:
                nxt.insert(j, nxt.pop(i))
        p, placed = evaluate(nxt)
        if p <= cur_peak:
            cur, cur_peak = nxt, p
            if p < best_peak:
                best_peak, best_placed, best_ins = p, placed, list(nxt)
        elif rng.random() < 0.02:  # occasional uphill restart from best
            cur, cur_peak = list(best_ins), best_peak
    return Plan(graph, order, best_placed, overlaps,
                "search+dmo" if with_overlap else "search")


# ---------------------------------------------------------------------------
# Joint execution-order x overlap search (beyond-paper)
# ---------------------------------------------------------------------------


def live_bytes_profile(graph: Graph, order: Sequence[Op]) -> List[int]:
    """Naive live-byte total at every execution step of ``order`` — a
    prefix-sum sweep over the liveness scopes, O(ops + tensors). This is the
    *floor* a non-overlapping allocator can reach at each step; the DMO peak
    may sit below it (overlap) or above it (fragmentation)."""
    scopes = graph.scopes(order)
    n = len(order)
    diff = [0] * (n + 1)
    for s, (a, b) in scopes.items():
        diff[a] += s.nbytes
        diff[b + 1] -= s.nbytes
    out: List[int] = []
    acc = 0
    for k in range(n):
        acc += diff[k]
        out.append(acc)
    return out


class LivePeakEstimator:
    """Incremental naive live-byte peak of an execution order.

    The joint search screens thousands of candidate linearisations; a full
    placement evaluation costs O(T^2) per candidate, but an *adjacent
    transposition* only changes which tensors are live at the two swapped
    steps. This estimator maintains the per-step live-byte profile under
    adjacent swaps in O(degree of the two ops) — mirroring
    :meth:`Graph.scopes` semantics exactly, so after any sequence of swaps
    the profile is bit-identical to a fresh :func:`live_bytes_profile` of
    the current order. ``swap(i)`` is its own inverse (undo = re-swap)."""

    def __init__(self, graph: Graph, order: Sequence[Op]):
        self.graph = graph
        # static structure: who reads / writes each arena storage (the same
        # kind filters Graph.scopes applies)
        self._readers: Dict[Tensor, List[Op]] = {}
        self._writers: Dict[Tensor, List[Op]] = {}
        for op in graph.ops:
            for t in op.inputs:
                s = t.storage()
                if s.kind in ("weight", "scratch"):
                    continue
                self._readers.setdefault(s, []).append(op)
            for t in op.outputs:
                s = t.storage()
                if s.kind == "scratch":
                    continue
                self._writers.setdefault(s, []).append(op)
        self.reset(order)

    def reset(self, order: Sequence[Op]) -> None:
        self.order = list(order)
        self.n = len(self.order)
        self._pos = {op: i for i, op in enumerate(self.order)}
        self._bytes_at = live_bytes_profile(self.graph, self.order)
        self._peak = max(self._bytes_at, default=0)
        self._dirty = False

    @property
    def peak(self) -> int:
        if self._dirty:
            self._peak = max(self._bytes_at, default=0)
            self._dirty = False
        return self._peak

    def _scope(self, s: Tensor) -> Tuple[int, int]:
        """[first, last] liveness of storage ``s`` under the current
        positions — the closed form of Graph.scopes' sweep: inputs are live
        from 0, outputs to the end, otherwise first touch to last read (or
        the first write when never read)."""
        reads = [self._pos[op] for op in self._readers.get(s, ())]
        writes = [self._pos[op] for op in self._writers.get(s, ())]
        first = 0 if s.kind == "input" else min(reads + writes)
        if s.kind == "output":
            last = self.n - 1
        else:
            last = max(reads) if reads else min(writes)
        return first, last

    def swap(self, i: int) -> int:
        """Adjacent transposition of ``order[i]`` and ``order[i+1]``;
        returns the (possibly stale-free) new peak."""
        a, b = self.order[i], self.order[i + 1]
        touched: List[Tensor] = []
        seen = set()
        for op in (a, b):
            for t in list(op.inputs) + list(op.outputs):
                s = t.storage()
                if s.kind in ("weight", "scratch") or id(s) in seen:
                    continue
                if s not in self._readers and s not in self._writers:
                    continue
                seen.add(id(s))
                touched.append(s)
        old = {id(s): self._scope(s) for s in touched}
        self._pos[a], self._pos[b] = i + 1, i
        self.order[i], self.order[i + 1] = b, a
        for s in touched:
            f1, l1 = old[id(s)]
            f2, l2 = self._scope(s)
            if (f1, l1) == (f2, l2):
                continue
            for k in (i, i + 1):
                d = s.nbytes * ((f2 <= k <= l2) - (f1 <= k <= l1))
                if d:
                    was = self._bytes_at[k]
                    self._bytes_at[k] = was + d
                    if was + d > self._peak:
                        self._peak = was + d
                    elif was == self._peak and d < 0:
                        self._dirty = True
        return self.peak


def plan_joint(graph: Graph, orders: Optional[Sequence[Sequence[Op]]] = None,
               *, method: str = "auto", profile: str = "paper",
               budget_s: float = 2.0, seed: int = 0,
               allow_order_moves: bool = True, order_move_prob: float = 0.25,
               max_rounds: Optional[int] = None,
               promote: bool = True) -> Tuple[Plan, Dict[str, Any]]:
    """Joint search over (linearisation, placement) — beyond every paper in
    PAPERS.md, which each optimise one axis at a time.

    ILS over the *product* space: order moves (adjacent transpositions kept
    dependency-respecting by :class:`serialise.OrderMoves`) interleave with
    the insertion-order placement moves of :func:`plan_search`. An order
    move is pre-screened by the incremental :class:`LivePeakEstimator`
    (floor-raising moves are usually skipped — but not always, because order
    and diagonal overlap trade off against each other) and by a
    (order-signature -> best peak) memo so repeated neighbourhoods are free;
    survivors get the full O(T^2) placement evaluation, and a winning order
    that differs from every seed is promoted to a full :func:`plan_dmo` in
    case the greedy planner family packs it better than the insertion ILS
    did. On a sequential graph (no legal swap) the loop degenerates to
    exactly the placement-only ILS, preserving ``plan_search``'s wins.

    Returns ``(plan, stats)`` — the best plan found (strategy ``joint+dmo``,
    or ``joint:<strategy>`` when the promotion won) and a telemetry dict.
    """
    import random
    import time as _time

    from repro.core.serialise import OrderMoves
    from repro.core.serialise import candidate_orders as _cand_orders

    t0 = _time.time()
    moves = OrderMoves(graph)
    src = [list(o) for o in (orders if orders is not None
                             else [list(graph.ops)] + _cand_orders(graph))]
    seeds_o: List[List[Op]] = []
    seen_sigs = set()
    for o in src:
        sig = moves.signature(o)
        if sig not in seen_sigs:
            seen_sigs.add(sig)
            seeds_o.append(o)

    overlap_fn = _default_overlap(method, profile)
    fn_cache: Dict[Tuple[int, int], int] = {}

    def ov(op: Op, ii: int) -> int:
        k = (id(op), ii)
        v = fn_cache.get(k)
        if v is None:
            v = fn_cache[k] = overlap_fn(op, ii)
        return v

    # per-order evaluation context: O_s values depend only on the op, but
    # *eligibility* (is this the input's last use?) depends on the order
    ctx: Dict[Tuple[int, ...], Tuple[List[Op], Dict, Dict]] = {}

    def context(order: List[Op], sig: Tuple[int, ...]):
        c = ctx.get(sig)
        if c is None:
            scopes = graph.scopes(order)
            overlaps = _compute_overlaps(order, ov, scopes)
            c = ctx[sig] = (list(order), scopes, overlaps)
        return c

    stats: Dict[str, Any] = {
        "orders_tried": 0, "order_moves": 0, "order_accepts": 0,
        "screened_out": 0, "memo_skips": 0, "placement_moves": 0,
        "evals": 0, "promotions": 0,
    }
    memo: Dict[Tuple[int, ...], int] = {}

    def place(order: List[Op], sig: Tuple[int, ...],
              insertion: List[Tensor]):
        o, scopes, overlaps = context(order, sig)
        placed: Dict[Tensor, int] = {}
        for t in insertion:
            placed[t] = _lowest_feasible(t, placed, scopes, o, overlaps)
        peak = max((x + t.nbytes for t, x in placed.items()), default=0)
        stats["evals"] += 1
        prev = memo.get(sig)
        memo[sig] = peak if prev is None else min(prev, peak)
        return peak, placed

    best = None  # (peak, sig, order, insertion, placed)
    for o in seeds_o:
        sig = moves.signature(o)
        _, scopes, _ = context(o, sig)
        tensors = list(scopes)
        stats["orders_tried"] += 1
        for ins in (
            sorted(tensors, key=lambda t: (-t.nbytes, scopes[t][0])),
            sorted(tensors, key=lambda t: (-t.nbytes, -scopes[t][1])),
            sorted(tensors, key=lambda t: (-scopes[t][1], -t.nbytes)),
            sorted(tensors, key=lambda t: (scopes[t][0], -t.nbytes)),
        ):
            p, placed = place(o, sig, ins)
            if best is None or p < best[0]:
                best = (p, sig, list(o), list(ins), placed)
    seed_peak = best[0]  # best achievable without leaving the seed orders

    cur_peak, cur_sig = best[0], best[1]
    cur_order, cur_ins = list(best[2]), list(best[3])
    est = LivePeakEstimator(graph, cur_order)
    legal = moves.legal_swaps(cur_order) if allow_order_moves else []
    rng = random.Random(seed)
    n_t = len(cur_ins)
    rounds = 0
    while (n_t > 2 and _time.time() - t0 < budget_s
           and (max_rounds is None or rounds < max_rounds)):
        rounds += 1
        if legal and rng.random() < order_move_prob:
            stats["order_moves"] += 1
            i = legal[rng.randrange(len(legal))]
            cand = moves.swap(cur_order, i)
            sig = moves.signature(cand)
            floor_before = est.peak
            floor_after = est.swap(i)
            known = memo.get(sig)
            if known is not None and known > cur_peak:
                est.swap(i)  # undo: this neighbourhood is memoised worse
                stats["memo_skips"] += 1
                continue
            if (known is None and floor_after > floor_before
                    and rng.random() < 0.7):
                # the floor estimator says the move raises naive liveness;
                # usually skip, but sometimes explore anyway — a higher
                # floor can still enable a better diagonal overlap
                est.swap(i)
                stats["screened_out"] += 1
                continue
            p, placed = place(cand, sig, cur_ins)
            if p <= cur_peak:
                cur_order, cur_sig, cur_peak = cand, sig, p
                legal = moves.legal_swaps(cur_order)
                stats["order_accepts"] += 1
                if p < best[0]:
                    best = (p, sig, list(cand), list(cur_ins), placed)
            else:
                est.swap(i)
        else:
            stats["placement_moves"] += 1
            nxt = list(cur_ins)
            for _ in range(rng.randint(1, 3)):
                a, b = rng.randrange(n_t), rng.randrange(n_t)
                if rng.random() < 0.5:
                    nxt[a], nxt[b] = nxt[b], nxt[a]
                else:
                    nxt.insert(b, nxt.pop(a))
            p, placed = place(cur_order, cur_sig, nxt)
            if p <= cur_peak:
                cur_ins, cur_peak = nxt, p
                if p < best[0]:
                    best = (p, cur_sig, list(cur_order), list(nxt), placed)
            elif rng.random() < 0.02:  # occasional uphill restart from best
                cur_peak, cur_sig = best[0], best[1]
                cur_order, cur_ins = list(best[2]), list(best[3])
                est.reset(cur_order)
                legal = moves.legal_swaps(cur_order) if allow_order_moves \
                    else []

    p, sig, o, ins, placed = best
    _, _, overlaps = context(o, sig)
    plan = Plan(graph, list(o), placed, overlaps, "joint+dmo")
    if promote and sig not in seen_sigs and p < seed_peak:
        # the winning order is new AND strictly beat every seed order: the
        # greedy planner family may pack it better still than the insertion
        # ILS did (one bounded promotion — gated on a strict order-axis win
        # so big graphs never pay a full plan_dmo for a sideways drift)
        promoted = plan_dmo(graph, o, method=method, profile=profile)
        stats["promotions"] = 1
        if promoted.peak_bytes < plan.peak_bytes:
            plan = Plan(graph, promoted.order, promoted.offsets,
                        promoted.overlaps, f"joint:{promoted.strategy}")
    stats.update(
        rounds=rounds, peak=plan.peak_bytes, wall_s=_time.time() - t0,
        order_changed=sig != moves.signature(seeds_o[0]),
        legal_swaps=len(moves.legal_swaps(plan.order)),
    )
    return plan, stats


def plan_original(graph: Graph, order: Optional[Sequence[Op]] = None) -> Plan:
    """Best non-overlapping baseline (the paper's "Original" column): min of
    the first-fit heap, greedy-by-size, and both modified-heap directions."""
    plans = [
        plan_naive(graph, order),
        plan_greedy_size(graph, order),
        plan_modified_heap(graph, order, None, "forward"),
        plan_modified_heap(graph, order, None, "backward"),
    ]
    return min(plans, key=lambda p: p.peak_bytes)


def best_plan(graph: Graph, orders: Optional[Sequence[Sequence[Op]]] = None,
              strategy: str = "dmo", method: str = "auto") -> Plan:
    """Best (lowest-peak) plan over candidate serialisation orders, as the
    paper does with eager & lazy orders."""
    from repro.core.serialise import candidate_orders

    orders = orders or candidate_orders(graph)
    plans = []
    for o in orders:
        if strategy == "dmo":
            plans.append(plan_dmo(graph, o, method))
        elif strategy == "naive":
            plans.append(plan_naive(graph, o))
        elif strategy == "modified_heap":
            plans.append(plan_modified_heap(graph, o))
        else:
            raise ValueError(strategy)
    return min(plans, key=lambda p: p.peak_bytes)
