"""Tensor-arena planners (paper §II.D + §IV).

Strategies:

- ``naive``          — classic greedy heap in execution order (allocate at
                       first use, free at last use, lowest-address-first).
                       This is the "Original" column of Table III.
- ``modified_heap``  — the paper's heuristic ordering: repeatedly allocate,
                       out of the frontier of unallocated tensors whose scope
                       overlaps an allocated one, the tensor that heap-packs
                       lowest. Forwards or backwards.
- ``dmo``            — modified heap, *backwards* (reverse execution order),
                       with the diagonal overlap relaxation: an op's input may
                       overlap the tail of the op's output by ``O_s`` bytes.

All planners return a :class:`Plan` mapping storage tensors to byte offsets,
with the peak arena size and a safety validator.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.graph import Graph, Op, Tensor
from repro.core import overlap as overlap_mod

OverlapFn = Callable[[Op, int], int]


def _default_overlap(method: str = "auto", profile: str = "paper") -> OverlapFn:
    return lambda op, idx: overlap_mod.safe_overlap(op, idx, method=method,
                                                    profile=profile)


@dataclasses.dataclass(frozen=True)
class TensorLayout:
    """Byte-granular placement of one arena tensor view: the dtype width, the
    byte offset the planner chose for its storage, and the (derived) element
    offset. This is the layout contract between the planner and the executor
    backends — kernels index the flat *byte* arena with it, so mixed-dtype
    plans (int8 next to f32) need no implicit element size."""

    name: str
    shape: Tuple[int, ...]
    dtype_bytes: int
    byte_offset: int

    @property
    def elem_offset(self) -> int:
        return self.byte_offset // self.dtype_bytes

    @property
    def elems(self) -> int:
        n = 1
        for s in self.shape:
            n *= int(s)
        return n

    @property
    def nbytes(self) -> int:
        return self.elems * self.dtype_bytes


@dataclasses.dataclass(frozen=True)
class OpLayout:
    """Lowering record for one executed op: the op plus the layout of every
    data input (``None`` for non-arena weight inputs) and of the output."""

    op: Op
    inputs: Tuple[Optional[TensorLayout], ...]
    output: TensorLayout


@dataclasses.dataclass
class Plan:
    graph: Graph
    order: List[Op]
    offsets: Dict[Tensor, int]
    overlaps: Dict[Tuple[int, int], int]  # (op index, input index) -> O_s bytes
    strategy: str = ""

    @property
    def peak_bytes(self) -> int:
        return max((off + t.nbytes for t, off in self.offsets.items()), default=0)

    def peak_bytes_by_dtype(self) -> Dict[int, int]:
        """Arena peak extent per dtype width (bytes): for each dtype, the
        highest end offset of any tensor of that width. Sums need not equal
        ``peak_bytes`` — dtypes share the one arena and may interleave."""
        out: Dict[int, int] = {}
        for t, off in self.offsets.items():
            out[t.dtype_bytes] = max(out.get(t.dtype_bytes, 0), off + t.nbytes)
        return out

    _DTYPE_NAMES = {1: "i8", 2: "f16", 4: "f32"}

    def dtype_peaks_report(self) -> str:
        """Human-readable per-dtype peaks, e.g. ``"i8:64KB"`` or
        ``"i8:1KB+f32:12KB"`` (the single formatter the benchmarks share)."""
        return "+".join(
            f"{self._DTYPE_NAMES.get(db, f'{db}B')}:{peak / 1024:.0f}KB"
            for db, peak in sorted(self.peak_bytes_by_dtype().items()))

    def offset_of(self, t: Tensor) -> int:
        return self.offsets[t.storage()]

    def _layout(self, t: Tensor) -> TensorLayout:
        s = t.storage()
        off = self.offsets[s]
        assert off % s.dtype_bytes == 0, \
            f"{s.name}: byte offset {off} not {s.dtype_bytes}-byte aligned"
        return TensorLayout(s.name, tuple(t.shape), s.dtype_bytes, off)

    def op_layouts(self) -> List[OpLayout]:
        """Flat-arena lowering metadata, one :class:`OpLayout` per executed op
        in order. Layouts carry per-tensor ``dtype_bytes`` alongside byte and
        element offsets, so backends execute mixed-dtype plans over a single
        flat byte arena. Aliases resolve to their storage owner, weight inputs
        (which live outside the arena) yield ``None``, and aliasing no-ops
        (``reshape``) are omitted — they move no bytes. Every offset is
        asserted ``dtype_bytes``-aligned (the placement invariant
        :func:`_lowest_feasible` maintains)."""
        out: List[OpLayout] = []
        for op in self.order:
            if op.kind == "reshape":
                continue
            ins: List[Optional[TensorLayout]] = []
            for t in op.inputs:
                if t.storage().kind == "weight":
                    ins.append(None)
                    continue
                ins.append(self._layout(t))
            out.append(OpLayout(op, tuple(ins), self._layout(op.output)))
        return out

    def validate(self) -> None:
        """Assert no live value can be clobbered under the overlap rules."""
        scopes = self.graph.scopes(self.order)
        tensors = list(self.offsets)
        for i, a in enumerate(tensors):
            sa, ea = scopes[a]
            xa, na = self.offsets[a], a.nbytes
            for b in tensors[i + 1:]:
                sb, eb = scopes[b]
                if ea < sb or eb < sa:
                    continue  # time-disjoint
                xb, nb = self.offsets[b], b.nbytes
                if xa + na <= xb or xb + nb <= xa:
                    continue  # space-disjoint
                os_ = self._allowed_overlap(a, b, scopes)
                if os_ is None:
                    raise AssertionError(
                        f"plan clobbers: {a.name}@{xa} vs {b.name}@{xb}")
                inp, outp = os_
                xi, xo = self.offsets[inp], self.offsets[outp]
                if xi < xo + outp.nbytes - os_bytes(self, inp, outp):
                    raise AssertionError(
                        f"overlap beyond O_s: {inp.name}@{xi} vs {outp.name}@{xo}")

    def _allowed_overlap(self, a: Tensor, b: Tensor, scopes):
        """If (a, b) are an (input, output) pair of some op with a recorded
        O_s, return them ordered (input, output); else None."""
        for (oi, ii), _ in self.overlaps.items():
            op = self.order[oi]
            inp = op.inputs[ii].storage()
            outp = op.output.storage()
            if {inp, outp} == {a, b}:
                return inp, outp
        return None

    def report(self) -> str:
        lines = [f"# plan {self.strategy}: peak {self.peak_bytes} bytes"]
        scopes = self.graph.scopes(self.order)
        for t in sorted(self.offsets, key=lambda t: self.offsets[t]):
            s, e = scopes[t]
            lines.append(
                f"  {t.name:32s} off={self.offsets[t]:>10d} size={t.nbytes:>10d}"
                f" scope=[{s},{e}]")
        return "\n".join(lines)


def os_bytes(plan: Plan, inp: Tensor, outp: Tensor) -> int:
    for (oi, ii), v in plan.overlaps.items():
        op = plan.order[oi]
        if op.inputs[ii].storage() is inp and op.output.storage() is outp:
            return v
    return 0


# ---------------------------------------------------------------------------
# Constraint machinery
# ---------------------------------------------------------------------------


def _compute_overlaps(order: List[Op], overlap_fn: Optional[OverlapFn],
                      scopes) -> Dict[Tuple[int, int], int]:
    """O_s for every (op, input) pair where the relaxation is legal: the input
    is an intermediate whose *last* use is this op (paper §II.D)."""
    if overlap_fn is None:
        return {}
    out: Dict[Tuple[int, int], int] = {}
    for oi, op in enumerate(order):
        if not op.outputs:
            continue
        if op.output.alias_of is not None:
            # §II.C removal: this op writes into an aggregated view — its
            # write offsets shift, so the overlap relaxation is dropped
            # (the conservative O_s=0 route the paper describes)
            continue
        for ii, t in enumerate(op.inputs):
            s = t.storage()
            if s.kind == "weight" or s.kind == "output":
                continue
            if t.alias_of is not None:
                continue
            if scopes[s][1] != oi:  # value needed later: no overwrite allowed
                continue
            if s is op.output.storage():
                continue
            v = overlap_fn(op, ii)
            if v > 0:
                out[(oi, ii)] = v
        # multiple overlappable inputs of one op would collide with each
        # other inside the overlap region; keep only the largest O_s.
        cand = [(k, v) for k, v in out.items() if k[0] == oi]
        if len(cand) > 1:
            cand.sort(key=lambda kv: -kv[1])
            for k, _ in cand[1:]:
                del out[k]
    return out


def _forbidden_intervals(t: Tensor, placed: Dict[Tensor, int], scopes,
                         order: List[Op],
                         overlaps: Dict[Tuple[int, int], int]) -> List[Tuple[int, int]]:
    """Intervals of start offsets forbidden for tensor ``t``."""
    # map (input storage, output storage) -> O_s for quick lookup
    relax: Dict[Tuple[Tensor, Tensor], int] = {}
    for (oi, ii), v in overlaps.items():
        op = order[oi]
        relax[(op.inputs[ii].storage(), op.output.storage())] = v
    sa, ea = scopes[t]
    out: List[Tuple[int, int]] = []
    for b, xb in placed.items():
        sb, eb = scopes[b]
        if ea < sb or eb < sa:
            continue
        nb = b.nbytes
        if (t, b) in relax:        # t is input overlapping output b's tail
            hi = xb + nb - relax[(t, b)]
        elif (b, t) in relax:      # t is the output; b the (placed) input:
            # constraint: xb >= x_t + n_t - O_s  ->  x_t <= xb - n_t + O_s,
            # i.e. forbidden to START in (xb - n_t + O_s, xb + nb) unless
            # fully above b.  Lower edge of forbidden zone:
            hi = xb + b.nbytes     # fully-above bound handled below
            lo = xb - t.nbytes + relax[(b, t)]
            if lo < hi:
                out.append((lo + 1, xb + nb))
            continue
        else:
            hi = xb + nb
        lo = xb - t.nbytes
        if lo < hi:
            out.append((lo + 1, hi))  # forbidden start offsets [lo+1, hi)
    return out


def _lowest_feasible(t: Tensor, placed, scopes, order, overlaps) -> int:
    """Lowest conflict-free start offset for ``t``, rounded up to the
    tensor's ``dtype_bytes`` alignment so executor backends can view the byte
    arena at the planned offset (an f32 tensor packed after an odd-sized int8
    tensor must not land on an unaligned byte). All-f32 graphs are unaffected:
    every boundary there is already a multiple of 4."""
    a = max(1, t.dtype_bytes)
    iv = sorted(_forbidden_intervals(t, placed, scopes, order, overlaps))
    x = 0
    for lo, hi in iv:
        if x < lo:
            break
        x = max(x, hi)
        x = -(-x // a) * a  # next aligned start at or above the interval end
    return x


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------


def plan_naive(graph: Graph, order: Optional[Sequence[Op]] = None) -> Plan:
    """Greedy heap in forward execution order, no overlap."""
    order = list(order or graph.ops)
    scopes = graph.scopes(order)
    placed: Dict[Tensor, int] = {}
    overlaps: Dict[Tuple[int, int], int] = {}
    # allocate exactly when the executor would: model inputs up front, then
    # each op's outputs at the moment the op runs (TFLite heap behaviour)
    alloc_order: List[Tensor] = [t for t in scopes if t.kind == "input"]
    for op in order:
        for t in op.outputs:
            s = t.storage()
            if s in scopes and s not in alloc_order:
                alloc_order.append(s)
    for t in scopes:  # stragglers (defensive)
        if t not in alloc_order:
            alloc_order.append(t)
    for t in alloc_order:
        placed[t] = _lowest_feasible(t, placed, scopes, order, overlaps)
    return Plan(graph, order, placed, overlaps, "naive")


def plan_greedy_size(graph: Graph, order: Optional[Sequence[Op]] = None,
                     overlap_fn: Optional[OverlapFn] = None) -> Plan:
    """TFLite-Micro-style greedy pre-allocator: place buffers largest-first at
    the lowest conflict-free offset, optionally with the DMO overlap
    relaxation. Without overlap this is the strongest non-overlapping
    baseline; with overlap it recovers the paper's diagonal cascades on the
    sequential models (big consumer outputs are placed first, and every input
    then tucks into its consumer's tail)."""
    order = list(order or graph.ops)
    scopes = graph.scopes(order)
    overlaps = _compute_overlaps(order, overlap_fn, scopes)
    placed: Dict[Tensor, int] = {}
    for t in sorted(scopes, key=lambda t: (-t.nbytes, scopes[t][0])):
        placed[t] = _lowest_feasible(t, placed, scopes, order, overlaps)
    name = "greedy_size+dmo" if overlap_fn else "greedy_size"
    return Plan(graph, order, placed, overlaps, name)


def plan_reverse_heap(graph: Graph, order: Optional[Sequence[Op]] = None,
                      overlap_fn: Optional[OverlapFn] = None) -> Plan:
    """The paper's §II.D DMO allocator: heap allocation in *reverse execution
    order* (each op's output, then its inputs), so that every input can be
    placed overlapping the tail of its consumer's already-placed output.
    Produces the diagonal cascade of Fig. 2b."""
    order = list(order or graph.ops)
    scopes = graph.scopes(order)
    overlaps = _compute_overlaps(order, overlap_fn, scopes)
    placed: Dict[Tensor, int] = {}
    for op in reversed(order):
        cands = [t.storage() for t in op.outputs]
        cands += sorted((t.storage() for t in op.intermediate_inputs()),
                        key=lambda s: -s.nbytes)
        for s in cands:
            if s.kind == "weight" or s in placed or s not in scopes:
                continue
            placed[s] = _lowest_feasible(s, placed, scopes, order, overlaps)
    for s in scopes:  # unconsumed stragglers
        if s not in placed:
            placed[s] = _lowest_feasible(s, placed, scopes, order, overlaps)
    name = "dmo_reverse" if overlap_fn else "reverse_heap"
    return Plan(graph, order, placed, overlaps, name)


def plan_modified_heap(graph: Graph, order: Optional[Sequence[Op]] = None,
                       overlap_fn: Optional[OverlapFn] = None,
                       direction: str = "backward") -> Plan:
    """The paper's modified heap (§IV), optionally with DMO overlap."""
    order = list(order or graph.ops)
    scopes = graph.scopes(order)
    overlaps = _compute_overlaps(order, overlap_fn, scopes)
    todo = list(scopes.keys())
    if not todo:
        return Plan(graph, order, {}, overlaps, "modified_heap")
    # seed: output buffer (backward) / input buffer (forward) at offset 0
    key = (lambda t: scopes[t][1]) if direction == "backward" else (
        lambda t: -scopes[t][0])
    seed = max(todo, key=lambda t: (key(t), t.nbytes))
    placed: Dict[Tensor, int] = {seed: 0}
    todo.remove(seed)
    while todo:
        frontier = [
            t for t in todo
            if any(scopes[t][0] <= scopes[p][1] and scopes[p][0] <= scopes[t][1]
                   for p in placed)
        ] or todo
        best, best_x = None, None
        for t in frontier:
            x = _lowest_feasible(t, placed, scopes, order, overlaps)
            if best_x is None or x < best_x or (x == best_x and t.nbytes > best.nbytes):
                best, best_x = t, x
        placed[best] = best_x
        todo.remove(best)
    name = "dmo" if overlap_fn is not None else f"modified_heap_{direction}"
    return Plan(graph, order, placed, overlaps, name)


def plan_dmo(graph: Graph, order: Optional[Sequence[Op]] = None,
             method: str = "auto", profile: str = "paper") -> Plan:
    """Diagonal memory optimisation: the better of the strict reverse-order
    heap (§II.D) and the modified-heap frontier heuristic (§IV), both with
    the O_s overlap relaxation."""
    fn = _default_overlap(method, profile)
    plans = [
        plan_greedy_size(graph, order, fn),
        plan_reverse_heap(graph, order, fn),
        plan_modified_heap(graph, order, fn, direction="backward"),
    ]
    return min(plans, key=lambda p: p.peak_bytes)


def plan_search(graph: Graph, order: Optional[Sequence[Op]] = None,
                method: str = "auto", budget_s: float = 10.0,
                seed: int = 0, with_overlap: bool = True,
                profile: str = "paper") -> Plan:
    """Beyond-paper: iterated local search over the *insertion order* of the
    lowest-feasible-offset allocator (with DMO overlap constraints).

    The buffer-placement problem is NP-hard (paper §IV); greedy orders get
    trapped when an overlap partner is placed before its constraint becomes
    visible. ILS over insertion orders escapes those traps and recovers the
    paper's optimal diagonal cascades (e.g. MobileNet v1's 33.3 %).
    """
    import random
    import time as _time

    order = list(order or graph.ops)
    scopes = graph.scopes(order)
    overlap_fn = (_default_overlap(method, profile)
                  if with_overlap else None)
    overlaps = _compute_overlaps(order, overlap_fn, scopes)
    tensors = list(scopes)

    def evaluate(insertion: List[Tensor]):
        placed: Dict[Tensor, int] = {}
        for t in insertion:
            placed[t] = _lowest_feasible(t, placed, scopes, order, overlaps)
        peak = max((x + t.nbytes for t, x in placed.items()), default=0)
        return peak, placed

    seeds = [
        sorted(tensors, key=lambda t: (-t.nbytes, scopes[t][0])),
        sorted(tensors, key=lambda t: (-t.nbytes, -scopes[t][1])),
        sorted(tensors, key=lambda t: (-scopes[t][1], -t.nbytes)),
        sorted(tensors, key=lambda t: (scopes[t][0], -t.nbytes)),
    ]
    best_peak, best_placed, best_ins = None, None, None
    for ins in seeds:
        p, placed = evaluate(ins)
        if best_peak is None or p < best_peak:
            best_peak, best_placed, best_ins = p, placed, list(ins)

    rng = random.Random(seed)
    cur = list(best_ins)
    cur_peak = best_peak
    t0 = _time.time()
    n = len(tensors)
    while _time.time() - t0 < budget_s and n > 2:
        nxt = list(cur)
        for _ in range(rng.randint(1, 3)):
            i, j = rng.randrange(n), rng.randrange(n)
            if rng.random() < 0.5:
                nxt[i], nxt[j] = nxt[j], nxt[i]
            else:
                nxt.insert(j, nxt.pop(i))
        p, placed = evaluate(nxt)
        if p <= cur_peak:
            cur, cur_peak = nxt, p
            if p < best_peak:
                best_peak, best_placed, best_ins = p, placed, list(nxt)
        elif rng.random() < 0.02:  # occasional uphill restart from best
            cur, cur_peak = list(best_ins), best_peak
    return Plan(graph, order, best_placed, overlaps,
                "search+dmo" if with_overlap else "search")


def plan_original(graph: Graph, order: Optional[Sequence[Op]] = None) -> Plan:
    """Best non-overlapping baseline (the paper's "Original" column): min of
    the first-fit heap, greedy-by-size, and both modified-heap directions."""
    plans = [
        plan_naive(graph, order),
        plan_greedy_size(graph, order),
        plan_modified_heap(graph, order, None, "forward"),
        plan_modified_heap(graph, order, None, "backward"),
    ]
    return min(plans, key=lambda p: p.peak_bytes)


def best_plan(graph: Graph, orders: Optional[Sequence[Sequence[Op]]] = None,
              strategy: str = "dmo", method: str = "auto") -> Plan:
    """Best (lowest-peak) plan over candidate serialisation orders, as the
    paper does with eager & lazy orders."""
    from repro.core.serialise import candidate_orders

    orders = orders or candidate_orders(graph)
    plans = []
    for o in orders:
        if strategy == "dmo":
            plans.append(plan_dmo(graph, o, method))
        elif strategy == "naive":
            plans.append(plan_naive(graph, o))
        elif strategy == "modified_heap":
            plans.append(plan_modified_heap(graph, o))
        else:
            raise ValueError(strategy)
    return min(plans, key=lambda p: p.peak_bytes)
