"""DMO applied to the assigned architectures' layer graphs.

For each arch we build the tensor-op graph of ONE decoder block at a given
(batch, seq) — the repeating memory unit of a microcontroller-style
sequential execution — and plan its activation arena with and without
diagonal overlap. This is the paper's technique carried to the transformer
substrate: elementwise chains (norm scales, activations, residual adds) are
the ``O_s = |out|`` diagonal case, matmuls are ``O_s = 0`` barriers, and the
planner packs around them.

(The 6ND matmuls dominate transformer FLOPs, but the *activation arena* is
what bounds deployability on small devices — same argument as the paper.)
"""
from __future__ import annotations

from typing import Tuple

from repro.core import pipeline
from repro.core.graph import Graph, Tensor
from repro.core.planner import Plan
from repro.models.config import ArchConfig


def block_graph(cfg: ArchConfig, batch: int = 1, seq: int = 128,
                dtype_bytes: int = 2) -> Graph:
    """One decoder block as a tensor-op graph (activations only)."""
    g = Graph(f"{cfg.name}_block")
    t = batch * seq
    d = cfg.d_model
    x = g.tensor("x", (t, d), dtype_bytes, "input")

    def fc(inp: Tensor, width: int, name: str) -> Tensor:
        return g.op("fully_connected", [inp], (t, width), name=name)

    def ew(inp, name, fn="relu", other=None):
        ins = [inp] if other is None else [inp, other]
        return g.op("elementwise", ins, inp.shape, dict(fn=fn), name=name)

    n1 = ew(x, "norm1", "identity")
    if cfg.attention in ("gqa", "hybrid"):
        q = fc(n1, cfg.q_dim, "wq")
        k = fc(n1, cfg.kv_dim, "wk")
        v = fc(n1, cfg.kv_dim, "wv")
        att = g.op("custom", [q, k, v], (t, cfg.q_dim), name="attention")
        y = fc(att, d, "wo")
    elif cfg.attention == "mla":
        ql = fc(n1, cfg.q_lora_rank, "wq_a")
        q = fc(ew(ql, "q_norm", "identity"),
               cfg.num_heads * (cfg.head_dim + cfg.rope_head_dim), "wq_b")
        kv = fc(n1, cfg.kv_lora_rank + cfg.rope_head_dim, "wkv_a")
        kup = fc(kv, cfg.num_heads * cfg.head_dim, "wk_b")
        vup = fc(kv, cfg.num_heads * (cfg.v_head_dim or cfg.head_dim), "wv_b")
        att = g.op("custom", [q, kup, vup],
                   (t, cfg.num_heads * (cfg.v_head_dim or cfg.head_dim)),
                   name="attention")
        y = fc(att, d, "wo")
    else:  # rwkv time mix
        r = fc(n1, d, "wr")
        k = fc(n1, d, "wk")
        v = fc(n1, d, "wv")
        wkv = g.op("custom", [r, k, v], (t, d), name="wkv_scan")
        y = fc(ew(wkv, "gate", "sigmoid"), d, "wo")
    if cfg.attention == "hybrid":
        xz = fc(n1, 2 * d * cfg.ssm_expand, "mamba_in")
        ssm = g.op("custom", [xz], (t, d * cfg.ssm_expand), name="ssm_scan")
        ym = fc(ssm, d, "mamba_out")
        y = ew(y, "merge", "add", ym)
    x2 = ew(x, "res1", "add", y)

    n2 = ew(x2, "norm2", "identity")
    if cfg.is_moe:
        router = fc(n2, cfg.num_experts, "router")
        # per-token expert compute at top-k width (capacity view)
        up = fc(n2, cfg.experts_per_token * cfg.moe_d_ff, "experts_up")
        gate = fc(n2, cfg.experts_per_token * cfg.moe_d_ff, "experts_gate")
        h = ew(up, "silu_mul", "mul", gate)
        down = fc(h, d, "experts_down")
        y2 = g.op("custom", [down, router], (t, d), name="combine")
    else:
        up = fc(n2, cfg.d_ff, "w_up")
        if cfg.activation == "silu":
            gate = fc(n2, cfg.d_ff, "w_gate")
            h = ew(up, "act", "mul", gate)
        else:
            h = ew(up, "act", "relu")
        y2 = fc(h, d, "w_down")
    g.op("elementwise", [x2, y2], (t, d), dict(fn="add"), name="res2",
         out_kind="output")
    g.validate()
    return g


def plan_block(cfg: ArchConfig, batch: int = 1, seq: int = 128,
               dtype_bytes: int = 2) -> Tuple[Plan, Plan]:
    """(original, dmo) plans of one block's activation arena, via the
    unified compile pipeline (cached per graph signature)."""
    g = block_graph(cfg, batch, seq, dtype_bytes)
    compiled = pipeline.compile(g, profile="paper", method="algorithmic")
    return compiled.baseline, compiled.plan


def compile_block(cfg: ArchConfig, batch: int = 1, seq: int = 128,
                  dtype_bytes: int = 2, profile: str = "paper",
                  method: str = "algorithmic",
                  **kwargs) -> "pipeline.CompiledPlan":
    """Full pipeline result (pass log, provenance, report) for one block."""
    g = block_graph(cfg, batch, seq, dtype_bytes)
    return pipeline.compile(g, profile=profile, method=method, **kwargs)
