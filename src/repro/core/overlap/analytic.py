"""Analytic method for the safe buffer overlap (paper §III-D, Eqs. 1-15).

``minR(i)`` is lower-bounded by the truncated linear function
``max(0, a*i + b)`` and ``maxW(i) = i``; the minimum of their difference over
``[0, i_c]`` gives ``minD`` and (Eq. 11):

    O_s = OB_s + min{ b/a, a*i_c + b - i_c } * T_s

The per-kind constants:

  depthwise conv (Eqs. 7, 8):  a = Sh*Iw / (Ow*Kc)
                               b = (Ow*Sw - Ph*Iw - Sh*Iw - Sw - Pw + 1) * Id
  2D conv (Eqs. 12, 13):       a = Sh*Iw*Id / (Ow*Od)
                               b = (Ow*Sw - Ph*Iw - Sh*Iw - Sw - Pw) * Id + 1
  pooling (Eqs. 14, 15):       a = Sh*Iw / Ow
                               b = (Ow*Sw - Ph*Iw - Sh*Iw - Sw - Pw) * Id + 1

Elementwise/softmax/mean are the ideal diagonal (``O_s = |out|``);
matmul/fully-connected is the degenerate case (``O_s = 0``).

Both the paper's closed form and a robust piecewise evaluation (min over the
breakpoints of the piecewise-linear difference) are provided; they agree on
every op in the model zoo (tested), the robust form is used by default.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

from repro.core.graph import Op, op_pads
from repro.core.overlap.algorithmic import _hwc


def _conv_family_constants(op: Op) -> Tuple[float, float, int]:
    """Return (a, b, i_c) in input-buffer elements / steps."""
    ih, iw, idep = _hwc(op.inputs[0].shape)
    oh, ow, od = _hwc(op.output.shape)
    sh, sw = op.params.get("stride", (1, 1))
    dh, dw = op.params.get("dilation", (1, 1))
    kh, kw = op.params["kernel"]
    # band-aware: the constants take the band's effective padding (op_pads
    # substitutes the explicit per-band pads for row_range-carrying ops;
    # a producer band's negative ph only raises minR, so the truncated
    # linear bound stays a lower bound)
    ph, pw = op_pads(op)
    if op.kind == "depthwise_conv2d":
        kc = op.params.get("multiplier", 1)
        a = (sh * iw) / (ow * kc)
        b = (ow * sw - ph * iw - sh * iw - sw - pw + 1) * idep
        i_c = oh * ow * idep * kc
    elif op.kind == "conv2d":
        a = (sh * iw * idep) / (ow * od)
        b = (ow * sw - ph * iw - sh * iw - sw - pw) * idep + 1
        i_c = oh * ow * od
    elif op.kind == "pool":
        a = (sh * iw) / ow
        b = (ow * sw - ph * iw - sh * iw - sw - pw) * idep + 1
        i_c = oh * ow * idep
    else:  # pragma: no cover
        raise ValueError(op.kind)
    return a, b, i_c


def _min_diff_piecewise(a: float, b: float, i_c: int) -> float:
    """Robust min over i in [0, i_c] of max(0, a*i + b) - i.

    The difference is piecewise linear with at most one breakpoint (the
    truncation point i* = -b/a); the minimum is attained at i=0, i=i_c or i*.
    """
    cands = [0.0, float(i_c)]
    if a > 0 and b < 0:
        cands.append(min(float(i_c), -b / a))
    return min(max(0.0, a * i + b) - i for i in cands)


def paper_closed_form(a: float, b: float, i_c: int) -> float:
    """Eq. (11)'s min term: min{ b/a, a*i_c + b - i_c }."""
    return min(b / a, a * i_c + b - i_c)


def safe_overlap_analytic(op: Op, input_index: int = 0,
                          use_paper_form: bool = False) -> Optional[int]:
    """Closed-form lower bound of ``O_s`` in bytes, or None if this op kind
    has no derived analytic solution (caller falls back to algorithmic)."""
    out = op.output
    ts = out.dtype_bytes
    if op.kind in ("elementwise", "softmax", "mean"):
        x = op.inputs[input_index]
        if op.kind == "elementwise" and x.elems != out.elems:
            return None  # broadcast operand: no derived form, fall back
        return out.nbytes
    if op.kind in ("fully_connected", "matmul"):
        return 0  # paper §III-A: "can not be overlapped at all"
    if op.kind in ("conv2d", "depthwise_conv2d", "pool"):
        if input_index != 0:
            return None
        a, b, i_c = _conv_family_constants(op)
        mind = (paper_closed_form(a, b, i_c) if use_paper_form
                else _min_diff_piecewise(a, b, i_c))
        mind = min(0.0, mind)
        os_bytes = out.nbytes + int(math.floor(mind)) * ts
        return int(max(0, min(out.nbytes, os_bytes)))
    if op.kind == "reshape":
        return 0
    return None
