"""Algorithmic method for the safe buffer overlap (paper §III-C, Alg. 2).

For each op kind we strip the arithmetic out of the TFLite reference loop
nest and keep only the offset computation, producing two arrays:

- ``minR[i]`` — minimum *input-buffer* offset read at step ``i`` or any
  future step (built with a reverse cumulative min);
- ``maxW[i]`` — maximum *output-buffer* offset written at step ``i`` or any
  previous step (``arange`` for the write-one-element-per-step kinds).

Then (Eq. 1):  ``O_s = |out| + min_i(minR[i] - maxW[i])`` — all in bytes here.

The loop nests are vectorised with NumPy so that million-step ops (full
MobileNet/Inception layers) are analysed in milliseconds.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.graph import Op, Tensor, op_pads

_INF = np.iinfo(np.int64).max // 4


def _rev_cummin(a: np.ndarray) -> np.ndarray:
    return np.minimum.accumulate(a[::-1])[::-1]


def _min_valid_coord(out_coords: np.ndarray, stride: int, pad: int, k: int,
                     dilation: int, in_dim: int) -> np.ndarray:
    """Per output coordinate: the smallest valid input coordinate touched by
    the kernel window, or _INF if the window is entirely padding."""
    start = out_coords * stride - pad                      # fy = 0 position
    # first kernel tap with coordinate >= 0
    f0 = np.maximum(0, -(-(-start) // dilation))           # ceil(-start/dil)
    f0 = np.where(start >= 0, 0, f0)
    coord = start + f0 * dilation
    valid = (f0 < k) & (coord < in_dim)
    return np.where(valid, coord, _INF)


def _spatial_min_read(op: Op) -> Tuple[np.ndarray, Tuple[int, ...]]:
    """Min input read offset (in elements) per (oy, ox) for conv-family ops.

    Returns the (Oh, Ow) int64 array of min read offsets where the minimum is
    taken over the kernel window (smallest valid iy, then smallest valid ix,
    channel 0), plus the (Ih, Iw, Id) input shape.
    """
    x = op.inputs[0]
    ih, iw, idep = _hwc(x.shape)
    oh, ow, od = _hwc(op.output.shape)
    sh, sw = op.params.get("stride", (1, 1))
    dh, dw = op.params.get("dilation", (1, 1))
    kh, kw = op.params["kernel"]
    # band-aware: row-banded ops substitute their explicit per-band pads
    # (possibly negative ph) and the whole band-local loop nest — reads
    # confined to the halo rows, writes to the band's output rows — falls
    # out of the same offset arithmetic
    ph, pw = op_pads(op)
    iy = _min_valid_coord(np.arange(oh), sh, ph, kh, dh, ih)   # (Oh,)
    ix = _min_valid_coord(np.arange(ow), sw, pw, kw, dw, iw)   # (Ow,)
    grid = iy[:, None] * (iw * idep) + ix[None, :] * idep       # (Oh, Ow)
    grid = np.where((iy[:, None] >= _INF) | (ix[None, :] >= _INF), _INF, grid)
    return grid.astype(np.int64), (ih, iw, idep)


def _hwc(shape: Tuple[int, ...]) -> Tuple[int, int, int]:
    """Interpret a shape as (H, W, C), folding any leading batch of 1."""
    s = tuple(shape)
    while len(s) > 3 and s[0] == 1:
        s = s[1:]
    if len(s) == 3:
        return s
    if len(s) == 2:
        return (1, s[0], s[1])
    if len(s) == 1:
        return (1, 1, s[0])
    raise ValueError(f"cannot interpret shape {shape} as HWC (batch must be 1)")


# ---------------------------------------------------------------------------
# Per-kind (minR, steps) profiles, offsets in *elements*
# ---------------------------------------------------------------------------


def _profile_conv2d(op: Op, input_index: int) -> np.ndarray:
    # steps: (oy, ox, oc); reads of input 0 at min (iy, ix, 0)
    grid, _ = _spatial_min_read(op)
    _, _, od = _hwc(op.output.shape)
    return np.repeat(grid.reshape(-1), od)


def _profile_depthwise(op: Op, input_index: int) -> np.ndarray:
    # steps: (oy, ox, ic, m); reads input channel ic only
    grid, (_, _, idep) = _spatial_min_read(op)
    kc = op.params.get("multiplier", 1)
    base = np.repeat(grid.reshape(-1), idep * kc)           # (Oh*Ow*Id*Kc,)
    chan = np.tile(np.repeat(np.arange(idep), kc), grid.size)
    return np.where(base >= _INF, _INF, base + chan)


def _profile_pool(op: Op, input_index: int) -> np.ndarray:
    grid, (_, _, idep) = _spatial_min_read(op)
    base = np.repeat(grid.reshape(-1), idep)
    chan = np.tile(np.arange(idep), grid.size)
    return np.where(base >= _INF, _INF, base + chan)


def _profile_elementwise(op: Op, input_index: int) -> np.ndarray:
    out_e = op.output.elems
    in_e = op.inputs[input_index].elems
    if in_e == out_e:
        return np.arange(out_e, dtype=np.int64)
    # broadcast input (e.g. bias): read offset i % in_e
    return np.arange(out_e, dtype=np.int64) % in_e


def _profile_softmax(op: Op, input_index: int) -> np.ndarray:
    # max & sum passes read everything before the first write; the write pass
    # reads in[i] at step i. Folding the pre-pass reads into step 0 keeps
    # minR[0] = 0 which is already implied by the write-pass reads.
    return np.arange(op.output.elems, dtype=np.int64)


def _profile_fully_connected(op: Op, input_index: int) -> np.ndarray:
    # steps: (b, oc); each step reads the whole input row b
    x = op.inputs[input_index]
    out_e = op.output.elems
    od = op.output.shape[-1]
    idim = x.shape[-1]
    b = np.arange(out_e, dtype=np.int64) // od
    return b * idim


def _profile_matmul_rhs(op: Op, input_index: int) -> np.ndarray:
    # reading the RHS: every step reads from offset (0 .. Id*Od); min read of
    # step (b, oc) is column oc's first element = oc (row-major (Id, Od)).
    od = op.output.shape[-1]
    out_e = op.output.elems
    return np.arange(out_e, dtype=np.int64) % od


def _profile_concat(op: Op, input_index: int) -> np.ndarray:
    axis = op.params.get("axis", -1)
    out = op.output
    shape = out.shape
    if axis < 0:
        axis += len(shape)
    outer = int(np.prod(shape[:axis])) if axis > 0 else 1
    inner = int(np.prod(shape[axis + 1:])) if axis + 1 < len(shape) else 1
    sizes = [t.shape[axis] for t in op.inputs]
    target = op.inputs[input_index]
    out_e = out.elems
    minr = np.full(out_e, _INF, dtype=np.int64)
    # output written sequentially; input j's slice within each outer block
    block = shape[axis] * inner
    start_in_block = sum(sizes[:input_index]) * inner
    seg = sizes[input_index] * inner
    for o in range(outer):
        s = o * block + start_in_block
        minr[s:s + seg] = o * seg + np.arange(seg)
    return minr


def _profile_pad(op: Op, input_index: int) -> np.ndarray:
    pads = op.params["paddings"]  # [(lo, hi)] per dim
    x = op.inputs[input_index]
    out = op.output
    out_e = out.elems
    # mapped input offset per output element; padding positions read nothing
    idx = np.arange(out_e, dtype=np.int64)
    coords = []
    rem = idx
    for d in range(len(out.shape) - 1, -1, -1):
        coords.append(rem % out.shape[d])
        rem = rem // out.shape[d]
    coords = coords[::-1]
    in_off = np.zeros(out_e, dtype=np.int64)
    valid = np.ones(out_e, dtype=bool)
    stride = 1
    for d in range(len(x.shape) - 1, -1, -1):
        c = coords[d] - pads[d][0]
        valid &= (c >= 0) & (c < x.shape[d])
        in_off += np.clip(c, 0, x.shape[d] - 1) * stride
        stride *= x.shape[d]
    return np.where(valid, in_off, _INF)


def _profile_mean(op: Op, input_index: int) -> np.ndarray:
    # all reads complete (accumulators) before the first write
    out_e = op.output.elems
    minr = np.full(out_e, _INF, dtype=np.int64)
    minr[0] = 0
    return minr


def _profile_embedding(op: Op, input_index: int) -> np.ndarray:
    # reads id i when writing row i: minR = row index
    out = op.output
    row = out.shape[-1]
    return np.arange(out.elems, dtype=np.int64) // row


_PROFILES = {
    "conv2d": _profile_conv2d,
    "depthwise_conv2d": _profile_depthwise,
    "pool": _profile_pool,
    "elementwise": _profile_elementwise,
    "softmax": _profile_softmax,
    "fully_connected": _profile_fully_connected,
    "concat": _profile_concat,
    "pad": _profile_pad,
    "mean": _profile_mean,
    "embedding_lookup": _profile_embedding,
}


def min_read_profile(op: Op, input_index: int = 0) -> Optional[np.ndarray]:
    """Raw per-step min read offset (elements, _INF = no read). None means
    "no model" (fully conservative)."""
    if op.kind == "matmul":
        return (_profile_fully_connected(op, input_index) if input_index == 0
                else _profile_matmul_rhs(op, input_index))
    fn = _PROFILES.get(op.kind)
    if fn is None:
        return None
    return fn(op, input_index)


def safe_overlap_algorithmic(op: Op, input_index: int = 0) -> int:
    """Exact ``O_s`` in bytes for (op, input_index) per Alg. 2."""
    out = op.output
    if op.kind == "reshape":
        return 0  # aliasing handled by the graph, not by overlap
    raw = min_read_profile(op, input_index)
    if raw is None:
        return 0  # custom / unknown: fully conservative
    ts_in = op.inputs[input_index].dtype_bytes
    ts_out = out.dtype_bytes
    minr_b = np.where(raw >= _INF, _INF, raw * ts_in)
    minr_b = _rev_cummin(minr_b)
    maxw_b = np.arange(out.elems, dtype=np.int64) * ts_out  # monotone writes
    diff = minr_b - maxw_b
    mind = int(min(diff.min(), 0)) if diff.size else 0
    os_bytes = out.nbytes + ts_out + mind - ts_out  # = OB + minD (bytes)
    # clip: the metric is "overlap of input start with output end"
    return int(max(0, min(out.nbytes, os_bytes)))
