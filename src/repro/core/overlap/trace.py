"""Bottom-up method for the safe buffer overlap (paper §III-B).

The paper instruments compiled binaries with a modified Valgrind; the
container equivalent is a *memory-event simulator*: we replay the TFLite
reference loop nest of each op kind in Python, emitting every load from the
input buffer and every store to the output buffer as (step, offset) events,
then post-process the raw event stream into ``O_s`` exactly as the paper's
tooling does. The op implementation here is treated as a black box by the
post-processing — it only sees events — so this path also validates the
event→O_s reduction itself.

Python loops: use small shapes (tests sweep these against the algorithmic
and analytic methods).
"""
from __future__ import annotations

from typing import Iterator, List, Tuple

import numpy as np

from repro.core.graph import Op, op_pads
from repro.core.overlap.algorithmic import _hwc

Event = Tuple[int, int, bool]  # (step, element offset, is_read)


def _conv_geometry(op: Op):
    ih, iw, idep = _hwc(op.inputs[0].shape)
    oh, ow, od = _hwc(op.output.shape)
    sh, sw = op.params.get("stride", (1, 1))
    dh, dw = op.params.get("dilation", (1, 1))
    kh, kw = op.params["kernel"]
    # band-aware (op_pads): row-banded ops replay their band-local loop nest
    ph, pw = op_pads(op)
    return (ih, iw, idep), (oh, ow, od), (sh, sw), (dh, dw), (kh, kw), (ph, pw)


def trace_events(op: Op, input_index: int = 0) -> Iterator[Event]:
    """Replay the reference loop nest, yielding load/store events."""
    if op.kind == "conv2d":
        (ih, iw, idep), (oh, ow, od), (sh, sw), (dh, dw), (kh, kw), (ph, pw) = \
            _conv_geometry(op)
        step = 0
        for oy in range(oh):
            for ox in range(ow):
                for oc in range(od):
                    for fy in range(kh):
                        iy = oy * sh - ph + fy * dh
                        if not 0 <= iy < ih:
                            continue
                        for fx in range(kw):
                            ix = ox * sw - pw + fx * dw
                            if not 0 <= ix < iw:
                                continue
                            for ic in range(idep):
                                yield step, (iy * iw + ix) * idep + ic, True
                    yield step, (oy * ow + ox) * od + oc, False
                    step += 1
    elif op.kind == "depthwise_conv2d":
        (ih, iw, idep), (oh, ow, od), (sh, sw), (dh, dw), (kh, kw), (ph, pw) = \
            _conv_geometry(op)
        kc = op.params.get("multiplier", 1)
        step = 0
        for oy in range(oh):
            for ox in range(ow):
                for ic in range(idep):
                    for m in range(kc):
                        for fy in range(kh):
                            iy = oy * sh - ph + fy * dh
                            if not 0 <= iy < ih:
                                continue
                            for fx in range(kw):
                                ix = ox * sw - pw + fx * dw
                                if not 0 <= ix < iw:
                                    continue
                                yield step, (iy * iw + ix) * idep + ic, True
                        yield step, (oy * ow + ox) * od + (ic * kc + m), False
                        step += 1
    elif op.kind == "pool":
        (ih, iw, idep), (oh, ow, od), (sh, sw), (dh, dw), (kh, kw), (ph, pw) = \
            _conv_geometry(op)
        step = 0
        for oy in range(oh):
            for ox in range(ow):
                for c in range(idep):
                    for fy in range(kh):
                        iy = oy * sh - ph + fy
                        if not 0 <= iy < ih:
                            continue
                        for fx in range(kw):
                            ix = ox * sw - pw + fx
                            if not 0 <= ix < iw:
                                continue
                            yield step, (iy * iw + ix) * idep + c, True
                    yield step, (oy * ow + ox) * od + c, False
                    step += 1
    elif op.kind in ("elementwise", "softmax"):
        n = op.output.elems
        in_e = op.inputs[input_index].elems
        if op.kind == "softmax":  # max + sum passes before any write
            for i in range(in_e):
                yield 0, i, True
        for i in range(n):
            yield i, i % in_e, True
            yield i, i, False
    elif op.kind in ("fully_connected", "matmul"):
        od = op.output.shape[-1]
        idim = op.inputs[0].shape[-1]
        batches = op.output.elems // od
        step = 0
        for b in range(batches):
            for oc in range(od):
                if input_index == 0:
                    for k in range(idim):
                        yield step, b * idim + k, True
                else:  # RHS (idim, od) row-major
                    for k in range(idim):
                        yield step, k * od + oc, True
                yield step, b * od + oc, False
                step += 1
    elif op.kind == "mean":
        in_e = op.inputs[0].elems
        for i in range(in_e):
            yield 0, i, True
        for i in range(op.output.elems):
            yield i, i, False
    else:
        raise NotImplementedError(f"trace for {op.kind}")


def events_to_overlap(events: List[Event], out_elems: int, ts_in: int,
                      ts_out: int) -> int:
    """Reduce a raw event stream to ``O_s`` (bytes) — black-box w.r.t. the op."""
    if not events:
        return 0
    n_steps = max(s for s, _, _ in events) + 1
    INF = np.iinfo(np.int64).max // 4
    min_r = np.full(n_steps, INF, dtype=np.int64)
    max_w = np.full(n_steps, -1, dtype=np.int64)
    for s, off, is_read in events:
        if is_read:
            min_r[s] = min(min_r[s], off * ts_in)
        else:
            max_w[s] = max(max_w[s], off * ts_out)
    min_r = np.minimum.accumulate(min_r[::-1])[::-1]   # min of this & future
    max_w = np.maximum.accumulate(max_w)               # max of this & past
    valid = max_w >= 0
    mind = int(min((min_r[valid] - max_w[valid]).min(), 0)) if valid.any() else 0
    ob = out_elems * ts_out
    return int(max(0, min(ob, ob + mind)))


def safe_overlap_trace(op: Op, input_index: int = 0) -> int:
    ts_in = op.inputs[input_index].dtype_bytes
    ts_out = op.output.dtype_bytes
    events = list(trace_events(op, input_index))
    # only keep reads of the requested input (the generator already does so)
    return events_to_overlap(events, op.output.elems, ts_in, ts_out)
