"""Safe buffer overlap (``O_s``) calculators — the paper's Section III.

Three methods, in increasing order of speed / decreasing generality:

- :mod:`.trace`       — bottom-up: replay the reference loop nest and record
                        every load/store event (the Valgrind stand-in).
- :mod:`.algorithmic` — Alg. 2: vectorised ``minR``/``maxW`` construction.
- :mod:`.analytic`    — Eqs. (5)-(15): closed-form truncated-linear bound.

All return ``O_s`` in **bytes**: the maximum number of bytes the start of the
given input buffer may overlap the end of the output buffer.
"""
from repro.core.overlap.algorithmic import safe_overlap_algorithmic
from repro.core.overlap.analytic import safe_overlap_analytic
from repro.core.overlap.trace import safe_overlap_trace


#: Op kinds for which the PAPER derives O_s solutions (§III-D + Fig. 3):
#: conv family, pooling, elementwise (incl. the in-place special case) and
#: the degenerate matmul. Everything else is treated as O_s = 0 in
#: paper-faithful mode; ``extended`` mode (beyond paper) also overlaps
#: concat / pad / mean / embedding via the algorithmic method.
PAPER_KINDS = frozenset({
    "conv2d", "depthwise_conv2d", "pool", "elementwise", "softmax",
    "fully_connected", "matmul", "mean",
})


def _image_op(op):
    """A per-image (batch-1) clone of a batched op: the three calculators
    replay the per-image loop nest, so their element counts and byte sizes
    must exclude the batch axis. The planner re-scales the per-image O_s to
    the batch (``planner.batched_os_bytes``). Batch-1 ops pass through
    untouched."""
    if all(t.batch == 1 for t in list(op.inputs) + list(op.outputs)):
        return op
    from repro.core.graph import Op, Tensor

    def img(t):
        return Tensor(t.name, t.shape, t.dtype_bytes, t.kind, None, batch=1)

    return Op(op.kind, [img(t) for t in op.inputs],
              [img(t) for t in op.outputs], dict(op.params), op.name)


def safe_overlap(op, input_index: int = 0, method: str = "auto",
                 profile: str = "paper") -> int:
    """Dispatch: ``auto`` prefers the analytic closed form (cheapest, always a
    safe lower bound) and falls back to the algorithmic method for op kinds
    without a derived analytic solution. ``profile='paper'`` restricts the
    overlap to the op kinds the paper derives; ``'extended'`` covers all.
    Batched ops are evaluated per-image (see :func:`_image_op`); the result
    is always the PER-IMAGE ``O_s`` in bytes."""
    if profile == "paper" and op.kind not in PAPER_KINDS:
        return 0
    op = _image_op(op)
    if method == "trace":
        return safe_overlap_trace(op, input_index)
    if method == "algorithmic":
        return safe_overlap_algorithmic(op, input_index)
    if method == "analytic":
        r = safe_overlap_analytic(op, input_index)
        if r is None:
            raise ValueError(f"no analytic O_s for op kind {op.kind!r}")
        return r
    if method == "auto":
        r = safe_overlap_analytic(op, input_index)
        if r is None:
            r = safe_overlap_algorithmic(op, input_index)
        return r
    raise ValueError(method)


__all__ = [
    "safe_overlap",
    "safe_overlap_trace",
    "safe_overlap_algorithmic",
    "safe_overlap_analytic",
]
