"""Legacy arena-execution API — thin wrapper over the ``numpy`` executor
backend.

The executors themselves moved into the pluggable backend layer:

- op semantics (row loops, weight synthesis): :mod:`repro.core.exec.ops`
- numpy backend (this module's old contents): :mod:`repro.core.exec.numpy_backend`
- pallas backend (flat donated arena, Pallas kernels):
  :mod:`repro.core.exec.pallas_backend`

:func:`run_reference` / :func:`run_in_arena` / :func:`verify_plan` keep their
historical signatures and bit-exact semantics; new code should go through
:func:`repro.core.exec.get_backend` (or ``CompiledPlan.execute``) instead.
"""
from __future__ import annotations

from repro.core.exec.numpy_backend import (ArenaExec, ReferenceExec,
                                           run_in_arena, run_reference,
                                           verify_plan)

__all__ = [
    "ArenaExec", "ReferenceExec", "run_in_arena", "run_reference",
    "verify_plan",
]
