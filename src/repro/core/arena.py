"""Arena execution + plan safety verification (the TFMin analogue).

Two executors over the same NumPy reference ops:

- :func:`run_reference` — private buffer per tensor (ground truth);
- :func:`run_in_arena`  — all intermediates live inside ONE flat byte arena
  at the offsets chosen by a :class:`~repro.core.planner.Plan`, each op
  processing its output *row by row in ascending index order* (reads of a row
  happen no later, and writes no earlier, than the reference element order —
  so a plan safe for the element order is safe here).

:func:`verify_plan` runs both and asserts bit-exact equality: if the plan
overlapped any buffer unsafely, the arena execution clobbers a live value and
the comparison fails. This is the open-source-tool verification described in
the paper's §I.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.graph import Graph, Op, Tensor, pad_amount
from repro.core.planner import Plan


def _weights_for(op: Op, rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """Deterministic random weights per op (same for both executors)."""
    w: Dict[str, np.ndarray] = {}
    if op.kind == "conv2d":
        kh, kw = op.params["kernel"]
        ic = op.inputs[0].shape[-1]
        oc = op.output.shape[-1]
        w["filter"] = rng.standard_normal((kh, kw, ic, oc)).astype(np.float32)
    elif op.kind == "depthwise_conv2d":
        kh, kw = op.params["kernel"]
        ic = op.inputs[0].shape[-1]
        kc = op.params.get("multiplier", 1)
        w["filter"] = rng.standard_normal((kh, kw, ic, kc)).astype(np.float32)
    elif op.kind == "fully_connected":
        idim = op.inputs[0].shape[-1]
        od = op.output.shape[-1]
        w["filter"] = rng.standard_normal((idim, od)).astype(np.float32)
    return w


def _pads(op: Op):
    ih, iw = op.inputs[0].shape[-3], op.inputs[0].shape[-2]
    oh, ow = op.output.shape[-3], op.output.shape[-2]
    kh, kw = op.params["kernel"]
    sh, sw = op.params.get("stride", (1, 1))
    dh, dw = op.params.get("dilation", (1, 1))
    if op.params.get("padding", "same") == "same":
        return pad_amount(ih, oh, kh, sh, dh), pad_amount(iw, ow, kw, sw, dw)
    return 0, 0


def _conv_row(op: Op, x: np.ndarray, filt: np.ndarray, oy: int) -> np.ndarray:
    """One output row of conv2d/depthwise (x is HWC)."""
    ih, iw, ic = x.shape
    oh, ow = op.output.shape[-3], op.output.shape[-2]
    kh, kw = op.params["kernel"]
    sh, sw = op.params.get("stride", (1, 1))
    dh, dw = op.params.get("dilation", (1, 1))
    ph, pw = _pads(op)
    if op.kind == "conv2d":
        oc = op.output.shape[-1]
        row = np.zeros((ow, oc), np.float32)
    else:
        kc = op.params.get("multiplier", 1)
        row = np.zeros((ow, ic * kc), np.float32)
    for fy in range(kh):
        iy = oy * sh - ph + fy * dh
        if not 0 <= iy < ih:
            continue
        for fx in range(kw):
            ixs = np.arange(ow) * sw - pw + fx * dw
            valid = (ixs >= 0) & (ixs < iw)
            src = x[iy, np.clip(ixs, 0, iw - 1), :]          # (Ow, ic)
            src = np.where(valid[:, None], src, 0.0)
            if op.kind == "conv2d":
                row += src @ filt[fy, fx]                     # (Ow, oc)
            else:
                kc = op.params.get("multiplier", 1)
                contrib = src[:, :, None] * filt[fy, fx][None, :, :]
                row += contrib.reshape(ow, ic * kc)
    return row


def _pool_row(op: Op, x: np.ndarray, oy: int) -> np.ndarray:
    ih, iw, c = x.shape
    ow = op.output.shape[-2]
    kh, kw = op.params["kernel"]
    sh, sw = op.params.get("stride", (1, 1))
    ph, pw = _pads(op)
    mode = op.params.get("mode", "avg")
    acc = np.full((ow, c), -np.inf if mode == "max" else 0.0, np.float32)
    cnt = np.zeros((ow, 1), np.float32)
    for fy in range(kh):
        iy = oy * sh - ph + fy
        if not 0 <= iy < ih:
            continue
        for fx in range(kw):
            ixs = np.arange(ow) * sw - pw + fx
            valid = (ixs >= 0) & (ixs < iw)
            src = x[iy, np.clip(ixs, 0, iw - 1), :]
            if mode == "max":
                acc = np.where(valid[:, None], np.maximum(acc, src), acc)
            else:
                acc += np.where(valid[:, None], src, 0.0)
                cnt += valid[:, None].astype(np.float32)
    if mode == "avg":
        acc = acc / np.maximum(cnt, 1.0)
    return acc


_ELEMENTWISE = {
    "relu": lambda a: np.maximum(a, 0.0),
    "relu6": lambda a: np.clip(a, 0.0, 6.0),
    "sigmoid": lambda a: 1.0 / (1.0 + np.exp(-a)),
    "identity": lambda a: a,
    "add": lambda a, b: a + b,
    "mul": lambda a, b: a * b,
    "sub": lambda a, b: a - b,
}


class _Exec:
    """Shared op evaluation; subclasses define tensor load/store."""

    def __init__(self, graph: Graph, seed: int = 0):
        self.graph = graph
        self.rng = np.random.default_rng(seed)
        self.weights = {id(op): _weights_for(op, self.rng) for op in graph.ops}

    def load(self, t: Tensor) -> np.ndarray:
        raise NotImplementedError

    def store(self, t: Tensor, v: np.ndarray) -> None:
        raise NotImplementedError

    def store_rows(self, op: Op, rows) -> None:
        """Default: materialise and store whole tensor (reference executor)."""
        out = np.stack([r for r in rows], axis=0)
        self.store(op.output, out.reshape(op.output.shape))

    def run(self, order: Optional[List[Op]] = None) -> None:
        for op in (order or self.graph.ops):
            self.execute(op)

    def execute(self, op: Op) -> None:
        k = op.kind
        if k in ("conv2d", "depthwise_conv2d"):
            x = self.load(op.inputs[0]).reshape(op.inputs[0].shape)
            x3 = x.reshape(x.shape[-3:])
            filt = self.weights[id(op)]["filter"]
            oh = op.output.shape[-3]
            self.store_rows(op, (_conv_row(op, x3, filt, oy) for oy in range(oh)))
        elif k == "pool":
            x3 = self.load(op.inputs[0]).reshape(op.inputs[0].shape[-3:])
            oh = op.output.shape[-3]
            self.store_rows(op, (_pool_row(op, x3, oy) for oy in range(oh)))
        elif k == "elementwise":
            fn = _ELEMENTWISE[op.params.get("fn", "relu")]
            xs = [self.load(t).reshape(t.shape) for t in op.inputs
                  if t.kind != "weight"]
            if len(xs) == 2 and xs[1].size != xs[0].size:
                xs[1] = np.broadcast_to(xs[1], xs[0].shape)
            self.store(op.output, fn(*xs).astype(np.float32))
        elif k == "softmax":
            x = self.load(op.inputs[0]).reshape(op.inputs[0].shape)
            e = np.exp(x - x.max(axis=-1, keepdims=True))
            self.store(op.output, (e / e.sum(axis=-1, keepdims=True)).astype(np.float32))
        elif k == "fully_connected":
            x = self.load(op.inputs[0]).reshape(-1, op.inputs[0].shape[-1])
            filt = self.weights[id(op)]["filter"]
            self.store(op.output, (x @ filt).reshape(op.output.shape).astype(np.float32))
        elif k == "matmul":
            a = self.load(op.inputs[0]).reshape(-1, op.inputs[0].shape[-1])
            b = self.load(op.inputs[1]).reshape(op.inputs[1].shape)
            self.store(op.output, (a @ b).reshape(op.output.shape).astype(np.float32))
        elif k == "concat":
            axis = op.params.get("axis", -1)
            xs = [self.load(t).reshape(t.shape) for t in op.inputs]
            self.store(op.output, np.concatenate(xs, axis=axis))
        elif k == "pad":
            x = self.load(op.inputs[0]).reshape(op.inputs[0].shape)
            self.store(op.output, np.pad(x, op.params["paddings"]))
        elif k == "mean":
            x = self.load(op.inputs[0]).reshape(op.inputs[0].shape)
            axes = tuple(op.params.get("axes", range(x.ndim - 1)))
            self.store(op.output, x.mean(axis=axes).reshape(op.output.shape)
                       .astype(np.float32))
        elif k == "reshape":
            pass  # aliasing no-op
        else:
            raise NotImplementedError(f"arena executor: {k}")


class ReferenceExec(_Exec):
    def __init__(self, graph: Graph, inputs: Dict[str, np.ndarray], seed: int = 0):
        super().__init__(graph, seed)
        self.vals: Dict[Tensor, np.ndarray] = {}
        for t in graph.tensors:
            if t.kind == "input":
                self.vals[t.storage()] = inputs[t.name].astype(np.float32)

    def load(self, t: Tensor) -> np.ndarray:
        return self.vals[t.storage()]

    def store(self, t: Tensor, v: np.ndarray) -> None:
        self.vals[t.storage()] = v.reshape(t.shape)


class ArenaExec(_Exec):
    """Executes inside a single flat float32 arena at planned offsets.

    Conv/pool outputs are written row-by-row (ascending), loads re-read the
    arena for every row — faithfully modelling the MCU execution order that
    DMO's O_s guarantees safe.
    """

    def __init__(self, graph: Graph, plan: Plan,
                 inputs: Dict[str, np.ndarray], seed: int = 0):
        super().__init__(graph, seed)
        self.plan = plan
        assert plan.peak_bytes % 4 == 0
        self.arena = np.zeros(plan.peak_bytes // 4, np.float32)
        for t in graph.tensors:
            if t.kind == "input":
                self.store(t, inputs[t.name].astype(np.float32))

    def _slice(self, t: Tensor) -> slice:
        s = t.storage()
        off = self.plan.offsets[s]
        assert off % 4 == 0 and s.dtype_bytes == 4, "arena exec is float32-only"
        return slice(off // 4, off // 4 + s.elems)

    def load(self, t: Tensor) -> np.ndarray:
        return self.arena[self._slice(t)].copy().reshape(t.shape)

    def store(self, t: Tensor, v: np.ndarray) -> None:
        self.arena[self._slice(t)] = v.reshape(-1)

    def store_rows(self, op: Op, rows) -> None:
        out = op.output
        sl = self._slice(out)
        row_elems = out.elems // out.shape[-3]
        base = sl.start
        for i, r in enumerate(rows):
            # NOTE: each row's inputs were loaded lazily by _conv_row via the
            # generator *before* this store — but rows are produced one at a
            # time, so reads for row i+1 happen after the row-i store, exactly
            # the diagonal order.
            self.arena[base + i * row_elems: base + (i + 1) * row_elems] = r.reshape(-1)

    def execute(self, op: Op) -> None:
        # conv/pool must re-load input per row to see the live arena
        if op.kind in ("conv2d", "depthwise_conv2d", "pool"):
            x_t = op.inputs[0]
            filt = self.weights[id(op)].get("filter")
            oh = op.output.shape[-3]

            def rows():
                for oy in range(oh):
                    x3 = self.load(x_t).reshape(x_t.shape[-3:])
                    if op.kind == "pool":
                        yield _pool_row(op, x3, oy)
                    else:
                        yield _conv_row(op, x3, filt, oy)

            self.store_rows(op, rows())
        else:
            super().execute(op)


def run_reference(graph: Graph, inputs: Dict[str, np.ndarray],
                  order: Optional[List[Op]] = None, seed: int = 0
                  ) -> Dict[str, np.ndarray]:
    ex = ReferenceExec(graph, inputs, seed)
    ex.run(order)
    return {t.name: ex.vals[t.storage()]
            for t in graph.tensors if t.kind == "output"}


def run_in_arena(graph: Graph, plan: Plan, inputs: Dict[str, np.ndarray],
                 seed: int = 0) -> Dict[str, np.ndarray]:
    ex = ArenaExec(graph, plan, inputs, seed)
    ex.run(plan.order)
    return {t.name: ex.load(t) for t in graph.tensors if t.kind == "output"}


def verify_plan(graph: Graph, plan: Plan, seed: int = 0) -> None:
    """Assert the planned arena execution is bit-exact vs private buffers."""
    rng = np.random.default_rng(seed + 1)
    inputs = {
        t.name: rng.standard_normal(t.shape).astype(np.float32)
        for t in graph.tensors if t.kind == "input"
    }
    ref = run_reference(graph, inputs, plan.order, seed)
    got = run_in_arena(graph, plan, inputs, seed)
    for k in ref:
        np.testing.assert_array_equal(ref[k], got[k], err_msg=f"output {k}")
