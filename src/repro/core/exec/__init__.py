"""Executor backends: run a planned arena on a real runtime.

A :class:`~repro.core.planner.Plan` (or a
:class:`~repro.core.pipeline.CompiledPlan`) describes ONE flat arena —
offsets plus the safe diagonal overlaps ``O_s`` — and the paper's claim is
that it is *executable*: ops walk output rows in ascending order inside the
shared buffer and never clobber a live value. This package turns that claim
into a pluggable runtime layer:

- ``numpy``  — :mod:`.numpy_backend`: the row-by-row NumPy interpreter
  (bit-exact ground truth, used by ``verify_plan``);
- ``pallas`` — :mod:`.pallas_backend`: lowers the plan to a sequence of
  Pallas kernels over one donated arena buffer (``input_output_aliases``
  threads the arena through the op sequence). Three programs: the
  **row-blocked** 2-D arena (plans legalised onto per-dtype VMEM tiles by
  :func:`repro.core.planner.legalise_for_blocks` — the compiled-mode path,
  and the default whenever the plan legalises), the **streaming** grid
  program (``mode="streaming"``: arena in HBM, each op's live window
  DMA'd into VMEM scratch per the planner's
  :meth:`~repro.core.planner.BlockPlan.window_schedule`, VMEM-gated on
  the window instead of the whole arena), and the **flat** byte arena
  (interpret-only fallback for mixed-dtype plans, and the cross-check
  reference). ``mode="interpret"`` runs any of them on CPU CI;
  ``mode="compiled"`` (or ``REPRO_DMO_INTERPRET=0``) lowers the blocked
  program with ``interpret=False`` — the TPU analogue of the paper's SRAM
  arena being VMEM. Select per instance via
  ``get_backend("pallas", mode=..., layout=...)``.

Every backend implements the :class:`ArenaExecutor` protocol::

    outputs = get_backend("pallas").execute(plan_or_compiled, inputs, weights)

``inputs``/``weights`` default to the deterministic synthesis of
:mod:`repro.core.exec.ops`, so two backends handed the same (plan, seed)
execute the identical network and can be diffed output-for-output
(:func:`cross_check`).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Protocol, Tuple

import numpy as np

from repro.core.exec import ops
from repro.core.exec.ops import (ELEMENTWISE, SUPPORTED_DTYPES,
                                 SUPPORTED_KINDS, OpQuant, QParams, QuantSpec,
                                 arena_dtype, calibrate, executability,
                                 executable, needs_quant, op_quant,
                                 quant_inputs, random_inputs, synth_weights)
from repro.core.graph import Graph
from repro.core.planner import Plan


class ArenaExecutor(Protocol):
    """An executor backend: runs a planned graph inside its flat arena."""

    #: registry name ("numpy", "pallas", ...)
    name: str

    def execute(self, plan_or_compiled, inputs=None, weights=None, *,
                seed: int = 0, quant=None) -> Dict[str, np.ndarray]:
        """Execute ``plan_or_compiled`` (a Plan or CompiledPlan) and return
        the model outputs keyed by tensor name. ``inputs`` / ``weights``
        default to the deterministic per-seed synthesis shared by all
        backends; ``quant`` is the :class:`~repro.core.exec.ops.QuantSpec`
        for int8 graphs (auto-calibrated when omitted)."""
        ...


def unwrap_plan(plan_or_compiled) -> Tuple[Plan, Graph]:
    """Accept a Plan or a CompiledPlan; return (plan, executed graph)."""
    if isinstance(plan_or_compiled, Plan):
        return plan_or_compiled, plan_or_compiled.graph
    plan = getattr(plan_or_compiled, "plan", None)
    if isinstance(plan, Plan):
        return plan, plan.graph
    raise TypeError(f"expected Plan or CompiledPlan, got "
                    f"{type(plan_or_compiled).__name__}")


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

_FACTORIES: Dict[str, Callable[..., ArenaExecutor]] = {}
_INSTANCES: Dict[str, ArenaExecutor] = {}


def register_backend(name: str, factory: Callable[..., ArenaExecutor]) -> None:
    _FACTORIES[name] = factory
    _INSTANCES.pop(name, None)  # re-registration must not serve a stale one


def available_backends() -> Tuple[str, ...]:
    return tuple(_FACTORIES)


def get_backend(name: str, **kwargs: Any) -> ArenaExecutor:
    """Backend instance by name. Default-configured instances are cached;
    passing kwargs constructs a fresh one."""
    if name not in _FACTORIES:
        raise ValueError(
            f"unknown executor backend {name!r}; available: "
            f"{available_backends()}")
    if kwargs:
        return _FACTORIES[name](**kwargs)
    if name not in _INSTANCES:
        _INSTANCES[name] = _FACTORIES[name]()
    return _INSTANCES[name]


def _numpy_factory(**kw) -> ArenaExecutor:
    from repro.core.exec.numpy_backend import NumpyExecutor
    return NumpyExecutor(**kw)


def _pallas_factory(**kw) -> ArenaExecutor:
    # imported lazily: the core planning path must not pay the jax import
    from repro.core.exec.pallas_backend import PallasExecutor
    return PallasExecutor(**kw)


register_backend("numpy", _numpy_factory)
register_backend("pallas", _pallas_factory)


# ---------------------------------------------------------------------------
# Cross-backend verification
# ---------------------------------------------------------------------------

#: fp32 tolerance for backends whose accumulations XLA may reassociate
#: relative to the numpy loop order. The single source of truth — the verify
#: pass, verify_plan and cross_check all compare through it.
FP32_RTOL = 1e-4
FP32_ATOL = 1e-4
#: Integer (int8) outputs tolerate one least-significant quantisation step:
#: transcendental ulp differences (exp in softmax/sigmoid) can flip a round.
INT8_ATOL = 1


def compare_outputs(ref: Dict[str, np.ndarray], got: Dict[str, np.ndarray],
                    exact: bool, label: str) -> None:
    """Assert two output dicts match: bit-exact, or at the shared tolerance
    for the output's dtype (fp32 atol/rtol for float outputs, <= 1 LSB for
    quantised int8 outputs). Raises ``AssertionError`` on any mismatch."""
    assert ref.keys() == got.keys(), f"{label}: output sets differ"
    for k in ref:
        if exact:
            np.testing.assert_array_equal(got[k], ref[k],
                                          err_msg=f"output {k} ({label})")
        elif np.issubdtype(np.asarray(ref[k]).dtype, np.integer):
            np.testing.assert_allclose(
                np.asarray(got[k]).astype(np.int32),
                np.asarray(ref[k]).astype(np.int32),
                rtol=0, atol=INT8_ATOL, err_msg=f"output {k} ({label})")
        else:
            np.testing.assert_allclose(got[k], ref[k], rtol=FP32_RTOL,
                                       atol=FP32_ATOL,
                                       err_msg=f"output {k} ({label})")


def cross_check(plan_or_compiled, seed: int = 0,
                backends: Tuple = ("numpy", "pallas")) -> None:
    """Execute the plan on both backends with identical inputs/weights (and,
    for int8 graphs, one shared calibration) and assert the arena outputs
    agree — fp32 tolerance where XLA may reassociate the dot-product
    accumulations the numpy semantics run in loop order, <= 1 LSB on
    quantised outputs. Raises ``AssertionError`` on any mismatch. Entries of
    ``backends`` are registry names or pre-configured executor instances
    (e.g. ``get_backend("pallas", layout="flat")``), so differently-laid-out
    programs of one backend can be diffed too."""
    plan, graph = unwrap_plan(plan_or_compiled)
    reason = executability(graph)
    if reason is not None:
        raise ValueError(f"graph is not executable by arena backends: {reason}")
    weights = synth_weights(graph, seed)
    quant = calibrate(graph, seed, weights) if needs_quant(graph) else None
    inputs = (quant_inputs(graph, quant, seed) if quant is not None
              else random_inputs(graph, seed))
    resolve = lambda b: b if hasattr(b, "execute") else get_backend(b)
    label = lambda b: b if isinstance(b, str) else getattr(b, "name", str(b))
    a = resolve(backends[0]).execute(plan, inputs, weights, seed=seed,
                                     quant=quant)
    b = resolve(backends[1]).execute(plan, inputs, weights, seed=seed,
                                     quant=quant)
    compare_outputs(a, b, exact=False,
                    label=f"{label(backends[1])} vs {label(backends[0])}")


__all__ = [
    "ArenaExecutor", "ELEMENTWISE", "FP32_ATOL", "FP32_RTOL", "INT8_ATOL",
    "arena_dtype",
    "OpQuant", "QParams", "QuantSpec", "SUPPORTED_DTYPES", "SUPPORTED_KINDS",
    "available_backends", "calibrate", "compare_outputs", "cross_check",
    "executability", "executable", "get_backend", "needs_quant", "op_quant",
    "ops", "quant_inputs", "random_inputs", "register_backend",
    "synth_weights", "unwrap_plan",
]
