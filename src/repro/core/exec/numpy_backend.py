"""NumPy arena executor backend (the TFMin analogue, reference semantics).

Two executors over the shared op semantics of :mod:`repro.core.exec.ops`:

- :class:`ReferenceExec` — private buffer per tensor (ground truth);
- :class:`ArenaExec`     — all intermediates live inside ONE flat byte arena
  at the offsets chosen by a :class:`~repro.core.planner.Plan`, each op
  processing its output *row by row in ascending index order* (reads of a row
  happen no later, and writes no earlier, than the reference element order —
  so a plan safe for the element order is safe here).

:class:`NumpyExecutor` wraps the pair behind the
:class:`~repro.core.exec.ArenaExecutor` protocol; :func:`verify_plan` runs
an arena backend against the private-buffer reference and asserts equality
(bit-exact for numpy, fp32 tolerance for backends whose accumulation order
XLA may reassociate). If the plan overlapped any buffer unsafely, the arena
execution clobbers a live value and the comparison fails — the
open-source-tool verification described in the paper's §I.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.exec import ops as X
from repro.core.graph import Graph, Op, Tensor
from repro.core.planner import Plan


class _Exec:
    """Shared op evaluation; subclasses define tensor load/store."""

    def __init__(self, graph: Graph, seed: int = 0,
                 weights: Optional[Dict[int, Dict[str, np.ndarray]]] = None):
        self.graph = graph
        self.weights = weights if weights is not None else X.synth_weights(
            graph, seed)

    def load(self, t: Tensor) -> np.ndarray:
        raise NotImplementedError

    def store(self, t: Tensor, v: np.ndarray) -> None:
        raise NotImplementedError

    def store_rows(self, op: Op, rows) -> None:
        """Default: materialise and store whole tensor (reference executor)."""
        out = np.stack([r for r in rows], axis=0)
        self.store(op.output, out.reshape(op.output.shape))

    def run(self, order: Optional[List[Op]] = None) -> None:
        for op in (order or self.graph.ops):
            self.execute(op)

    def execute(self, op: Op) -> None:
        k = op.kind
        if k in ("conv2d", "depthwise_conv2d"):
            x = self.load(op.inputs[0]).reshape(op.inputs[0].shape)
            x3 = x.reshape(x.shape[-3:])
            filt = self.weights[id(op)]["filter"]
            oh = op.output.shape[-3]
            self.store_rows(op, (X.conv_row(op, x3, filt, oy)
                                 for oy in range(oh)))
        elif k == "pool":
            x3 = self.load(op.inputs[0]).reshape(op.inputs[0].shape[-3:])
            oh = op.output.shape[-3]
            self.store_rows(op, (X.pool_row(op, x3, oy) for oy in range(oh)))
        elif k == "elementwise":
            fn = X.ELEMENTWISE[op.params.get("fn", "relu")]
            xs = [self.load(t).reshape(t.shape) for t in op.inputs
                  if t.kind != "weight"]
            if len(xs) == 2 and xs[1].size != xs[0].size:
                xs[1] = np.broadcast_to(xs[1], xs[0].shape)
            self.store(op.output, fn(*xs).astype(np.float32))
        elif k == "softmax":
            x = self.load(op.inputs[0]).reshape(op.inputs[0].shape)
            e = np.exp(x - x.max(axis=-1, keepdims=True))
            self.store(op.output,
                       (e / e.sum(axis=-1, keepdims=True)).astype(np.float32))
        elif k == "fully_connected":
            x = self.load(op.inputs[0]).reshape(-1, op.inputs[0].shape[-1])
            filt = self.weights[id(op)]["filter"]
            self.store(op.output,
                       (x @ filt).reshape(op.output.shape).astype(np.float32))
        elif k == "matmul":
            a = self.load(op.inputs[0]).reshape(-1, op.inputs[0].shape[-1])
            b = self.load(op.inputs[1]).reshape(op.inputs[1].shape)
            self.store(op.output,
                       (a @ b).reshape(op.output.shape).astype(np.float32))
        elif k == "concat":
            axis = op.params.get("axis", -1)
            xs = [self.load(t).reshape(t.shape) for t in op.inputs]
            self.store(op.output, np.concatenate(xs, axis=axis))
        elif k == "pad":
            x = self.load(op.inputs[0]).reshape(op.inputs[0].shape)
            self.store(op.output, np.pad(x, op.params["paddings"]))
        elif k == "mean":
            x = self.load(op.inputs[0]).reshape(op.inputs[0].shape)
            axes = tuple(op.params.get("axes", range(x.ndim - 1)))
            self.store(op.output, x.mean(axis=axes).reshape(op.output.shape)
                       .astype(np.float32))
        elif k == "reshape":
            pass  # aliasing no-op
        else:
            raise NotImplementedError(f"arena executor: {k}")


class ReferenceExec(_Exec):
    def __init__(self, graph: Graph, inputs: Dict[str, np.ndarray],
                 seed: int = 0, weights=None):
        super().__init__(graph, seed, weights)
        self.vals: Dict[Tensor, np.ndarray] = {}
        for t in graph.tensors:
            if t.kind == "input":
                self.vals[t.storage()] = inputs[t.name].astype(np.float32)

    def load(self, t: Tensor) -> np.ndarray:
        return self.vals[t.storage()]

    def store(self, t: Tensor, v: np.ndarray) -> None:
        self.vals[t.storage()] = v.reshape(t.shape)


class ArenaExec(_Exec):
    """Executes inside a single flat float32 arena at planned offsets.

    Conv/pool outputs are written row-by-row (ascending), loads re-read the
    arena for every row — faithfully modelling the MCU execution order that
    DMO's O_s guarantees safe.
    """

    def __init__(self, graph: Graph, plan: Plan,
                 inputs: Dict[str, np.ndarray], seed: int = 0, weights=None):
        super().__init__(graph, seed, weights)
        self.plan = plan
        assert plan.peak_bytes % 4 == 0
        self.arena = np.zeros(plan.peak_bytes // 4, np.float32)
        for t in graph.tensors:
            if t.kind == "input":
                self.store(t, inputs[t.name].astype(np.float32))

    def _slice(self, t: Tensor) -> slice:
        s = t.storage()
        off = self.plan.offsets[s]
        assert off % 4 == 0 and s.dtype_bytes == 4, "arena exec is float32-only"
        return slice(off // 4, off // 4 + s.elems)

    def load(self, t: Tensor) -> np.ndarray:
        return self.arena[self._slice(t)].copy().reshape(t.shape)

    def store(self, t: Tensor, v: np.ndarray) -> None:
        self.arena[self._slice(t)] = v.reshape(-1)

    def store_rows(self, op: Op, rows) -> None:
        out = op.output
        sl = self._slice(out)
        row_elems = out.elems // out.shape[-3]
        base = sl.start
        for i, r in enumerate(rows):
            # NOTE: each row's inputs were loaded lazily by conv_row via the
            # generator *before* this store — but rows are produced one at a
            # time, so reads for row i+1 happen after the row-i store, exactly
            # the diagonal order.
            self.arena[base + i * row_elems: base + (i + 1) * row_elems] = \
                r.reshape(-1)

    def execute(self, op: Op) -> None:
        # conv/pool must re-load input per row to see the live arena
        if op.kind in ("conv2d", "depthwise_conv2d", "pool"):
            x_t = op.inputs[0]
            filt = self.weights[id(op)].get("filter")
            oh = op.output.shape[-3]

            def rows():
                for oy in range(oh):
                    x3 = self.load(x_t).reshape(x_t.shape[-3:])
                    if op.kind == "pool":
                        yield X.pool_row(op, x3, oy)
                    else:
                        yield X.conv_row(op, x3, filt, oy)

            self.store_rows(op, rows())
        else:
            super().execute(op)


# ---------------------------------------------------------------------------
# Module-level API (legacy names; repro.core.arena re-exports these)
# ---------------------------------------------------------------------------


def run_reference(graph: Graph, inputs: Dict[str, np.ndarray],
                  order: Optional[List[Op]] = None, seed: int = 0,
                  weights=None) -> Dict[str, np.ndarray]:
    ex = ReferenceExec(graph, inputs, seed, weights)
    ex.run(order)
    return {t.name: ex.vals[t.storage()]
            for t in graph.tensors if t.kind == "output"}


def run_in_arena(graph: Graph, plan: Plan, inputs: Dict[str, np.ndarray],
                 seed: int = 0, weights=None) -> Dict[str, np.ndarray]:
    ex = ArenaExec(graph, plan, inputs, seed, weights)
    ex.run(plan.order)
    return {t.name: ex.load(t) for t in graph.tensors if t.kind == "output"}


class NumpyExecutor:
    """The ``numpy`` :class:`~repro.core.exec.ArenaExecutor` backend."""

    name = "numpy"

    def execute(self, plan_or_compiled, inputs=None, weights=None, *,
                seed: int = 0) -> Dict[str, np.ndarray]:
        from repro.core.exec import unwrap_plan
        plan, graph = unwrap_plan(plan_or_compiled)
        reason = X.executability(graph)
        if reason is not None:
            # same gate as the pallas backend: split row bands / strided
            # views / non-f32 graphs would execute with silently wrong
            # semantics rather than fail — refuse loudly instead
            raise ValueError(
                f"numpy backend cannot execute {graph.name!r}: {reason}")
        if inputs is None:
            inputs = X.random_inputs(graph, seed)
        if weights is None:
            weights = X.synth_weights(graph, seed)
        return run_in_arena(graph, plan, inputs, seed, weights)


def verify_plan(graph: Graph, plan: Plan, seed: int = 0,
                backend: str = "numpy") -> None:
    """Assert the planned arena execution matches private buffers: bit-exact
    for the numpy backend; fp32 tolerance for backends (pallas) whose dot
    accumulations XLA may reassociate. Any unsafe overlap in the plan
    clobbers a live value and raises ``AssertionError``."""
    from repro.core.exec import compare_outputs, get_backend
    inputs = X.random_inputs(graph, seed)
    weights = X.synth_weights(graph, seed)
    ref = run_reference(graph, inputs, plan.order, seed, weights)
    got = get_backend(backend).execute(plan, inputs, weights, seed=seed)
    compare_outputs(ref, got, exact=(backend == "numpy"),
                    label=f"{backend} arena vs reference")
