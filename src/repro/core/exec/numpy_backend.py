"""NumPy arena executor backend (the TFMin analogue, reference semantics).

Two executors over the shared op semantics of :mod:`repro.core.exec.ops`:

- :class:`ReferenceExec` — private buffer per tensor (ground truth);
- :class:`ArenaExec`     — all intermediates live inside ONE flat *byte*
  arena at the offsets chosen by a :class:`~repro.core.planner.Plan`, each op
  processing its output *row by row in ascending index order* (reads of a row
  happen no later, and writes no earlier, than the reference element order —
  so a plan safe for the element order is safe here). Tensors are typed
  views into the byte arena — int8 ops read/write i8 views at byte offsets,
  f32 ops f32 views — so mixed-dtype plans execute in the one buffer.

Both executors are dtype-aware: ops whose output storage is int8 run the
quantised tier (int32 accumulation + per-tensor scale/zero-point
requantisation) when a :class:`~repro.core.exec.ops.QuantSpec` is supplied;
f32 ops always run the float32 reference semantics.

:class:`NumpyExecutor` wraps the pair behind the
:class:`~repro.core.exec.ArenaExecutor` protocol; :func:`verify_plan` runs
an arena backend against the private-buffer reference and asserts equality
(bit-exact for numpy, tolerance for backends whose accumulation order XLA
may reassociate — fp32 atol for float graphs, <= 1 LSB for int8). If the
plan overlapped any buffer unsafely, the arena execution clobbers a live
value and the comparison fails — the open-source-tool verification described
in the paper's §I.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.exec import ops as X
from repro.core.graph import Graph, Op, Tensor
from repro.core.planner import Plan


class _Exec:
    """Shared op evaluation; subclasses define tensor load/store."""

    def __init__(self, graph: Graph, seed: int = 0,
                 weights: Optional[Dict[int, Dict[str, np.ndarray]]] = None,
                 quant: Optional[X.QuantSpec] = None):
        self.graph = graph
        self.weights = weights if weights is not None else X.synth_weights(
            graph, seed)
        #: Quantisation spec; None runs every op on the f32 tier (which is
        #: exactly what calibration needs on an int8-annotated graph).
        self.quant = quant

    def load(self, t: Tensor) -> np.ndarray:
        raise NotImplementedError

    def store(self, t: Tensor, v: np.ndarray) -> None:
        raise NotImplementedError

    def load_image(self, t: Tensor, b: int) -> np.ndarray:
        """Per-image value: image ``b`` of a batched tensor, or the whole
        value of a batch-1 tensor (weights and operands shared across the
        batch)."""
        v = self.load(t)
        return v[b] if t.storage().batch > 1 else v

    def store_image(self, t: Tensor, v: np.ndarray, b: int) -> None:
        raise NotImplementedError

    def store_rows(self, op: Op, rows, b: int = 0) -> None:
        """Default: materialise and store whole image (reference executor)."""
        out = np.stack([r for r in rows], axis=0)
        self.store_image(op.output, out.reshape(op.output.shape), b)

    def run(self, order: Optional[List[Op]] = None) -> None:
        for op in (order or self.graph.ops):
            self.execute(op)

    def _filter(self, op: Op, q) -> Optional[np.ndarray]:
        """The op's weight tensor on the active tier (int8 when quantised)."""
        if q is not None and id(op) in self.quant.weights_q:
            return self.quant.weights_q[id(op)]["filter"]
        return self.weights[id(op)].get("filter")

    def execute(self, op: Op) -> None:
        if op.kind == "reshape":
            return  # aliasing no-op
        # batched ops execute image by image in ASCENDING order — the order
        # the batched O_s (planner.batched_os_bytes) is derived against:
        # image b's writes land before image b+1's reads
        for b in range(op.output.storage().batch):
            self.execute_image(op, b)

    def execute_image(self, op: Op, b: int) -> None:
        q = X.op_quant(op, self.quant)
        if op.kind in ("conv2d", "depthwise_conv2d"):
            x = self.load_image(op.inputs[0], b).reshape(op.inputs[0].shape)
            x3 = x.reshape(x.shape[-3:])
            filt = self._filter(op, q)
            oh = op.output.shape[-3]
            self.store_rows(op, (X.conv_row(op, x3, filt, oy, q)
                                 for oy in range(oh)), b)
        elif op.kind == "pool":
            x3 = self.load_image(op.inputs[0], b).reshape(
                op.inputs[0].shape[-3:])
            oh = op.output.shape[-3]
            self.store_rows(op, (X.pool_row(op, x3, oy, q)
                                 for oy in range(oh)), b)
        else:
            xs = [self.load_image(t, b).reshape(t.shape) for t in op.inputs
                  if t.storage().kind != "weight"]
            self.store_image(op.output, X.eval_op(op, xs,
                                                  self._filter(op, q), q), b)


class ReferenceExec(_Exec):
    def __init__(self, graph: Graph, inputs: Dict[str, np.ndarray],
                 seed: int = 0, weights=None, quant=None):
        super().__init__(graph, seed, weights, quant)
        self.vals: Dict[Tensor, np.ndarray] = {}
        for t in graph.tensors:
            if t.kind == "input":
                v = np.asarray(inputs[t.name])
                # int8 inputs stay int8 (quantised execution); everything
                # else is the f32 tier (including calibration runs, which
                # feed float inputs to an int8-annotated graph)
                self.vals[t.storage()] = v if v.dtype == np.int8 \
                    else v.astype(np.float32)

    def load(self, t: Tensor) -> np.ndarray:
        return self.vals[t.storage()]

    def store(self, t: Tensor, v: np.ndarray) -> None:
        self.vals[t.storage()] = v.reshape(X.tensor_shape(t))

    def store_image(self, t: Tensor, v: np.ndarray, b: int) -> None:
        s = t.storage()
        if s.batch == 1:
            self.store(t, v)
            return
        buf = self.vals.get(s)
        if buf is None:
            buf = self.vals[s] = np.zeros((s.batch,) + tuple(t.shape),
                                          v.dtype)
        buf[b] = v.reshape(t.shape)


class ArenaExec(_Exec):
    """Executes inside a single flat byte arena at planned offsets.

    Conv/pool outputs are written row-by-row (ascending), loads re-read the
    arena for every row — faithfully modelling the MCU execution order that
    DMO's O_s guarantees safe. Each tensor is a dtype view
    (:func:`~repro.core.exec.ops.arena_dtype`) into the byte buffer at its
    planned byte offset, which the planner keeps ``dtype_bytes``-aligned.
    """

    def __init__(self, graph: Graph, plan: Plan,
                 inputs: Dict[str, np.ndarray], seed: int = 0, weights=None,
                 quant=None):
        super().__init__(graph, seed, weights, quant)
        if quant is None and X.needs_quant(graph):
            # without a QuantSpec every op would run the f32 tier and its
            # store would silently truncate floats into the int8 views —
            # fail loudly instead (NumpyExecutor.execute auto-calibrates)
            raise ValueError(
                f"{graph.name!r} has int8 arena tensors: arena execution "
                "requires a QuantSpec (see repro.core.exec.ops.calibrate)")
        self.plan = plan
        self.arena = np.zeros(plan.peak_bytes, np.uint8)
        #: Fused-chain scratch tensors have no arena placement: the numpy
        #: reference keeps them in private side buffers (the VMEM-scratch
        #: analogue), so fused graphs execute with identical op semantics.
        self.scratch: Dict[Tensor, np.ndarray] = {}
        for t in graph.tensors:
            if t.kind == "input":
                self.store(t, np.asarray(inputs[t.name]))

    def _view(self, t: Tensor) -> np.ndarray:
        """Typed view of the tensor's storage bytes inside the arena (or of
        its private side buffer for fused-chain scratch tensors)."""
        s = t.storage()
        if s.kind == "scratch":
            buf = self.scratch.get(s)
            if buf is None:
                buf = self.scratch[s] = np.zeros(
                    s.elems, X.arena_dtype(s.dtype_bytes))
            return buf
        off = self.plan.offsets[s]
        assert off % s.dtype_bytes == 0, \
            f"{s.name}: byte offset {off} not {s.dtype_bytes}-byte aligned"
        return self.arena[off:off + s.nbytes].view(X.arena_dtype(s.dtype_bytes))

    def load(self, t: Tensor) -> np.ndarray:
        return self._view(t).copy().reshape(X.tensor_shape(t))

    def store(self, t: Tensor, v: np.ndarray) -> None:
        view = self._view(t)
        view[:] = np.asarray(v, dtype=view.dtype).reshape(-1)

    def store_image(self, t: Tensor, v: np.ndarray, b: int) -> None:
        s = t.storage()
        view = self._view(t)
        if s.batch > 1:
            n = s.image_elems
            view = view[b * n:(b + 1) * n]
        view[:] = np.asarray(v, dtype=view.dtype).reshape(-1)

    def store_rows(self, op: Op, rows, b: int = 0) -> None:
        out = op.output
        view = self._view(out)
        row_elems = out.image_elems // out.shape[-3]
        base = b * out.storage().image_elems
        for i, r in enumerate(rows):
            # NOTE: each row's inputs were loaded lazily by conv_row via the
            # generator *before* this store — but rows are produced one at a
            # time, so reads for row i+1 happen after the row-i store, exactly
            # the diagonal order.
            view[base + i * row_elems:base + (i + 1) * row_elems] = \
                r.reshape(-1)

    def execute_image(self, op: Op, b: int) -> None:
        # conv/pool must re-load input per row to see the live arena
        if op.kind in ("conv2d", "depthwise_conv2d", "pool"):
            q = X.op_quant(op, self.quant)
            x_t = op.inputs[0]
            filt = self._filter(op, q)
            oh = op.output.shape[-3]

            def rows():
                for oy in range(oh):
                    x3 = self.load_image(x_t, b).reshape(x_t.shape[-3:])
                    if op.kind == "pool":
                        yield X.pool_row(op, x3, oy, q)
                    else:
                        yield X.conv_row(op, x3, filt, oy, q)

            self.store_rows(op, rows(), b)
        else:
            super().execute_image(op, b)


# ---------------------------------------------------------------------------
# Module-level API (legacy names; repro.core.arena re-exports these)
# ---------------------------------------------------------------------------


def run_reference(graph: Graph, inputs: Dict[str, np.ndarray],
                  order: Optional[List[Op]] = None, seed: int = 0,
                  weights=None, quant=None) -> Dict[str, np.ndarray]:
    ex = ReferenceExec(graph, inputs, seed, weights, quant)
    ex.run(order)
    return {t.name: ex.vals[t.storage()]
            for t in graph.tensors if t.kind == "output"}


def run_in_arena(graph: Graph, plan: Plan, inputs: Dict[str, np.ndarray],
                 seed: int = 0, weights=None,
                 quant=None) -> Dict[str, np.ndarray]:
    ex = ArenaExec(graph, plan, inputs, seed, weights, quant)
    ex.run(plan.order)
    return {t.name: ex.load(t) for t in graph.tensors if t.kind == "output"}


class NumpyExecutor:
    """The ``numpy`` :class:`~repro.core.exec.ArenaExecutor` backend."""

    name = "numpy"

    def execute(self, plan_or_compiled, inputs=None, weights=None, *,
                seed: int = 0, quant=None) -> Dict[str, np.ndarray]:
        from repro.core.exec import unwrap_plan
        plan, graph = unwrap_plan(plan_or_compiled)
        reason = X.executability(graph)
        if reason is not None:
            # same gate as the pallas backend: strided views / unsupported-
            # dtype graphs / legacy (pad-less) split bands would execute
            # with silently wrong semantics rather than fail — refuse
            # loudly instead. Split row bands carrying explicit band pads
            # pass the gate and run as ordinary convs over band shapes.
            raise ValueError(
                f"numpy backend cannot execute {graph.name!r}: {reason}")
        if weights is None:
            weights = X.synth_weights(graph, seed)
        if quant is None and X.needs_quant(graph):
            quant = X.calibrate(graph, seed, weights)
        if inputs is None:
            inputs = (X.quant_inputs(graph, quant, seed) if quant is not None
                      else X.random_inputs(graph, seed))
        return run_in_arena(graph, plan, inputs, seed, weights, quant)


def verify_plan(graph: Graph, plan: Plan, seed: int = 0,
                backend: str = "numpy") -> None:
    """Assert the planned arena execution matches private buffers: bit-exact
    for the numpy backend; tolerance for backends (pallas) whose dot
    accumulations XLA may reassociate (fp32 atol, or <= 1 LSB on int8
    outputs). Any unsafe overlap in the plan clobbers a live value and
    raises ``AssertionError``."""
    from repro.core.exec import compare_outputs, get_backend
    weights = X.synth_weights(graph, seed)
    quant = X.calibrate(graph, seed, weights) if X.needs_quant(graph) else None
    inputs = (X.quant_inputs(graph, quant, seed) if quant is not None
              else X.random_inputs(graph, seed))
    ref = run_reference(graph, inputs, plan.order, seed, weights, quant)
    got = get_backend(backend).execute(plan, inputs, weights, seed=seed,
                                       quant=quant)
    compare_outputs(ref, got, exact=(backend == "numpy"),
                    label=f"{backend} arena vs reference")
