"""Shared per-op row/tensor semantics for every arena executor backend.

This is the single place the repo defines *what an op computes* and *in which
element order* — the row-ascending reference semantics the paper's safe
overlap ``O_s`` is derived against (§III.A: reads of an output row's inputs
happen no later, and its write no earlier, than the reference element order).
Backends reuse these definitions rather than re-deriving them:

- the ``numpy`` backend (:mod:`repro.core.exec.numpy_backend`) calls
  :func:`conv_row` / :func:`pool_row` / :func:`eval_op` directly;
- the ``pallas`` backend (:mod:`repro.core.exec.pallas_backend`) mirrors the
  same loop nests in its kernels (:mod:`repro.kernels.arena_ops`) and is
  cross-checked against the numpy backend by the pipeline's verify pass.

Every kernel is **dtype-parameterised**: with ``q=None`` it runs the f32
reference semantics; with an :class:`OpQuant` context it runs the quantised
tier — int8 storage, int32 accumulation, per-tensor scale/zero-point
requantisation (TFLite-micro affine convention: asymmetric int8 activations,
symmetric int8 weights). The requantisation arithmetic is float32 end to end
(:func:`requantise`), formula-for-formula identical to the jnp mirrors in
:mod:`repro.kernels.arena_ops`, so the two backends agree to <= 1 LSB.

Weight synthesis lives here too, so all backends execute the same network:
weights are deterministic per (graph, seed) and keyed by op identity.
Quantisation parameters come from :func:`calibrate` — a float reference run
records per-tensor ranges, exactly the post-training calibration step of the
paper's 8-bit TFLite models.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph, Op, Tensor, band_range, op_pads

#: Op kinds every arena executor implements. An op kind outside this set
#: cannot be executed (and therefore not numerically verified or lowered).
SUPPORTED_KINDS = frozenset({
    "conv2d", "depthwise_conv2d", "pool", "elementwise", "softmax",
    "fully_connected", "matmul", "concat", "pad", "mean", "reshape",
})

#: Arena dtype widths the executor backends implement, mapped to the numpy
#: dtype a byte-arena view uses. (f16 plans are plannable but not executable.)
SUPPORTED_DTYPES: Dict[int, np.dtype] = {
    1: np.dtype(np.int8),
    4: np.dtype(np.float32),
}

#: Elementwise function table shared by all backends (numpy ufunc semantics;
#: the pallas backend maps these 1:1 onto jnp equivalents).
ELEMENTWISE = {
    "relu": lambda a: np.maximum(a, 0.0),
    "relu6": lambda a: np.clip(a, 0.0, 6.0),
    "sigmoid": lambda a: 1.0 / (1.0 + np.exp(-a)),
    "identity": lambda a: a,
    "add": lambda a, b: a + b,
    "mul": lambda a, b: a * b,
    "sub": lambda a, b: a - b,
}


def arena_dtype(dtype_bytes: int) -> np.dtype:
    """Numpy dtype a byte-arena view uses for a tensor of this width."""
    return SUPPORTED_DTYPES[dtype_bytes]


def weights_for(op: Op, rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """Deterministic random weights per op (same for every backend),
    fan-in-scaled (He style) so activation magnitudes stay O(1) through
    arbitrarily deep graphs — unscaled gaussians blow up to ~1e16 after ~30
    conv layers, which destroys f32 precision and makes post-training
    calibration (and therefore the int8 tier) degenerate."""
    w: Dict[str, np.ndarray] = {}
    if op.kind == "conv2d":
        kh, kw = op.params["kernel"]
        ic = op.inputs[0].shape[-1]
        oc = op.output.shape[-1]
        w["filter"] = (rng.standard_normal((kh, kw, ic, oc))
                       / np.sqrt(kh * kw * ic)).astype(np.float32)
    elif op.kind == "depthwise_conv2d":
        kh, kw = op.params["kernel"]
        ic = op.inputs[0].shape[-1]
        kc = op.params.get("multiplier", 1)
        w["filter"] = (rng.standard_normal((kh, kw, ic, kc))
                       / np.sqrt(kh * kw)).astype(np.float32)
    elif op.kind == "fully_connected":
        idim = op.inputs[0].shape[-1]
        od = op.output.shape[-1]
        w["filter"] = (rng.standard_normal((idim, od))
                       / np.sqrt(idim)).astype(np.float32)
    return w


def synth_weights(graph: Graph, seed: int = 0) -> Dict[int, Dict[str, np.ndarray]]:
    """All weights of a graph, keyed by ``id(op)``. The rng is consumed in
    op order, so every backend handed the same (graph, seed) pair executes
    the identical network.

    Split row bands (ops carrying ``split_src``) share ONE draw per source
    op: every band of a split conv convolves the same filter — and since a
    band's filter has the source op's shape, the split graph's rng stream
    stays position-for-position aligned with its unsplit reference, so the
    two graphs execute the identical network (the property the split-vs-
    unsplit verification tier rests on)."""
    rng = np.random.default_rng(seed)
    out: Dict[int, Dict[str, np.ndarray]] = {}
    groups: Dict[str, Dict[str, np.ndarray]] = {}
    for op in graph.ops:
        src = op.params.get("split_src")
        if src is not None and src in groups:
            out[id(op)] = groups[src]
            continue
        w = weights_for(op, rng)
        out[id(op)] = w
        if src is not None:
            groups[src] = w
    return out


def tensor_shape(t: Tensor) -> Tuple[int, ...]:
    """Runtime array shape of a tensor's value: the per-image shape with the
    batch axis prepended when the tensor is batched. Backends execute batched
    tensors image by image (the per-image kernels above never see the batch
    axis), so this is the only place the value shape and the plan shape
    diverge."""
    return ((t.batch,) + tuple(t.shape)) if t.batch > 1 else tuple(t.shape)


def random_inputs(graph: Graph, seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic random model inputs (float32), keyed by tensor name.
    These are the *real-valued* inputs; int8 graphs quantise them through
    :func:`quant_inputs` after calibration. Batched inputs draw
    ``(batch,) + shape`` from the same rng stream, so image 0 of a batched
    input is bit-identical to the batch-1 input at the same seed."""
    rng = np.random.default_rng(seed + 1)
    return {
        t.name: rng.standard_normal(tensor_shape(t)).astype(np.float32)
        for t in graph.tensors if t.kind == "input"
    }


# ---------------------------------------------------------------------------
# Quantisation (the paper's 8-bit TFLite-micro tier)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QParams:
    """Per-tensor affine quantisation: ``real = (q - zero_point) * scale``."""
    scale: float
    zero_point: int


@dataclasses.dataclass
class QuantSpec:
    """Quantisation of one (graph, seed, weights) triple: per-tensor
    activation params plus symmetric int8 weights per weighted op. Built by
    :func:`calibrate`; shared by every backend so they execute the identical
    quantised network."""
    tensors: Dict[str, QParams]                  # storage tensor name -> params
    weight_scale: Dict[int, float]               # id(op) -> weight scale
    weights_q: Dict[int, Dict[str, np.ndarray]]  # id(op) -> int8 weights


@dataclasses.dataclass(frozen=True)
class OpQuant:
    """Per-op quantised execution context: params of each arena input, of the
    output, and the (symmetric) weight scale for weighted kinds."""
    ins: Tuple[QParams, ...]
    out: QParams
    wscale: float = 0.0


def needs_quant(graph: Graph) -> bool:
    """True when any data tensor (arena or fused-chain scratch) is int8 —
    execution then requires a :class:`QuantSpec`."""
    return any(t.dtype_bytes == 1 for t in graph.data_tensors())


def quantise(x: np.ndarray, qp: QParams) -> np.ndarray:
    """f32 -> int8 at the tensor's affine params (round half-to-even, the
    convention both numpy and jnp share)."""
    q = np.round(x.astype(np.float32) / np.float32(qp.scale)) + qp.zero_point
    return np.clip(q, -128, 127).astype(np.int8)


def dequantise(q: np.ndarray, qp: QParams) -> np.ndarray:
    """int8 -> f32 at the tensor's affine params."""
    return (q.astype(np.float32) - np.float32(qp.zero_point)) \
        * np.float32(qp.scale)


def requantise(acc: np.ndarray, mult: float, zp: int) -> np.ndarray:
    """int32 accumulator (or f32 partial) -> int8 output: scale by the f32
    multiplier, round, re-centre on the output zero point, saturate. The jnp
    kernels implement this formula operation-for-operation, so backend
    outputs agree to the last rounding ulp."""
    q = np.round(acc.astype(np.float32) * np.float32(mult)) + zp
    return np.clip(q, -128, 127).astype(np.int8)


def rescale_q(x: np.ndarray, src: QParams, dst: QParams) -> np.ndarray:
    """int8 -> int8 between two affine params (concat/pad input alignment)."""
    mult = f32_div(src.scale, dst.scale)
    return requantise(x.astype(np.int32) - src.zero_point, mult,
                      dst.zero_point)


def f32_div(a: float, b: float) -> float:
    """a / b evaluated in float32 — the shared multiplier precision, so both
    backends bake the bit-identical constant into their requantisation."""
    return float(np.float32(np.float32(a) / np.float32(b)))


def acc_multiplier(op: Op, q: OpQuant) -> float:
    """The requantisation multiplier of an int32-accumulating op, evaluated
    in float32: ``s_x * s_w / s_y`` for conv/depthwise/fully_connected,
    ``s_a * s_b / s_y`` for matmul, ``s_x / s_y`` for pool/mean."""
    if op.kind in ("conv2d", "depthwise_conv2d", "fully_connected"):
        num = np.float32(np.float32(q.ins[0].scale) * np.float32(q.wscale))
    elif op.kind == "matmul":
        num = np.float32(np.float32(q.ins[0].scale) * np.float32(q.ins[1].scale))
    else:  # pool / mean: storage passthrough scale
        num = np.float32(q.ins[0].scale)
    return float(np.float32(num / np.float32(q.out.scale)))


def calibrate(graph: Graph, seed: int = 0,
              weights: Optional[Dict[int, Dict[str, np.ndarray]]] = None,
              ) -> QuantSpec:
    """Post-training calibration: run the float32 reference once, record each
    arena tensor's observed range (forced to include 0, the TFLite
    convention), and derive asymmetric int8 activation params plus symmetric
    int8 weights (zero_point 0, -128 reserved).

    Band pieces of one split op (``split_src`` provenance) pool their ranges
    into one group, so every band quantises at the params the *unsplit*
    tensor would calibrate to — the bands jointly observe exactly the
    reference tensor's values, and the shared params make a split graph's
    int8 execution elementwise-identical to its unsplit reference (the
    concat realigning the bands becomes a lossless identity rescale)."""
    from repro.core.exec.numpy_backend import ReferenceExec  # lazy: no cycle
    if weights is None:
        weights = synth_weights(graph, seed)
    ex = ReferenceExec(graph, random_inputs(graph, seed), seed, weights)
    ex.run()
    group_of: Dict[Tensor, str] = {}
    for op in graph.ops:
        src = op.params.get("split_src")
        if src is not None:
            group_of[op.output.storage()] = src
    ranges: Dict[str, Tuple[float, float]] = {}
    # data_tensors, not arena_tensors: fused-chain scratch tensors never
    # occupy the arena but still need activation params (the fused kernel
    # requantises every stage exactly like the unfused execution)
    for t in graph.data_tensors():
        v = ex.vals.get(t)
        lo = float(min(0.0, v.min())) if v is not None and v.size else -1.0
        hi = float(max(0.0, v.max())) if v is not None and v.size else 1.0
        key = group_of.get(t, t.name)
        if key in ranges:
            lo, hi = min(lo, ranges[key][0]), max(hi, ranges[key][1])
        ranges[key] = (lo, hi)
    tensors: Dict[str, QParams] = {}
    for t in graph.data_tensors():
        lo, hi = ranges[group_of.get(t, t.name)]
        scale = (hi - lo) / 255.0 or 1.0
        zp = int(np.clip(round(-128.0 - lo / scale), -128, 127))
        tensors[t.name] = QParams(scale, zp)
    wscale: Dict[int, float] = {}
    wq: Dict[int, Dict[str, np.ndarray]] = {}
    for op in graph.ops:
        w = weights.get(id(op), {})
        if "filter" in w and op.output.storage().dtype_bytes == 1:
            s = (float(np.abs(w["filter"]).max()) / 127.0) or 1.0
            wscale[id(op)] = s
            wq[id(op)] = {"filter": np.clip(
                np.round(w["filter"] / np.float32(s)), -127, 127
            ).astype(np.int8)}
    return QuantSpec(tensors, wscale, wq)


def quant_inputs(graph: Graph, spec: QuantSpec,
                 seed: int = 0) -> Dict[str, np.ndarray]:
    """The deterministic model inputs of :func:`random_inputs`, with int8
    input tensors quantised at their calibrated params."""
    floats = random_inputs(graph, seed)
    return {
        t.name: (quantise(floats[t.name], spec.tensors[t.name])
                 if t.dtype_bytes == 1 else floats[t.name])
        for t in graph.tensors if t.kind == "input"
    }


def op_quant(op: Op, spec: Optional[QuantSpec]) -> Optional[OpQuant]:
    """Quantised execution context for one op, or ``None`` when the op runs
    the f32 tier (f32 output, or no spec at all)."""
    if spec is None or op.output.storage().dtype_bytes != 1:
        return None
    ins = tuple(spec.tensors[t.storage().name] for t in op.inputs
                if t.storage().kind != "weight")
    return OpQuant(ins, spec.tensors[op.output.storage().name],
                   spec.weight_scale.get(id(op), 0.0))


def pads(op: Op) -> Tuple[int, int]:
    """Leading (ph, pw) pad of a conv/pool op (TF SAME convention). Split
    row bands substitute their explicit per-band pads — see
    :func:`repro.core.graph.op_pads` — which is all the row kernels below
    need to run a band as an ordinary conv over its band shapes."""
    return op_pads(op)


# ---------------------------------------------------------------------------
# Row kernels (conv/pool walk output rows in ascending index order)
# ---------------------------------------------------------------------------


def conv_row(op: Op, x: np.ndarray, filt: np.ndarray, oy: int,
             q: Optional[OpQuant] = None) -> np.ndarray:
    """One output row of conv2d/depthwise (x is HWC). f32 path with
    ``q=None``; int8 path accumulates ``(x - x_zp) * w`` in int32 and
    requantises with the float32 multiplier."""
    ih, iw, ic = x.shape
    oh, ow = op.output.shape[-3], op.output.shape[-2]
    kh, kw = op.params["kernel"]
    sh, sw = op.params.get("stride", (1, 1))
    dh, dw = op.params.get("dilation", (1, 1))
    ph, pw = pads(op)
    kc = op.params.get("multiplier", 1)
    oc = op.output.shape[-1] if op.kind == "conv2d" else ic * kc
    if q is not None:
        acc = np.zeros((ow, oc), np.int32)
        x_zp = q.ins[0].zero_point
    else:
        acc = np.zeros((ow, oc), np.float32)
    for fy in range(kh):
        iy = oy * sh - ph + fy * dh
        if not 0 <= iy < ih:
            continue
        row = x[iy]                                           # (iw, ic)
        if q is not None:
            row = row.astype(np.int32) - x_zp
        for fx in range(kw):
            ixs = np.arange(ow) * sw - pw + fx * dw
            valid = (ixs >= 0) & (ixs < iw)
            src = row[np.clip(ixs, 0, iw - 1), :]             # (ow, ic)
            src = np.where(valid[:, None], src, 0 if q is not None else 0.0)
            w = filt[fy, fx]
            if op.kind == "conv2d":
                acc += src @ (w.astype(np.int32) if q is not None else w)
            else:
                w = w.astype(np.int32) if q is not None else w
                acc += (src[:, :, None] * w[None, :, :]).reshape(ow, ic * kc)
    if q is not None:
        return requantise(acc, acc_multiplier(op, q), q.out.zero_point)
    return acc


def pool_row(op: Op, x: np.ndarray, oy: int,
             q: Optional[OpQuant] = None) -> np.ndarray:
    ih, iw, c = x.shape
    ow = op.output.shape[-2]
    kh, kw = op.params["kernel"]
    sh, sw = op.params.get("stride", (1, 1))
    ph, pw = pads(op)
    mode = op.params.get("mode", "avg")
    if q is not None:
        acc = np.full((ow, c), -2147483647 if mode == "max" else 0, np.int32)
    else:
        acc = np.full((ow, c), -np.inf if mode == "max" else 0.0, np.float32)
    cnt = np.zeros((ow, 1), np.float32)
    for fy in range(kh):
        iy = oy * sh - ph + fy
        if not 0 <= iy < ih:
            continue
        for fx in range(kw):
            ixs = np.arange(ow) * sw - pw + fx
            valid = (ixs >= 0) & (ixs < iw)
            src = x[iy, np.clip(ixs, 0, iw - 1), :]
            if q is not None:
                src = src.astype(np.int32)
            if mode == "max":
                acc = np.where(valid[:, None], np.maximum(acc, src), acc)
            else:
                acc += np.where(valid[:, None], src,
                                0 if q is not None else 0.0)
                cnt += valid[:, None].astype(np.float32)
    if q is not None:
        x_zp, mult = q.ins[0].zero_point, acc_multiplier(op, q)
        if mode == "avg":
            val = acc.astype(np.float32) / np.maximum(cnt, 1.0) - x_zp
        else:
            val = acc - x_zp
        return requantise(val, mult, q.out.zero_point)
    if mode == "avg":
        acc = acc / np.maximum(cnt, 1.0)
    return acc


# ---------------------------------------------------------------------------
# Whole-tensor kernels (the non-row op kinds), dtype-parameterised
# ---------------------------------------------------------------------------


def eval_op(op: Op, xs: Sequence[np.ndarray],
            filt: Optional[np.ndarray] = None,
            q: Optional[OpQuant] = None) -> np.ndarray:
    """Evaluate a non-row op on already-loaded arena inputs ``xs`` (weight
    inputs excluded, op order preserved). ``filt`` is the synthesized weight
    where the kind takes one (int8 when ``q`` is set). Returns the output
    tensor value in the op's storage dtype."""
    k = op.kind
    if k == "elementwise":
        fn = ELEMENTWISE[op.params.get("fn", "relu")]
        if q is not None:
            xs = [dequantise(x, qp) for x, qp in zip(xs, q.ins)]
        xs = list(xs)
        if len(xs) == 2 and xs[1].size != xs[0].size:
            xs[1] = np.broadcast_to(xs[1], xs[0].shape)
        if q is not None:
            return quantise(fn(*xs).astype(np.float32), q.out)
        return fn(*xs).astype(np.float32)
    if k == "softmax":
        x = dequantise(xs[0], q.ins[0]) if q is not None else xs[0]
        e = np.exp(x - x.max(axis=-1, keepdims=True))
        y = (e / e.sum(axis=-1, keepdims=True)).astype(np.float32)
        return quantise(y, q.out) if q is not None else y
    if k == "fully_connected":
        x = xs[0].reshape(-1, op.inputs[0].shape[-1])
        if q is not None:
            acc = (x.astype(np.int32) - q.ins[0].zero_point) \
                @ filt.astype(np.int32)
            return requantise(acc, acc_multiplier(op, q),
                              q.out.zero_point).reshape(op.output.shape)
        return (x @ filt).reshape(op.output.shape).astype(np.float32)
    if k == "matmul":
        a = xs[0].reshape(-1, op.inputs[0].shape[-1])
        b = xs[1].reshape(op.inputs[1].shape)
        if q is not None:
            acc = (a.astype(np.int32) - q.ins[0].zero_point) \
                @ (b.astype(np.int32) - q.ins[1].zero_point)
            return requantise(acc, acc_multiplier(op, q),
                              q.out.zero_point).reshape(op.output.shape)
        return (a @ b).reshape(op.output.shape).astype(np.float32)
    if k == "concat":
        axis = op.params.get("axis", -1)
        if q is not None:
            xs = [rescale_q(x, qp, q.out) for x, qp in zip(xs, q.ins)]
        return np.concatenate(list(xs), axis=axis)
    if k == "pad":
        if q is not None:
            padded = np.pad(xs[0], op.params["paddings"],
                            constant_values=q.ins[0].zero_point)
            return rescale_q(padded, q.ins[0], q.out)
        return np.pad(xs[0], op.params["paddings"])
    if k == "mean":
        x = xs[0]
        axes = tuple(op.params.get("axes", range(x.ndim - 1)))
        if q is not None:
            cnt = 1
            for ax in axes:
                cnt *= x.shape[ax]
            acc = x.astype(np.int32).sum(axis=axes)
            val = acc.astype(np.float32) / np.float32(cnt) \
                - q.ins[0].zero_point
            return requantise(val, acc_multiplier(op, q),
                              q.out.zero_point).reshape(op.output.shape)
        return x.mean(axis=axes).reshape(op.output.shape).astype(np.float32)
    raise NotImplementedError(f"arena executor: {k}")


# ---------------------------------------------------------------------------
# Lowering gates
# ---------------------------------------------------------------------------


def has_strided_views(graph: Graph) -> bool:
    """Non-trivial aliases (concat-removal views) whose element offsets a
    flat-arena executor cannot represent."""
    return any(t.alias_of is not None and t.elems != t.storage().elems
               for t in graph.tensors)


def executability(graph: Graph) -> Optional[str]:
    """None when every arena backend can execute ``graph``; else a
    human-readable ``"; "``-joined list of *all* refusal reasons (so a mixed
    int8 + split-band graph reports both problems at once, not just the first
    the walk happens to meet)."""
    reasons: List[str] = []

    def add(r: str) -> None:
        if r not in reasons:
            reasons.append(r)

    for op in graph.ops:
        if op.kind not in SUPPORTED_KINDS:
            add(f"unsupported op kind {op.kind!r}")
        rr = band_range(op)
        if rr is not None:
            # a band op executes as an ordinary conv over its band shapes
            # *iff* it carries the explicit band-local pads; legacy split
            # graphs (pre-band_pad) would execute with silently wrong
            # geometry, so they stay refused
            if op.kind not in ("conv2d", "depthwise_conv2d", "pool"):
                add(f"split row bands on non-row-streaming op {op.name} "
                    f"({op.kind!r})")
            elif "band_pad" not in op.params:
                add(f"split row bands without explicit band pads "
                    f"(op {op.name}: legacy split graph)")
            elif rr[1] - rr[0] != op.output.shape[-3]:
                add(f"split row bands: op {op.name} row_range {rr} "
                    f"disagrees with its {op.output.shape[-3]} output rows")
        if op.kind == "elementwise" and \
                op.params.get("fn", "relu") not in ELEMENTWISE:
            add(f"unknown elementwise fn {op.params.get('fn')!r}")
        for t in op.inputs:
            if t.storage().kind == "weight":
                add(f"op {op.name} reads a non-arena (weight) tensor")
        if op.kind != "reshape":
            widths = {t.storage().dtype_bytes for t in op.inputs
                      if t.storage().kind != "weight"}
            widths.add(op.output.storage().dtype_bytes)
            if len(widths) > 1:
                add(f"op {op.name} mixes arena dtypes "
                    f"{sorted(widths)} (no cast ops)")
    for t in graph.data_tensors():
        if t.dtype_bytes not in SUPPORTED_DTYPES:
            add(f"unsupported arena dtype ({t.dtype_bytes}-byte tensor "
                f"{t.name})")
            break
    if has_strided_views(graph):
        add("aggregated views (strided offsets)")
    return "; ".join(reasons) if reasons else None


def executable(graph: Graph) -> bool:
    return executability(graph) is None
