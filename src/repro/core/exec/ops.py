"""Shared per-op row/tensor semantics for every arena executor backend.

This is the single place the repo defines *what an op computes* and *in which
element order* — the row-ascending reference semantics the paper's safe
overlap ``O_s`` is derived against (§III.A: reads of an output row's inputs
happen no later, and its write no earlier, than the reference element order).
Backends reuse these definitions rather than re-deriving them:

- the ``numpy`` backend (:mod:`repro.core.exec.numpy_backend`) calls
  :func:`conv_row` / :func:`pool_row` / :func:`eval_op` directly;
- the ``pallas`` backend (:mod:`repro.core.exec.pallas_backend`) mirrors the
  same loop nests in its kernels (:mod:`repro.kernels.arena_ops`) and is
  cross-checked against the numpy backend by the pipeline's verify pass.

Weight synthesis lives here too, so all backends execute the same network:
weights are deterministic per (graph, seed) and keyed by op identity.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.graph import Graph, Op, Tensor, pad_amount

#: Op kinds every arena executor implements. An op kind outside this set
#: cannot be executed (and therefore not numerically verified or lowered).
SUPPORTED_KINDS = frozenset({
    "conv2d", "depthwise_conv2d", "pool", "elementwise", "softmax",
    "fully_connected", "matmul", "concat", "pad", "mean", "reshape",
})

#: Elementwise function table shared by all backends (numpy ufunc semantics;
#: the pallas backend maps these 1:1 onto jnp equivalents).
ELEMENTWISE = {
    "relu": lambda a: np.maximum(a, 0.0),
    "relu6": lambda a: np.clip(a, 0.0, 6.0),
    "sigmoid": lambda a: 1.0 / (1.0 + np.exp(-a)),
    "identity": lambda a: a,
    "add": lambda a, b: a + b,
    "mul": lambda a, b: a * b,
    "sub": lambda a, b: a - b,
}


def weights_for(op: Op, rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """Deterministic random weights per op (same for every backend)."""
    w: Dict[str, np.ndarray] = {}
    if op.kind == "conv2d":
        kh, kw = op.params["kernel"]
        ic = op.inputs[0].shape[-1]
        oc = op.output.shape[-1]
        w["filter"] = rng.standard_normal((kh, kw, ic, oc)).astype(np.float32)
    elif op.kind == "depthwise_conv2d":
        kh, kw = op.params["kernel"]
        ic = op.inputs[0].shape[-1]
        kc = op.params.get("multiplier", 1)
        w["filter"] = rng.standard_normal((kh, kw, ic, kc)).astype(np.float32)
    elif op.kind == "fully_connected":
        idim = op.inputs[0].shape[-1]
        od = op.output.shape[-1]
        w["filter"] = rng.standard_normal((idim, od)).astype(np.float32)
    return w


def synth_weights(graph: Graph, seed: int = 0) -> Dict[int, Dict[str, np.ndarray]]:
    """All weights of a graph, keyed by ``id(op)``. The rng is consumed in
    op order, so every backend handed the same (graph, seed) pair executes
    the identical network."""
    rng = np.random.default_rng(seed)
    return {id(op): weights_for(op, rng) for op in graph.ops}


def random_inputs(graph: Graph, seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic random model inputs (float32), keyed by tensor name."""
    rng = np.random.default_rng(seed + 1)
    return {
        t.name: rng.standard_normal(t.shape).astype(np.float32)
        for t in graph.tensors if t.kind == "input"
    }


def pads(op: Op) -> Tuple[int, int]:
    """Leading (ph, pw) pad of a conv/pool op (TF SAME convention)."""
    ih, iw = op.inputs[0].shape[-3], op.inputs[0].shape[-2]
    oh, ow = op.output.shape[-3], op.output.shape[-2]
    kh, kw = op.params["kernel"]
    sh, sw = op.params.get("stride", (1, 1))
    dh, dw = op.params.get("dilation", (1, 1))
    if op.params.get("padding", "same") == "same":
        return pad_amount(ih, oh, kh, sh, dh), pad_amount(iw, ow, kw, sw, dw)
    return 0, 0


def conv_row(op: Op, x: np.ndarray, filt: np.ndarray, oy: int) -> np.ndarray:
    """One output row of conv2d/depthwise (x is HWC)."""
    ih, iw, ic = x.shape
    oh, ow = op.output.shape[-3], op.output.shape[-2]
    kh, kw = op.params["kernel"]
    sh, sw = op.params.get("stride", (1, 1))
    dh, dw = op.params.get("dilation", (1, 1))
    ph, pw = pads(op)
    if op.kind == "conv2d":
        oc = op.output.shape[-1]
        row = np.zeros((ow, oc), np.float32)
    else:
        kc = op.params.get("multiplier", 1)
        row = np.zeros((ow, ic * kc), np.float32)
    for fy in range(kh):
        iy = oy * sh - ph + fy * dh
        if not 0 <= iy < ih:
            continue
        for fx in range(kw):
            ixs = np.arange(ow) * sw - pw + fx * dw
            valid = (ixs >= 0) & (ixs < iw)
            src = x[iy, np.clip(ixs, 0, iw - 1), :]          # (Ow, ic)
            src = np.where(valid[:, None], src, 0.0)
            if op.kind == "conv2d":
                row += src @ filt[fy, fx]                     # (Ow, oc)
            else:
                kc = op.params.get("multiplier", 1)
                contrib = src[:, :, None] * filt[fy, fx][None, :, :]
                row += contrib.reshape(ow, ic * kc)
    return row


def pool_row(op: Op, x: np.ndarray, oy: int) -> np.ndarray:
    ih, iw, c = x.shape
    ow = op.output.shape[-2]
    kh, kw = op.params["kernel"]
    sh, sw = op.params.get("stride", (1, 1))
    ph, pw = pads(op)
    mode = op.params.get("mode", "avg")
    acc = np.full((ow, c), -np.inf if mode == "max" else 0.0, np.float32)
    cnt = np.zeros((ow, 1), np.float32)
    for fy in range(kh):
        iy = oy * sh - ph + fy
        if not 0 <= iy < ih:
            continue
        for fx in range(kw):
            ixs = np.arange(ow) * sw - pw + fx
            valid = (ixs >= 0) & (ixs < iw)
            src = x[iy, np.clip(ixs, 0, iw - 1), :]
            if mode == "max":
                acc = np.where(valid[:, None], np.maximum(acc, src), acc)
            else:
                acc += np.where(valid[:, None], src, 0.0)
                cnt += valid[:, None].astype(np.float32)
    if mode == "avg":
        acc = acc / np.maximum(cnt, 1.0)
    return acc


# ---------------------------------------------------------------------------
# Lowering gates
# ---------------------------------------------------------------------------


def has_strided_views(graph: Graph) -> bool:
    """Non-trivial aliases (concat-removal views) whose element offsets a
    flat-arena executor cannot represent."""
    return any(t.alias_of is not None and t.elems != t.storage().elems
               for t in graph.tensors)


def executability(graph: Graph) -> Optional[str]:
    """None when every arena backend can execute ``graph``; else a short
    human-readable reason why not (used by lowering gates and error text)."""
    for op in graph.ops:
        if op.kind not in SUPPORTED_KINDS:
            return f"unsupported op kind {op.kind!r}"
        if "row_range" in op.params:
            return "split row bands"
        if op.kind == "elementwise" and op.params.get("fn", "relu") not in ELEMENTWISE:
            return f"unknown elementwise fn {op.params.get('fn')!r}"
        for t in op.inputs:
            if t.storage().kind == "weight":
                return f"op {op.name} reads a non-arena (weight) tensor"
    if has_strided_views(graph):
        return "aggregated views (strided offsets)"
    if any(t.dtype_bytes != 4 for t in graph.arena_tensors()):
        return "non-f32 arena tensors"
    return None


def executable(graph: Graph) -> bool:
    return executability(graph) is None
