"""Pallas arena executor: lower a plan to kernels over ONE donated buffer.

The lowering walks :meth:`Plan.op_layouts` and emits one
:class:`~repro.kernels.arena_ops.OpSpec` per op — the op kind plus the
dtype-carrying layout record the planner chose (*byte* offsets into the flat
arena plus each tensor's width), which is all a kernel needs to index the
shared buffer. The spec sequence jit-compiles to ``fn(arena, *weights)``
with the arena argument donated and every kernel aliasing its arena operand
(``input_output_aliases={0: 0}``), so the entire network executes inside one
flat *byte* buffer of exactly ``plan.peak_bytes`` — the planner's peak *is*
the runtime footprint, overlaps included.

The arena is uint8; kernels bitcast their windows to the tier the layout
declares — f32 ops read/write float32 views, int8 ops read/write i8 views
and run the quantised tier (int32 accumulation, per-tensor scale/zero-point
requantisation whose float32 multipliers are baked into the spec as static
``qmeta``), so mixed-dtype plans execute in the one buffer.

``interpret=True`` (default) runs on CPU CI; on an actual TPU the arena
would live in VMEM (the paper's SRAM analogue). Row loops are sequential
``fori_loop``s — see the §III.F multi-threading caveat in
:mod:`repro.kernels.arena_ops`.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.exec import ops as X
from repro.core.exec import unwrap_plan
from repro.core.graph import Op
from repro.core.planner import Plan


def _canon_meta(op: Op) -> Tuple:
    """Kind-specific static parameters for the kernel (see arena_ops)."""
    k = op.kind
    if k in ("conv2d", "depthwise_conv2d"):
        kh, kw = op.params["kernel"]
        sh, sw = op.params.get("stride", (1, 1))
        dh, dw = op.params.get("dilation", (1, 1))
        ph, pw = X.pads(op)
        return (kh, kw, sh, sw, dh, dw, ph, pw,
                op.params.get("multiplier", 1))
    if k == "pool":
        kh, kw = op.params["kernel"]
        sh, sw = op.params.get("stride", (1, 1))
        ph, pw = X.pads(op)
        return (kh, kw, sh, sw, ph, pw, op.params.get("mode", "avg"))
    if k == "elementwise":
        return (op.params.get("fn", "relu"),)
    if k == "concat":
        return (op.params.get("axis", -1),)
    if k == "pad":
        return (tuple(tuple(p) for p in op.params["paddings"]),)
    if k == "mean":
        x = op.inputs[0]
        return (tuple(op.params.get("axes", range(len(x.shape) - 1))),)
    return ()


def _canon_qmeta(op: Op, q: Optional[X.OpQuant]) -> Tuple:
    """Hashable quantisation statics per kind (zero points and the float32
    requantisation multipliers of :func:`repro.core.exec.ops.acc_multiplier`
    / :func:`~repro.core.exec.ops.rescale_q`, so both backends bake the
    bit-identical constants)."""
    if q is None:
        return ()
    k = op.kind
    if k in ("conv2d", "depthwise_conv2d", "fully_connected", "pool", "mean"):
        return (q.ins[0].zero_point, X.acc_multiplier(op, q),
                q.out.zero_point)
    if k == "matmul":
        return (q.ins[0].zero_point, q.ins[1].zero_point,
                X.acc_multiplier(op, q), q.out.zero_point)
    if k in ("elementwise", "softmax"):
        in_q = tuple((qp.scale, qp.zero_point) for qp in q.ins)
        out_q = (q.out.scale, q.out.zero_point)
        return (in_q[0], out_q) if k == "softmax" else (in_q, out_q)
    if k == "concat":
        in_q = tuple((qp.zero_point, X.f32_div(qp.scale, q.out.scale))
                     for qp in q.ins)
        return (in_q, (q.out.zero_point,))
    if k == "pad":
        return ((q.ins[0].zero_point,
                 X.f32_div(q.ins[0].scale, q.out.scale)),
                (q.out.zero_point,))
    return ()


class PallasExecutor:
    """The ``pallas`` :class:`~repro.core.exec.ArenaExecutor` backend."""

    name = "pallas"

    def __init__(self, interpret: bool = True):
        self.interpret = interpret

    def lower(self, plan: Plan,
              quant: Optional[X.QuantSpec] = None) -> Tuple:
        """Plan -> OpSpec sequence (static lowering, no weights bound).
        ``quant`` must be supplied for plans with int8 ops — its per-op
        contexts become the kernels' static ``qmeta``."""
        from repro.kernels.arena_ops import OpSpec
        specs: List[OpSpec] = []
        for lay in plan.op_layouts():
            op = lay.op
            assert all(l is not None for l in lay.inputs), \
                f"{op.name}: non-arena input cannot be lowered"
            q = X.op_quant(op, quant)
            specs.append(OpSpec(
                kind=op.kind,
                in_off=tuple(l.byte_offset for l in lay.inputs),
                in_shape=tuple(l.shape for l in lay.inputs),
                out_off=lay.output.byte_offset,
                out_shape=lay.output.shape,
                dtype="i8" if lay.output.dtype_bytes == 1 else "f32",
                meta=_canon_meta(op),
                qmeta=_canon_qmeta(op, q)))
        return tuple(specs)

    def execute(self, plan_or_compiled, inputs=None, weights=None, *,
                seed: int = 0, quant=None) -> Dict[str, np.ndarray]:
        import jax.numpy as jnp
        from repro.kernels import arena_ops

        plan, graph = unwrap_plan(plan_or_compiled)
        reason = X.executability(graph)
        if reason is not None:
            raise ValueError(
                f"pallas backend cannot lower {graph.name!r}: {reason}")
        if weights is None:
            weights = X.synth_weights(graph, seed)
        if quant is None and X.needs_quant(graph):
            quant = X.calibrate(graph, seed, weights)
        if inputs is None:
            inputs = (X.quant_inputs(graph, quant, seed) if quant is not None
                      else X.random_inputs(graph, seed))

        specs = self.lower(plan, quant)
        wflat = []
        for op in plan.order:
            if op.kind in arena_ops.WEIGHTED_KINDS:
                if quant is not None and id(op) in quant.weights_q:
                    wflat.append(jnp.asarray(quant.weights_q[id(op)]["filter"],
                                             jnp.int8))
                else:
                    wflat.append(jnp.asarray(weights[id(op)]["filter"],
                                             jnp.float32))

        arena = np.zeros(plan.peak_bytes, np.uint8)
        for t in graph.tensors:
            if t.kind == "input":
                s, off = t.storage(), plan.offsets[t.storage()]
                v = np.asarray(inputs[t.name],
                               X.arena_dtype(s.dtype_bytes)).reshape(-1)
                arena[off:off + s.nbytes] = v.view(np.uint8)

        fn = arena_ops.lower_program(specs, self.interpret)
        with warnings.catch_warnings():
            # CPU jit can't honour the donation and warns; the in-kernel
            # aliasing is what carries the single-buffer semantics there
            warnings.filterwarnings("ignore", message=".*donated.*")
            out_arena = np.asarray(fn(jnp.asarray(arena), *wflat))

        outs: Dict[str, np.ndarray] = {}
        for t in graph.tensors:
            if t.kind == "output":
                s, off = t.storage(), plan.offsets[t.storage()]
                outs[t.name] = out_arena[off:off + s.nbytes].view(
                    X.arena_dtype(s.dtype_bytes)).reshape(t.shape)
        return outs
