"""Pallas arena executor: lower a plan to kernels over ONE donated buffer.

The lowering walks :meth:`Plan.op_layouts` and emits one
:class:`~repro.kernels.arena_ops.OpSpec` per op — the op kind plus the
*element offsets* the planner chose, which is all a kernel needs to index the
flat arena. The spec sequence jit-compiles to ``fn(arena, *weights)`` with
the arena argument donated and every kernel aliasing its arena operand
(``input_output_aliases={0: 0}``), so the entire network executes inside one
flat f32 buffer of exactly ``plan.peak_bytes`` — the planner's peak *is* the
runtime footprint, overlaps included.

``interpret=True`` (default) runs on CPU CI; on an actual TPU the arena
would live in VMEM (the paper's SRAM analogue). Row loops are sequential
``fori_loop``s — see the §III.F multi-threading caveat in
:mod:`repro.kernels.arena_ops`.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Tuple

import numpy as np

from repro.core.exec import ops as X
from repro.core.exec import unwrap_plan
from repro.core.graph import Op
from repro.core.planner import Plan


def _canon_meta(op: Op) -> Tuple:
    """Kind-specific static parameters for the kernel (see arena_ops)."""
    k = op.kind
    if k in ("conv2d", "depthwise_conv2d"):
        kh, kw = op.params["kernel"]
        sh, sw = op.params.get("stride", (1, 1))
        dh, dw = op.params.get("dilation", (1, 1))
        ph, pw = X.pads(op)
        return (kh, kw, sh, sw, dh, dw, ph, pw,
                op.params.get("multiplier", 1))
    if k == "pool":
        kh, kw = op.params["kernel"]
        sh, sw = op.params.get("stride", (1, 1))
        ph, pw = X.pads(op)
        return (kh, kw, sh, sw, ph, pw, op.params.get("mode", "avg"))
    if k == "elementwise":
        return (op.params.get("fn", "relu"),)
    if k == "concat":
        return (op.params.get("axis", -1),)
    if k == "pad":
        return (tuple(tuple(p) for p in op.params["paddings"]),)
    if k == "mean":
        x = op.inputs[0]
        return (tuple(op.params.get("axes", range(len(x.shape) - 1))),)
    return ()


class PallasExecutor:
    """The ``pallas`` :class:`~repro.core.exec.ArenaExecutor` backend."""

    name = "pallas"

    def __init__(self, interpret: bool = True):
        self.interpret = interpret

    def lower(self, plan: Plan) -> Tuple:
        """Plan -> OpSpec sequence (static lowering, no weights bound)."""
        from repro.kernels.arena_ops import OpSpec
        specs: List[OpSpec] = []
        for op, in_offs, out_off in plan.op_layouts():
            assert all(o is not None for o in in_offs), \
                f"{op.name}: non-arena input cannot be lowered"
            specs.append(OpSpec(
                kind=op.kind,
                in_off=tuple(in_offs),
                in_shape=tuple(t.shape for t in op.inputs
                               if t.storage().kind != "weight"),
                out_off=out_off,
                out_shape=op.output.shape,
                meta=_canon_meta(op)))
        return tuple(specs)

    def execute(self, plan_or_compiled, inputs=None, weights=None, *,
                seed: int = 0) -> Dict[str, np.ndarray]:
        import jax.numpy as jnp
        from repro.kernels import arena_ops

        plan, graph = unwrap_plan(plan_or_compiled)
        reason = X.executability(graph)
        if reason is not None:
            raise ValueError(
                f"pallas backend cannot lower {graph.name!r}: {reason}")
        if inputs is None:
            inputs = X.random_inputs(graph, seed)
        if weights is None:
            weights = X.synth_weights(graph, seed)

        specs = self.lower(plan)
        wflat = []
        for op in plan.order:
            if op.kind in arena_ops.WEIGHTED_KINDS:
                wflat.append(jnp.asarray(weights[id(op)]["filter"],
                                         jnp.float32))

        assert plan.peak_bytes % 4 == 0
        arena = np.zeros(plan.peak_bytes // 4, np.float32)
        for t in graph.tensors:
            if t.kind == "input":
                s, off = t.storage(), plan.offsets[t.storage()] // 4
                arena[off:off + s.elems] = \
                    inputs[t.name].astype(np.float32).reshape(-1)

        fn = arena_ops.lower_program(specs, self.interpret)
        with warnings.catch_warnings():
            # CPU jit can't honour the donation and warns; the in-kernel
            # aliasing is what carries the single-buffer semantics there
            warnings.filterwarnings("ignore", message=".*donated.*")
            out_arena = np.asarray(fn(jnp.asarray(arena), *wflat))

        outs: Dict[str, np.ndarray] = {}
        for t in graph.tensors:
            if t.kind == "output":
                s, off = t.storage(), plan.offsets[t.storage()] // 4
                outs[t.name] = out_arena[off:off + s.elems].reshape(t.shape)
        return outs
