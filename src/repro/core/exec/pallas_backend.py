"""Pallas arena executor: lower a plan to kernels over ONE donated buffer.

Three arena programs share the backend (see :mod:`repro.kernels.arena_ops`):

- **row-blocked** (the default whenever the plan legalises): the plan is
  passed through :func:`repro.core.planner.legalise_for_blocks`, giving
  every tensor a ``(rows, rowlen)`` block at a sublane-tile-aligned row
  offset over one typed 2-D arena ((8, 128) f32 / (32, 128) int8 tiles).
  Kernels address whole arena rows via ``pl.dslice`` — no byte bitcasts —
  so the same program lowers under ``interpret=False``: this is the
  compiled-mode path, the TPU-VMEM realisation of the paper's SRAM arena.
  The whole arena is VMEM-resident, so VMEM caps ``total_rows``.
- **streaming** (``mode="streaming"``): the same row-blocked layouts, but
  the arena lives in ``pltpu.ANY`` (HBM) and each op DMAs only its *live
  window* (:meth:`repro.core.planner.BlockPlan.window_schedule`) into VMEM
  scratch with double-buffered ``make_async_copy``. The VMEM gate becomes
  the schedule's ``max_resident_bytes`` instead of the whole arena — the
  refactor that turns the ~16 MB arena ceiling into a window ceiling.
- **flat** (fallback, and the cross-check reference): the byte-granular
  program over a 1-D uint8 arena of exactly ``plan.peak_bytes``; kernels
  bitcast their windows to the tier each layout declares, so mixed-dtype
  plans execute in one buffer. Byte-granular dynamic slices fight the VMEM
  tilings, so this program is interpret-mode only.

Execution mode is ``mode="interpret"`` (CPU CI), ``mode="compiled"``
(``interpret=False`` lowering; requires row-blocked layouts and a backend
with a real Pallas lowering), or ``mode="streaming"`` (whose interpret-ness
follows the stack-wide switch unless ``interpret=`` is passed explicitly).
The default follows the stack-wide ``REPRO_DMO_INTERPRET`` switch
(:mod:`repro.kernels.runtime`), so one env var retargets the executor and
every standalone kernel together. The VMEM budget the compiled and
streaming gates check against is ``vmem_budget`` bytes (default: the
``REPRO_DMO_VMEM_BUDGET`` env var, else 16 MiB).

Split row bands lower like any conv/pool: ``_canon_meta`` takes the op's
geometry from the band-aware :func:`repro.core.exec.ops.pads`, so a band's
OpSpec carries its band shapes plus the explicit band-local pads (negative
leading row pad for producer bands) and the ordinary row kernels index
exactly the band's rows — in both the flat and the row-blocked program.

In either program the spec sequence jit-compiles to ``fn(arena, *weights)``
with the arena argument donated and every kernel aliasing its arena operand
(``input_output_aliases={0: 0}``), so the entire network executes inside one
buffer — the planner's peak (padded to whole rows in blocked mode) *is* the
runtime footprint, overlaps included. Row loops are sequential
``fori_loop``s — see the §III.F multi-threading caveat in
:mod:`repro.kernels.arena_ops`.
"""
from __future__ import annotations

import collections
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.exec import ops as X
from repro.core.exec import unwrap_plan
from repro.core.graph import Op
from repro.core.planner import (BlockPlan, Plan, chain_addr_of,
                                chain_image_rows_of, fused_slots,
                                legalise_for_blocks, tile_rows)


def _fused_chains(order: Sequence[Op]) -> Dict[str, List[Op]]:
    """Chain-name -> members (in order) for a fused graph's execution order,
    with the contiguity check the weight flattening relies on: a chain's
    members must be consecutive in the order so the fused spec (emitted at
    the first member's position) consumes consecutive stage weights from the
    flattened weight list."""
    chains: Dict[str, List[Op]] = {}
    pos: Dict[str, int] = {}
    for i, op in enumerate(order):
        cname = op.params.get("fuse_chain")
        if cname is None:
            continue
        if cname in pos:
            assert pos[cname] == i - 1, \
                f"fused chain {cname!r} is not contiguous in execution order"
        pos[cname] = i
        chains.setdefault(cname, []).append(op)
    return chains


def _addr_triple(lay) -> Tuple[int, int, int]:
    """A layout's packed-addressing spec triple
    ``(cols_per_row, row_span, image_rowlen)``."""
    return (lay.cols_per_row, lay.row_span, lay.image_rowlen)


def _canon_meta(op: Op) -> Tuple:
    """Kind-specific static parameters for the kernel (see arena_ops)."""
    k = op.kind
    if k in ("conv2d", "depthwise_conv2d"):
        kh, kw = op.params["kernel"]
        sh, sw = op.params.get("stride", (1, 1))
        dh, dw = op.params.get("dilation", (1, 1))
        ph, pw = X.pads(op)
        return (kh, kw, sh, sw, dh, dw, ph, pw,
                op.params.get("multiplier", 1))
    if k == "pool":
        kh, kw = op.params["kernel"]
        sh, sw = op.params.get("stride", (1, 1))
        ph, pw = X.pads(op)
        return (kh, kw, sh, sw, ph, pw, op.params.get("mode", "avg"))
    if k == "elementwise":
        return (op.params.get("fn", "relu"),)
    if k == "concat":
        return (op.params.get("axis", -1),)
    if k == "pad":
        return (tuple(tuple(p) for p in op.params["paddings"]),)
    if k == "mean":
        x = op.inputs[0]
        return (tuple(op.params.get("axes", range(len(x.shape) - 1))),)
    return ()


def _canon_qmeta(op: Op, q: Optional[X.OpQuant]) -> Tuple:
    """Hashable quantisation statics per kind (zero points and the float32
    requantisation multipliers of :func:`repro.core.exec.ops.acc_multiplier`
    / :func:`~repro.core.exec.ops.rescale_q`, so both backends bake the
    bit-identical constants)."""
    if q is None:
        return ()
    k = op.kind
    if k in ("conv2d", "depthwise_conv2d", "fully_connected", "pool", "mean"):
        return (q.ins[0].zero_point, X.acc_multiplier(op, q),
                q.out.zero_point)
    if k == "matmul":
        return (q.ins[0].zero_point, q.ins[1].zero_point,
                X.acc_multiplier(op, q), q.out.zero_point)
    if k in ("elementwise", "softmax"):
        in_q = tuple((qp.scale, qp.zero_point) for qp in q.ins)
        out_q = (q.out.scale, q.out.zero_point)
        return (in_q[0], out_q) if k == "softmax" else (in_q, out_q)
    if k == "concat":
        in_q = tuple((qp.zero_point, X.f32_div(qp.scale, q.out.scale))
                     for qp in q.ins)
        return (in_q, (q.out.zero_point,))
    if k == "pad":
        return ((q.ins[0].zero_point,
                 X.f32_div(q.ins[0].scale, q.out.scale)),
                (q.out.zero_point,))
    return ()


#: VMEM budget assumed when neither the constructor nor the
#: REPRO_DMO_VMEM_BUDGET env var names one (bytes; ~a TPU core's VMEM).
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024


class PallasExecutor:
    """The ``pallas`` :class:`~repro.core.exec.ArenaExecutor` backend.

    ``mode``: ``"interpret"`` (CPU-runnable, the default), ``"compiled"``
    (``interpret=False`` lowering), or ``"streaming"`` (ANY-space arena,
    live windows DMA'd into VMEM scratch; runs interpreted or compiled —
    pass ``interpret=`` to pin it, else the shared switch decides). ``None``
    defers to the shared ``REPRO_DMO_INTERPRET`` switch. ``layout``:
    ``"auto"`` runs the row-blocked program whenever the plan legalises
    (uniform dtype, no aggregated views) and falls back to the flat byte
    program otherwise; ``"blocks"`` / ``"flat"`` force one program. The
    legalisation itself prefers *packed* row layouts (planner
    ``packing="auto"``) and reverts to the legacy one-image-row-per-arena-
    row layout whenever packing fails to reduce the padded peak.
    Compiled and streaming modes require the row-blocked program — a flat
    byte arena cannot meet the VMEM tilings. ``vmem_budget`` (bytes) gates
    execution: compiled mode refuses arenas larger than it, streaming mode
    refuses only schedules whose ``max_resident_bytes`` exceeds it."""

    name = "pallas"

    def __init__(self, interpret: Optional[bool] = None,
                 mode: Optional[str] = None, layout: str = "auto",
                 vmem_budget: Optional[int] = None):
        if mode is not None and mode not in ("interpret", "compiled",
                                             "streaming"):
            raise ValueError(f"unknown pallas mode {mode!r} (expected "
                             "'interpret', 'compiled' or 'streaming')")
        if layout not in ("auto", "blocks", "flat"):
            raise ValueError(f"unknown pallas layout {layout!r} "
                             "(expected 'auto', 'blocks' or 'flat')")
        if mode is None and interpret is not None:
            mode = "interpret" if interpret else "compiled"
        #: None = follow the REPRO_DMO_INTERPRET env *per call*, so the
        #: default-constructed (registry-cached) instance retargets when
        #: the switch flips mid-process
        self._mode = mode
        self._interpret = interpret     # explicit pin (streaming mode only)
        self.layout = layout
        self.vmem_budget = vmem_budget
        #: Lowered-spec cache across execute() calls: (plan identity, route,
        #: quant identity) -> spec tuple. Values pin the plan/quant objects
        #: so the id() keys stay valid; bounded FIFO. Together with the
        #: content-addressed jit cache in arena_ops.lower_program this makes
        #: repeated executions of one compiled plan re-trace nothing.
        self._lowered: "collections.OrderedDict" = collections.OrderedDict()
        #: synth_weights/calibrate results per (plan identity, seed) — both
        #: are deterministic, so repeat executions skip calibration too.
        self._autoparams: "collections.OrderedDict" = collections.OrderedDict()
        self._cache_hits = 0
        self._cache_misses = 0
        self._check_mode_layout()

    def lowering_cache_info(self) -> Dict[str, int]:
        """Hit/miss counters of the per-executor lowering cache (tests and
        the trace exporter read this)."""
        return {"hits": self._cache_hits, "misses": self._cache_misses,
                "size": len(self._lowered)}

    @property
    def mode(self) -> str:
        if self._mode is not None:
            return self._mode
        from repro.kernels.runtime import default_interpret
        return "interpret" if default_interpret() else "compiled"

    @property
    def interpret(self) -> bool:
        mode = self.mode
        if mode == "streaming":
            if self._interpret is not None:
                return self._interpret
            from repro.kernels.runtime import default_interpret
            return default_interpret()
        return mode == "interpret"

    def _check_mode_layout(self) -> None:
        if self.mode in ("compiled", "streaming") and self.layout == "flat":
            raise ValueError(
                f"{self.mode} mode requires row-blocked layouts: the flat "
                "byte arena is interpret-only (byte-granular dynamic slices "
                "cannot meet the (8, 128)/(32, 128) VMEM tilings)")

    def _resolve_budget(self) -> int:
        if self.vmem_budget is not None:
            return int(self.vmem_budget)
        import os
        env = os.environ.get("REPRO_DMO_VMEM_BUDGET", "").strip()
        return int(env) if env else DEFAULT_VMEM_BUDGET

    # -- lowering -----------------------------------------------------------

    @staticmethod
    def _flat_off(plan: Plan, t, b: int) -> int:
        """Byte offset of image ``b`` of a flat-arena operand (batch-1
        operands — weights excluded earlier — are shared across images)."""
        s = t.storage()
        off = plan._layout(t).byte_offset
        return off + b * s.image_nbytes if s.batch > 1 else off

    def lower(self, plan: Plan,
              quant: Optional[X.QuantSpec] = None) -> Tuple:
        """Plan -> flat-program OpSpec sequence (static lowering, no weights
        bound): *byte* offsets per operand. ``quant`` must be supplied for
        plans with int8 ops — its per-op contexts become the kernels' static
        ``qmeta``. A fused band chain lowers to ONE spec (at its first
        member's position) whose stages carry byte offsets into the arena or
        — for scratch-flagged operands — into the chain's scratch buffer.
        Batched ops expand to one per-image spec each (image-minor order,
        ascending — the order the batched O_s is derived against), so the
        kernel bodies never see the batch axis."""
        from repro.kernels.arena_ops import OpSpec
        chains = _fused_chains(plan.order)
        emitted: set = set()
        specs: List[OpSpec] = []
        for op in plan.order:
            if op.kind == "reshape":
                continue
            cname = op.params.get("fuse_chain")
            if cname is not None:
                if cname not in emitted:
                    emitted.add(cname)
                    specs.append(self._fused_flat_spec(
                        plan, chains[cname], quant))
                continue
            assert all(t.storage().kind != "weight" for t in op.inputs), \
                f"{op.name}: non-arena input cannot be lowered"
            lays = [plan._layout(t) for t in op.inputs]
            out = plan._layout(op.output)
            q = X.op_quant(op, quant)
            for b in range(op.output.storage().batch):
                specs.append(OpSpec(
                    kind=op.kind,
                    in_off=tuple(self._flat_off(plan, t, b)
                                 for t in op.inputs),
                    in_shape=tuple(l.shape for l in lays),
                    out_off=self._flat_off(plan, op.output, b),
                    out_shape=out.shape,
                    dtype="i8" if out.dtype_bytes == 1 else "f32",
                    meta=_canon_meta(op),
                    qmeta=_canon_qmeta(op, q)))
        return tuple(specs)

    def _fused_flat_spec(self, plan: Plan, members: List[Op],
                         quant: Optional[X.QuantSpec]):
        """One flat-program spec for a fused band chain: stage offsets are
        *byte* offsets — arena placements for external operands, packed
        scratch-byte slots (:func:`repro.core.planner.fused_slots` over the
        batched ``nbytes``) for chain-internal ones. Batched chains expand
        their stages op-major (member-major, image-minor) inside the ONE
        call — the exact order the planner's liveness model and the batched
        O_s derivation assume — so a chain's terminal image-0 write can
        never clobber an external input a later image still reads."""
        from repro.kernels.arena_ops import OpSpec
        cat = members[-1]
        B = cat.output.storage().batch
        internal = {op.output.storage() for op in members[:-1]}
        align = max(s.dtype_bytes for s in internal)
        slots, total = fused_slots(members, lambda s: s.nbytes,
                                   align=align)
        stages: List[OpSpec] = []
        for op in members:
            q = X.op_quant(op, quant)
            for b in range(B):
                in_off, in_scr = [], []
                for t in op.inputs:
                    s = t.storage()
                    if s in internal:
                        in_off.append(slots[s] + b * s.image_nbytes)
                        in_scr.append(1)
                    else:
                        in_off.append(self._flat_off(plan, t, b))
                        in_scr.append(0)
                s_out = op.output.storage()
                if s_out in internal:
                    out_off = slots[s_out] + b * s_out.image_nbytes
                    out_scr = 1
                else:
                    out_off = self._flat_off(plan, op.output, b)
                    out_scr = 0
                stages.append(OpSpec(
                    kind=op.kind,
                    in_off=tuple(in_off),
                    in_shape=tuple(tuple(t.shape) for t in op.inputs),
                    out_off=out_off,
                    out_shape=tuple(op.output.shape),
                    dtype="i8" if op.output.storage().dtype_bytes == 1
                    else "f32",
                    meta=_canon_meta(op),
                    qmeta=_canon_qmeta(op, q),
                    in_scratch=tuple(in_scr),
                    out_scratch=out_scr))
        ext = self._chain_ext_inputs(members, internal)
        out_lay = plan._layout(cat.output)
        return OpSpec(
            kind="fused",
            in_off=tuple(self._flat_off(plan, t, 0) for t in ext),
            in_shape=tuple(tuple(t.shape) for t in ext),
            out_off=self._flat_off(plan, cat.output, 0),
            out_shape=out_lay.shape,
            dtype="i8" if out_lay.dtype_bytes == 1 else "f32",
            meta=(cat.params["fuse_chain"],),
            stages=tuple(stages),
            scratch_rows=total)          # bytes in the flat program

    @staticmethod
    def _chain_ext_inputs(members: List[Op], internal) -> List:
        """The chain's external data inputs, deduped in first-read order —
        the DMA order of the streaming fused kernel."""
        ext, seen = [], set()
        for op in members:
            for t in op.inputs:
                s = t.storage()
                if s.kind == "weight" or s in internal or s in seen:
                    continue
                seen.add(s)
                ext.append(t)
        return ext

    def lower_blocks(self, bplan: BlockPlan,
                     quant: Optional[X.QuantSpec] = None) -> Tuple:
        """BlockPlan -> row-blocked OpSpec sequence: arena *row* offsets and
        ``(rows, used)`` block shapes from the legalised
        :class:`~repro.core.planner.BlockLayout` records. A fused band
        chain lowers to ONE spec at its first member's position (stage
        offsets are arena rows, or scratch-slot rows for chain-internal
        operands)."""
        from repro.kernels.arena_ops import OpSpec
        dtype = "i8" if bplan.dtype_bytes == 1 else "f32"
        packed = bplan.packing == "packed"
        sub = bplan.tiling[0]
        chains = _fused_chains(bplan.order)
        emitted: set = set()
        specs: List[OpSpec] = []
        for op in bplan.order:
            if op.kind == "reshape":
                continue
            cname = op.params.get("fuse_chain")
            if cname is not None:
                if cname not in emitted:
                    emitted.add(cname)
                    specs.append(self._fused_block_spec(
                        bplan, chains[cname], quant))
                continue
            ins = [t for t in op.inputs if t.storage().kind != "weight"]
            assert len(ins) == len(op.inputs), \
                f"{op.name}: non-arena input cannot be lowered"
            lays = [bplan.layout_of(t) for t in ins]
            out = bplan.layout_of(op.output)
            q = X.op_quant(op, quant)
            # packed plans carry their addressing triples into the kernels;
            # legacy plans emit the exact pre-packing specs (shared lowering
            # cache, bit-identical programs)
            extra = dict(
                in_addr=tuple(_addr_triple(l) for l in lays),
                out_addr=_addr_triple(out),
                out_tile=tile_rows(out.cols_per_row, out.row_span, sub),
            ) if packed else {}
            # batched ops expand image-minor: each per-image spec addresses
            # image b's padded sub-block (BlockLayout.image_row_offset)
            for b in range(out.batch):
                specs.append(OpSpec(
                    kind=op.kind,
                    in_off=tuple(
                        l.image_row_offset(b if l.batch > 1 else 0)
                        for l in lays),
                    in_shape=tuple(tuple(t.shape) for t in ins),
                    out_off=out.image_row_offset(b),
                    out_shape=tuple(op.output.shape),
                    dtype=dtype,
                    meta=_canon_meta(op),
                    qmeta=_canon_qmeta(op, q),
                    rowlen=bplan.arena_rowlen,
                    in_rows=tuple((l.image_rows, l.rowlen) for l in lays),
                    out_rows=(out.image_rows, out.rowlen),
                    **extra))
        return tuple(specs)

    def _fused_block_spec(self, bplan: BlockPlan, members: List[Op],
                          quant: Optional[X.QuantSpec], window=None):
        """One row-blocked spec for a fused band chain — or, given the
        chain's staged :class:`~repro.core.planner.OpWindow`, the streaming
        variant, whose stages run entirely inside the VMEM scratch buffer
        (every operand gets an ``include_io`` scratch slot; external inputs
        are DMA'd in up front, the terminal output DMA'd back once).
        Scratch slots are sized over the *batched* rows (per-image rows ×
        batch — per-image sub-blocks pack back to back inside a slot);
        stages expand op-major (member-major, image-minor) so the chain
        executes in the exact order the planner's liveness model assumes,
        each stage addressing its image's sub-block."""
        from repro.kernels.arena_ops import OpSpec
        dtype = "i8" if bplan.dtype_bytes == 1 else "f32"
        L = bplan.arena_rowlen
        sub = bplan.tiling[0]
        cat = members[-1]
        B = cat.output.storage().batch
        internal = {op.output.storage() for op in members[:-1]}
        streaming = window is not None
        packed = bplan.packing == "packed"
        irows_of = chain_image_rows_of(bplan)

        def rows_of(s) -> int:
            """Batched slot rows of one chain operand."""
            return irows_of(s) * (s.batch if s.batch > 1 else 1)
        addr_of = chain_addr_of(bplan)

        def triple_of(s):
            """The packed-addressing spec triple of a chain operand —
            arena tensors from their layout, scratch tensors from the
            shared :func:`~repro.core.planner.chain_addr_of` rule."""
            lay = bplan.layouts.get(s)
            if lay is not None:
                return _addr_triple(lay)
            c, k = addr_of(s)
            return (c, k, int(s.shape[-2]) * int(s.shape[-1]))

        def used_of(s):
            lay = bplan.layouts.get(s)
            if lay is not None:
                return lay.rowlen
            c, k, rl = triple_of(s)
            return L if k > 1 else c * rl

        slots, total = fused_slots(members, rows_of, round_to=sub,
                                   include_io=streaming)
        for s in internal:
            assert used_of(s) <= L, \
                f"scratch row of {s.name} wider than the arena row"

        def place(t, b):
            """(offset, (rows, used), scratch?) of one stage operand for
            image ``b`` — scratch-resident operands address their image's
            sub-block inside the batched slot, arena-resident ones the
            image's padded arena sub-block."""
            s = t.storage()
            if s in internal or streaming:
                bb = b if s.batch > 1 else 0
                return (slots[s] + bb * irows_of(s),
                        (irows_of(s), used_of(s)), 1)
            lay = bplan.layouts[s]
            return (lay.image_row_offset(b if lay.batch > 1 else 0),
                    (lay.image_rows, lay.rowlen), 0)

        stages: List[OpSpec] = []
        for op in members:
            q = X.op_quant(op, quant)
            extra = dict(
                in_addr=tuple(triple_of(t.storage()) for t in op.inputs),
                out_addr=triple_of(op.output.storage()),
            ) if packed else {}
            for b in range(B):
                placed = [place(t, b) for t in op.inputs]
                o_off, o_rows, o_scr = place(op.output, b)
                stages.append(OpSpec(
                    kind=op.kind,
                    in_off=tuple(p[0] for p in placed),
                    in_shape=tuple(tuple(t.shape) for t in op.inputs),
                    out_off=o_off,
                    out_shape=tuple(op.output.shape),
                    dtype=dtype,
                    meta=_canon_meta(op),
                    qmeta=_canon_qmeta(op, q),
                    rowlen=L,
                    in_rows=tuple(p[1] for p in placed),
                    out_rows=o_rows,
                    in_scratch=tuple(p[2] for p in placed),
                    out_scratch=o_scr,
                    **extra))
        ext = self._chain_ext_inputs(members, internal)
        out_lay = bplan.layout_of(cat.output)
        # top-level I/O covers the WHOLE batched block of each external
        # operand (per-image sub-blocks are contiguous), so the streaming
        # up-front/write-back DMAs stay one entry per tensor
        spec = OpSpec(
            kind="fused",
            in_off=tuple(bplan.layout_of(t).row_offset for t in ext),
            in_shape=tuple(tuple(t.shape) for t in ext),
            out_off=out_lay.row_offset,
            out_shape=tuple(cat.output.shape),
            dtype=dtype,
            meta=(cat.params["fuse_chain"],),
            rowlen=L,
            in_rows=tuple((bplan.layout_of(t).rows,
                           bplan.layout_of(t).rowlen) for t in ext),
            out_rows=(out_lay.rows, out_lay.rowlen),
            stages=tuple(stages),
            scratch_rows=total)
        if streaming:
            import dataclasses
            assert window.win_rows == total, \
                f"fused window/slot mismatch: {window.win_rows} vs {total}"
            spec = dataclasses.replace(
                spec, win_lo=window.lo, win_rows=window.win_rows,
                in_slots=tuple(slots[t.storage()] for t in ext),
                out_slot=slots[cat.output.storage()])
        return spec

    def lower_stream(self, bplan: BlockPlan,
                     quant: Optional[X.QuantSpec] = None) -> Tuple:
        """BlockPlan -> streaming OpSpec sequence: the row-blocked specs
        with each op's live-window statics grafted on from the planner's
        :class:`~repro.core.planner.WindowSchedule` (1:1 — both skip
        reshape views and both emit one entry per fused chain), so
        ``win_rows > 0`` selects the streaming grid program in
        :mod:`repro.kernels.arena_ops`. Fused chains are re-lowered in
        their streaming form (all stage operands scratch-resident)."""
        import dataclasses
        specs = self.lower_blocks(bplan, quant)
        ws = bplan.window_schedule()
        chains = _fused_chains(bplan.order)
        assert len(specs) == len(ws.windows), \
            f"spec/window mismatch: {len(specs)} vs {len(ws.windows)}"
        out: List = []
        for s, w in zip(specs, ws.windows):
            if s.kind == "fused":
                out.append(self._fused_block_spec(
                    bplan, chains[w.op_name], quant, window=w))
            else:
                out.append(dataclasses.replace(
                    s, win_lo=w.lo, win_rows=w.win_rows, win_starts=w.starts))
        return tuple(out)

    # -- execution ----------------------------------------------------------

    def _legalised(self, plan: Plan) -> Optional[BlockPlan]:
        """The row-blocked legalisation this call should execute, or None
        for the flat program. An explicit ``layout="flat"`` always runs the
        flat program — a BlockPlan's byte offsets are valid flat offsets —
        so blocked-vs-flat cross-checks stay meaningful. A plan that cannot
        be row-blocked (mixed dtype, aggregated views) raises under
        ``layout="blocks"`` and falls back to flat under ``"auto"`` —
        except in compiled and streaming modes, where flat is not
        lowerable."""
        self._check_mode_layout()   # env-followed mode may have flipped
        if self.layout == "flat":
            return None
        if isinstance(plan, BlockPlan):
            return plan
        try:
            return legalise_for_blocks(plan)
        except ValueError:
            if self.layout == "blocks" or self.mode in ("compiled",
                                                        "streaming"):
                raise
            return None

    def execute(self, plan_or_compiled, inputs=None, weights=None, *,
                seed: int = 0, quant=None) -> Dict[str, np.ndarray]:
        import jax.numpy as jnp
        from repro.kernels import arena_ops

        plan, graph = unwrap_plan(plan_or_compiled)
        reason = X.executability(graph)
        if reason is not None:
            raise ValueError(
                f"pallas backend cannot lower {graph.name!r}: {reason}")
        if weights is None and quant is None:
            cached = self._autoparams.get((id(plan), seed))
            if cached is not None and cached[0] is plan:
                weights, quant = cached[1], cached[2]
            else:
                weights = X.synth_weights(graph, seed)
                if X.needs_quant(graph):
                    quant = X.calibrate(graph, seed, weights)
                self._autoparams[(id(plan), seed)] = (plan, weights, quant)
                while len(self._autoparams) > 32:
                    self._autoparams.popitem(last=False)
        if weights is None:
            weights = X.synth_weights(graph, seed)
        if quant is None and X.needs_quant(graph):
            quant = X.calibrate(graph, seed, weights)
        if inputs is None:
            inputs = (X.quant_inputs(graph, quant, seed) if quant is not None
                      else X.random_inputs(graph, seed))

        def w_of(op):
            if quant is not None and id(op) in quant.weights_q:
                return jnp.asarray(quant.weights_q[id(op)]["filter"],
                                   jnp.int8)
            return jnp.asarray(weights[id(op)]["filter"], jnp.float32)

        # weight order mirrors the per-image spec/stage expansion exactly:
        # a batched op repeats its filter per image (same jnp buffer, no
        # copies); a batched fused chain's stages run op-major so each
        # weighted member's filter repeats per image consecutively
        wflat = []
        wchains = _fused_chains(plan.order)
        wemitted: set = set()
        for op in plan.order:
            if op.kind == "reshape":
                continue
            cname = op.params.get("fuse_chain")
            if cname is not None:
                if cname in wemitted:
                    continue
                wemitted.add(cname)
                for m in wchains[cname]:
                    if m.kind in arena_ops.WEIGHTED_KINDS:
                        wflat.extend(
                            w_of(m)
                            for _ in range(m.output.storage().batch))
                continue
            if op.kind in arena_ops.WEIGHTED_KINDS:
                wflat.extend(w_of(op)
                             for _ in range(op.output.storage().batch))

        bplan = self._legalised(plan)
        route = (("stream" if self.mode == "streaming" else "blocks")
                 if bplan is not None else "flat")
        key = (id(plan), route, id(quant) if quant is not None else None)
        cached = self._lowered.get(key)
        if cached is not None and cached[0] is plan and cached[1] is quant:
            specs = cached[2]
            self._cache_hits += 1
        else:
            self._cache_misses += 1
            if route == "stream":
                specs = self.lower_stream(bplan, quant)
            elif route == "blocks":
                specs = self.lower_blocks(bplan, quant)
            else:
                specs = self.lower(plan, quant)
            self._lowered[key] = (plan, quant, specs)
            while len(self._lowered) > 32:
                self._lowered.popitem(last=False)

        if bplan is not None:
            if self.mode == "streaming":
                budget = self._resolve_budget()
                ws = bplan.window_schedule()
                if ws.max_resident_bytes > budget:
                    raise ValueError(
                        f"streaming window of {graph.name!r} does not fit "
                        f"VMEM: peak resident {ws.max_resident_bytes} bytes "
                        f"({ws.max_window_rows} live rows) exceeds the "
                        f"{budget}-byte budget")
            elif self.mode == "compiled":
                budget = self._resolve_budget()
                # a fused chain's scratch is VMEM-resident alongside the
                # whole arena while its super-kernel runs
                scratch = max((s.scratch_rows for s in specs
                               if s.kind == "fused"), default=0)
                arena_bytes = (bplan.total_rows + scratch) * bplan.row_bytes
                if arena_bytes > budget:
                    raise ValueError(
                        f"arena of {graph.name!r} does not fit VMEM: "
                        f"{arena_bytes} bytes ({bplan.total_rows} rows"
                        + (f" + {scratch} fused-scratch rows" if scratch
                           else "")
                        + f") exceeds the {budget}-byte budget — "
                        "mode='streaming' keeps only the live window "
                        "resident")
            arena = self._seed_block_arena(bplan, graph, inputs)
        else:
            arena = np.zeros(plan.peak_bytes, np.uint8)
            for t in graph.tensors:
                if t.kind == "input":
                    s, off = t.storage(), plan.offsets[t.storage()]
                    v = np.asarray(inputs[t.name],
                                   X.arena_dtype(s.dtype_bytes)).reshape(-1)
                    arena[off:off + s.nbytes] = v.view(np.uint8)

        fn = arena_ops.lower_program(specs, self.interpret)
        with warnings.catch_warnings():
            # CPU jit can't honour the donation and warns; the in-kernel
            # aliasing is what carries the single-buffer semantics there
            warnings.filterwarnings("ignore", message=".*donated.*")
            out_arena = np.asarray(fn(jnp.asarray(arena), *wflat))

        if bplan is not None:
            return self._gather_block_outputs(bplan, graph, out_arena)
        outs: Dict[str, np.ndarray] = {}
        for t in graph.tensors:
            if t.kind == "output":
                s, off = t.storage(), plan.offsets[t.storage()]
                outs[t.name] = out_arena[off:off + s.nbytes].view(
                    X.arena_dtype(s.dtype_bytes)).reshape(X.tensor_shape(t))
        return outs

    @staticmethod
    def _seed_block_arena(bplan: BlockPlan, graph, inputs) -> np.ndarray:
        """A zeroed (total_rows, rowlen) typed arena with every model input
        scattered into its block layout (row-major over the used row
        prefix). Batched inputs scatter image by image: image ``b`` fills
        its own per-image-padded sub-block of ``image_rows`` rows."""
        dt = X.arena_dtype(bplan.dtype_bytes)
        L = bplan.arena_rowlen
        arena = np.zeros((bplan.total_rows, L), dt)
        for t in graph.tensors:
            if t.kind != "input":
                continue
            lay = bplan.layout_of(t)
            ir = lay.image_rows
            imgs = np.asarray(inputs[t.name], dt).reshape(lay.batch, -1)
            k = lay.row_span
            for b in range(lay.batch):
                off = lay.row_offset + b * ir
                flat = imgs[b]
                if k > 1:
                    # one image row spans k arena rows, column-padded per row
                    rl, h = lay.image_rowlen, ir // k
                    block = np.zeros((h, k * L), dt)
                    block[:, :rl] = flat.reshape(h, rl)
                    arena[off:off + ir, :] = block.reshape(ir, L)
                    continue
                block = np.zeros(ir * lay.rowlen, dt)
                block[:flat.size] = flat
                arena[off:off + ir, :lay.rowlen] = \
                    block.reshape(ir, lay.rowlen)
        return arena

    @staticmethod
    def _gather_block_outputs(bplan: BlockPlan, graph,
                              out_arena: np.ndarray) -> Dict[str, np.ndarray]:
        outs: Dict[str, np.ndarray] = {}
        L = bplan.arena_rowlen
        for t in graph.tensors:
            if t.kind != "output":
                continue
            lay = bplan.layout_of(t)
            k = lay.row_span
            ir = lay.image_rows
            imgs = []
            for b in range(lay.batch):
                off = lay.row_offset + b * ir
                if k > 1:
                    rl, h = lay.image_rowlen, ir // k
                    rows = out_arena[off:off + ir, :]
                    flat = rows.reshape(h, k * L)[:, :rl]
                else:
                    flat = out_arena[off:off + ir, :lay.rowlen]
                imgs.append(flat.reshape(-1)[:t.image_elems])
            outs[t.name] = np.stack(imgs).reshape(X.tensor_shape(t))
        return outs
