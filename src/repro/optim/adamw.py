"""AdamW with global-norm clipping and cosine schedule (pure pytree ops).

Moments are kept in float32 regardless of parameter dtype. The optimiser
state is donated by the train step — the ``O_s = |out|`` in-place special
case of the paper's diagonal memory optimisation, realised as XLA buffer
donation (see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    #: moment dtype; bf16 halves optimiser HBM for the >100B configs
    moment_dtype: str = "float32"


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def init(params, moment_dtype: str = "float32") -> Dict[str, Any]:
    mk = lambda p: jnp.zeros(p.shape, jnp.dtype(moment_dtype))
    return {
        "m": jax.tree.map(mk, params),
        "v": jax.tree.map(mk, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: OptConfig, grads, opt_state, params
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)

    def upd_flat(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = (cfg.b1 * m.astype(jnp.float32)
                 + (1 - cfg.b1) * g).astype(mdt)
        v_new = (cfg.b2 * v.astype(jnp.float32)
                 + (1 - cfg.b2) * g * g).astype(mdt)
        # read the update back through the (possibly bf16) stored moments:
        # every f32 intermediate above is then single-use, so XLA fuses the
        # whole chain without materialising f32 copies of the param stacks
        # (§Perf hillclimb 2; costs one rounding step when moments are bf16)
        u = ((m_new.astype(jnp.float32) / b1c)
             / (jnp.sqrt(v_new.astype(jnp.float32) / b2c) + cfg.eps)
             + cfg.weight_decay * p.astype(jnp.float32))
        return ((p.astype(jnp.float32) - lr * u).astype(p.dtype),
                m_new, v_new)

    upd = upd_flat  # elementwise chain: XLA fuses it, outputs alias donated state

    flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"],
                        is_leaf=lambda x: isinstance(x, jax.Array))
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
