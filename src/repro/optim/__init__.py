"""repro.optim subpackage."""
