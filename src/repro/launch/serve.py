"""Serving launcher: batched greedy generation with a donated KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \\
        --batch 4 --prompt-len 64 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.checkpoint import store
from repro.configs import get_arch
from repro.models import transformer as T
from repro.serve.engine import Engine, ServeConfig
from repro.train import steps as TS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.ckpt:
        like = jax.eval_shape(
            lambda: TS.init_state(cfg, jax.random.PRNGKey(0)))
        params = store.restore(args.ckpt, like)["params"]

    scfg = ServeConfig(cache_len=args.prompt_len + args.max_new,
                       window=args.window, max_new_tokens=args.max_new)
    eng = Engine(cfg, params, scfg)

    rng = np.random.default_rng(args.seed)
    if cfg.frontend != "none":
        prompts = rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32)
    else:
        prompts = rng.integers(0, cfg.vocab_size,
                               (args.batch, args.prompt_len)).astype(np.int32)

    t0 = time.time()
    out = eng.generate(prompts)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s incl. compile)")
    for i, row in enumerate(out[:4]):
        print(f"  seq{i}: {row.tolist()}")


if __name__ == "__main__":
    main()
