"""repro.launch subpackage."""
