import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes and extract memory / cost / collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both

This is the ONLY entry point that forces 512 host devices; smoke tests and
benchmarks see the real single CPU device.
"""
import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import roofline as RL
from repro import sharding as SH
from repro.configs import arch_names, get_arch, get_shape
from repro.launch import mesh as M
from repro.launch import specs as SP
from repro.models import transformer as T
from repro.models.config import SHAPES, ArchConfig, ShapeConfig
from repro.optim.adamw import OptConfig
from repro.train import steps as TS

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "dryrun")


def _lower_train(cfg: ArchConfig, shape: ShapeConfig, mesh, ba):
    # pure data parallel for small models (params replicated, batch over
    # data AND model axes) — see specs.parallel_policy / §Perf hillclimb 3
    policy = SP.parallel_policy(cfg, mesh)
    if policy == "dp":
        ext = (*ba, "model")
        n = 1
        for a in ext:
            n *= mesh.shape[a]
        if shape.global_batch % n == 0:  # else keep batch on (pod,)data only
            ba = ext
    specs = SP.input_specs(cfg, shape)
    st_sh = SP.state_shardings(cfg, mesh, policy=policy)
    b_sh = SP.batch_shardings(cfg, shape, mesh, batch_ax=ba)
    data_shards = 1
    for a in ba:
        data_shards *= mesh.shape[a]
    micro = TS.default_microbatches(cfg, shape.global_batch, shape.seq_len,
                                    data_shards)
    fn = functools.partial(TS.train_step, cfg, TS.opt_config_for(cfg),
                           remat=True, microbatches=micro,
                           accum_dtype=TS.accum_dtype_for(cfg))
    jitted = jax.jit(fn, donate_argnums=(0,),
                     in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None))
    return (jitted.lower(specs["state"], specs["batch"]),
            {"microbatches": micro, "policy": policy})


def _lower_prefill(cfg: ArchConfig, shape: ShapeConfig, mesh, ba):
    specs = SP.input_specs(cfg, shape)
    p_sh = SP.param_shardings(cfg, mesh)
    cache_sh = SP.cache_shardings(cfg, shape, mesh)
    from jax.sharding import NamedSharding
    ax = ba if len(ba) > 1 else ba[0]
    tok_dims = [ax] + [None] * (len(specs["inputs"].shape) - 1)
    tok_sh = NamedSharding(mesh, SP._fit(mesh, specs["inputs"].shape, tok_dims))
    fn = functools.partial(T.prefill, cfg, cache_len=shape.seq_len)
    jitted = jax.jit(fn, in_shardings=(p_sh, tok_sh),
                     out_shardings=(None, cache_sh))
    return jitted.lower(specs["params"], specs["inputs"]), {}


def _lower_decode(cfg: ArchConfig, shape: ShapeConfig, mesh, ba):
    specs = SP.input_specs(cfg, shape)
    p_sh = SP.param_shardings(cfg, mesh)
    c_sh = SP.cache_shardings(cfg, shape, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    ax = ba if len(ba) > 1 else ba[0]
    tok_sh = NamedSharding(mesh, SP._fit(mesh, specs["tokens"].shape,
                                         [ax, None]))
    pos_sh = NamedSharding(mesh, P())
    window = SP.decode_window(cfg, shape)

    def fn(params, cache, tokens, pos):
        return T.decode_step(cfg, params, cache, tokens, pos, window=window)

    jitted = jax.jit(fn, donate_argnums=(1,),
                     in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                     out_shardings=(None, c_sh))
    return jitted.lower(specs["params"], specs["cache"], specs["tokens"],
                        specs["pos"]), {"window": window,
                                        "cache_len": SP.cache_len_for(cfg, shape)}


def run_one(arch: str, shape_name: str, multi_pod: bool,
            compile_: bool = True) -> Dict[str, Any]:
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = M.make_production_mesh(multi_pod=multi_pod)
    ba = M.batch_axes(mesh)
    chips = 1
    for a in mesh.axis_names:
        chips *= mesh.shape[a]
    t0 = time.time()
    env_ba = ba
    if shape.kind == "train" and SP.parallel_policy(cfg, mesh) == "dp":
        ext = (*ba, "model")
        n = 1
        for a in ext:
            n *= mesh.shape[a]
        if shape.global_batch % n == 0:
            env_ba = ext
    with mesh, SH.axis_env(mesh, batch=env_ba):
        if shape.kind == "train":
            lowered, extra = _lower_train(cfg, shape, mesh, ba)
        elif shape.kind == "prefill":
            lowered, extra = _lower_prefill(cfg, shape, mesh, ba)
        else:
            lowered, extra = _lower_decode(cfg, shape, mesh, ba)
        t_lower = time.time() - t0
        rec: Dict[str, Any] = {
            "arch": arch, "shape": shape_name,
            "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
            "chips": chips, "lower_s": round(t_lower, 1), **extra,
        }
        if compile_:
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
            rl = RL.analyse(f"{arch}/{shape_name}/{rec['mesh']}", compiled,
                            None, RL.model_flops_for(cfg, shape), chips)
            ma = compiled.memory_analysis()
            rec.update({
                "hlo_flops": rl.hlo_flops,
                "hlo_bytes": rl.hlo_bytes,
                "collective_bytes": rl.coll_bytes,
                "collectives": rl.coll_breakdown,
                "t_compute_s": rl.t_compute,
                "t_memory_s": rl.t_memory,
                "t_collective_s": rl.t_collective,
                "bottleneck": rl.bottleneck,
                "model_flops": rl.model_flops,
                "useful_flops_ratio": rl.useful_flops_ratio,
                "per_device_bytes": {
                    "arguments": ma.argument_size_in_bytes,
                    "outputs": ma.output_size_in_bytes,
                    "temps": ma.temp_size_in_bytes,
                    "code": ma.generated_code_size_in_bytes,
                },
            })
            print(rl.row(), flush=True)
            print(f"  per-device: args={ma.argument_size_in_bytes / 2**30:.2f}"
                  f"GiB out={ma.output_size_in_bytes / 2**30:.2f}GiB "
                  f"temps={ma.temp_size_in_bytes / 2**30:.2f}GiB "
                  f"(HBM 16GiB)", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun_results.jsonl")
    args = ap.parse_args()

    pairs = []
    archs = arch_names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in pods:
                pairs.append((a, s, mp))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    ok = fail = 0
    with open(args.out, "a") as f:
        for a, s, mp in pairs:
            tag = f"{a} × {s} × {'2x16x16' if mp else '16x16'}"
            print(f"=== dry-run {tag}", flush=True)
            try:
                rec = run_one(a, s, mp, compile_=not args.no_compile)
                rec["ok"] = True
                ok += 1
            except Exception as e:  # record failures: they are bugs
                traceback.print_exc()
                rec = {"arch": a, "shape": s, "multi_pod": mp, "ok": False,
                       "error": f"{type(e).__name__}: {e}"}
                fail += 1
            f.write(json.dumps(rec) + "\n")
            f.flush()
    print(f"dry-run complete: {ok} ok, {fail} failed")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
