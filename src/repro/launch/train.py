"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \\
        --steps 200 --batch 8 --seq 256

Reduced configs train for real on the host CPU; full configs require the
production mesh (use launch/dryrun.py to validate those without hardware).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticCorpus, embedding_batches
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import OptConfig
from repro.train import steps as TS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the 2-layer smoke variant (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, name=cfg.name.replace("-smoke", ""))
    opt = OptConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                    total_steps=args.steps)

    dc = DataConfig(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    if cfg.frontend != "none":
        batches = embedding_batches(dc, cfg.d_model, seed=args.seed)
    else:
        batches = SyntheticCorpus(dc).packed_batches()

    state = TS.init_state(cfg, jax.random.PRNGKey(args.seed), opt)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params:,} steps={args.steps} "
          f"batch={args.batch}x{args.seq}")

    step_fn = jax.jit(
        lambda st, b: TS.train_step(cfg, opt, st, b, remat=False,
                                    microbatches=args.microbatches),
        donate_argnums=(0,))
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        state, m = step_fn(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            tok_s = (i + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {i:5d} loss={float(m['loss']):8.4f} "
                  f"ce={float(m['ce']):8.4f} gnorm={float(m['grad_norm']):7.3f} "
                  f"lr={float(m['lr']):.2e} tok/s={tok_s:,.0f}", flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            p = store.save(args.ckpt_dir, state, step=i + 1)
            print(f"checkpoint -> {p}", flush=True)
    print(f"done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
