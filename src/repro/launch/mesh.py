"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis
composes with ``data`` for batch sharding (pure data parallel across pods —
the only inter-pod traffic is the gradient all-reduce).

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = 1, 1
    return jax.make_mesh((data, model), ("data", "model"))
