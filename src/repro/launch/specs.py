"""ShapeDtypeStruct input specs + parameter/cache shardings per (arch, shape).

``input_specs`` produces weak-type-correct, shardable stand-ins for every
input of the lowered step — no device allocation ever happens for the full
configs (dry-run only).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.models.config import ArchConfig, ShapeConfig
from repro.optim import adamw
from repro.train import steps as TS

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# Abstract state/batch construction
# ---------------------------------------------------------------------------


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))


def abstract_state(cfg: ArchConfig):
    oc = TS.opt_config_for(cfg)
    return jax.eval_shape(
        lambda: TS.init_state(cfg, jax.random.PRNGKey(0), oc))


def abstract_cache(cfg: ArchConfig, batch: int, cache_len: int):
    return jax.eval_shape(lambda: T.init_cache(cfg, batch, cache_len))


def cache_len_for(cfg: ArchConfig, shape: ShapeConfig) -> int:
    """decode_32k keeps the full 32k cache; long_500k uses the sliding
    window ring for attention archs (sub-quadratic path; SSM state is O(1))."""
    if shape.kind == "long_decode":
        return min(cfg.sliding_window or 4096, shape.seq_len)
    return shape.seq_len


def decode_window(cfg: ArchConfig, shape: ShapeConfig) -> int:
    return cfg.sliding_window if shape.kind == "long_decode" else 0


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """All inputs of the step lowered for ``shape`` (see launch/dryrun.py)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.frontend != "none":
            inputs = SDS((b, s, cfg.d_model), jnp.float32)
        else:
            inputs = SDS((b, s), jnp.int32)
        return {
            "state": abstract_state(cfg),
            "batch": {"inputs": inputs, "targets": SDS((b, s), jnp.int32)},
        }
    if shape.kind == "prefill":
        if cfg.frontend != "none":
            inputs = SDS((b, s, cfg.d_model), jnp.float32)
        else:
            inputs = SDS((b, s), jnp.int32)
        return {"params": abstract_params(cfg), "inputs": inputs}
    # decode shapes
    cl = cache_len_for(cfg, shape)
    return {
        "params": abstract_params(cfg),
        "cache": abstract_cache(cfg, b, cl),
        "tokens": SDS((b, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _fit(mesh: Mesh, shape: Tuple[int, ...], spec_dims) -> P:
    """Drop sharding on axes that do not divide evenly."""
    out = []
    for size, ax in zip(shape, spec_dims):
        out.append(ax if size % _axis_size(mesh, ax) == 0 else None)
    return P(*out)


#: (path-substring, per-dim logical spec RIGHT-ALIGNED to the array rank).
#: "M" = model axis, "F" = FSDP over the data axis (applied only when the
#: replicated-over-data state would overflow HBM — see ``needs_fsdp``),
#: None = replicated.
_PARAM_RULES = [
    ("moe/router/w", (None, None)),
    ("moe/w_gate", ("M", None, "F")),
    ("moe/w_up", ("M", None, "F")),
    ("moe/w_down", ("M", "F", None)),
    ("attn/wq/w", ("F", "M")), ("attn/wk/w", ("F", "M")),
    ("attn/wv/w", ("F", "M")), ("attn/wo/w", ("M", "F")),
    ("attn/wq/b", ("M",)), ("attn/wk/b", ("M",)), ("attn/wv/b", ("M",)),
    ("attn/wq_a/w", (None, None)), ("attn/wkv_a/w", (None, None)),
    ("attn/wq_b/w", (None, "M")), ("attn/wk_b/w", (None, "M")),
    ("attn/wv_b/w", (None, "M")),
    ("mlp/w_up/w", ("F", "M")), ("mlp/w_gate/w", ("F", "M")),
    ("mlp/w_down/w", ("M", "F")),
    ("rwkv/wr/w", (None, "M")), ("rwkv/wk/w", (None, "M")),
    ("rwkv/wv/w", (None, "M")), ("rwkv/wd/w", (None, "M")),
    ("rwkv/wg/w", (None, "M")), ("rwkv/wo/w", ("M", None)),
    ("cmix/wk/w", (None, "M")), ("cmix/wv/w", ("M", None)),
    ("mamba/w_in/w", (None, "M")), ("mamba/conv", (None, "M")),
    ("mamba/w_bc/w", ("M", None)), ("mamba/w_dt/w", ("M", None)),
    ("mamba/a_log", ("M", None)), ("mamba/d_skip", ("M",)),
    ("mamba/w_out/w", ("M", None)),
]


def parallel_policy(cfg: ArchConfig, mesh: Mesh) -> str:
    """"dp" = pure data parallel (params replicated, batch over data AND
    model axes) for models whose full train state fits one chip; "tp" =
    tensor/expert parallel over the model axis (default).

    Rationale (§Perf hillclimb 3): tensor parallelism costs two activation
    all-reduces per layer; for sub-1B models that collective time dwarfs
    their compute. With replicated params the only collective left is the
    gradient all-reduce.
    """
    state_bytes = cfg.param_count() * (2 + 4 + 4)
    if not cfg.is_moe and state_bytes <= 8 * 2**30:
        return "dp"
    return "tp"


def needs_fsdp(cfg: ArchConfig, mesh: Mesh, model_axis="model",
               budget_bytes: float = 8 * 2**30) -> bool:
    """True when params+AdamW moments sharded over the model axis alone
    would exceed the per-chip budget — then "F" dims shard over data too."""
    state_bytes = cfg.param_count() * (2 + 4 + 4)  # bf16 + f32 m,v
    return state_bytes / _axis_size(mesh, model_axis) > budget_bytes


def _param_spec(mesh: Mesh, path: str, leaf, model_axis="model",
                fsdp: bool = False, fsdp_axis="data") -> P:
    shape = leaf.shape
    if path == "embed":
        # vocab-sharded when divisible; otherwise fully replicated (sharding
        # d_model instead trips an SPMD gather bug on the pod mesh for the
        # indivisible-vocab archs — hymba 32001, minicpm3 73448)
        cand = ("M", "F") if fsdp else ("M", None)
        return _fit(mesh, shape, _resolve(cand, model_axis, fsdp, fsdp_axis))
    if path == "lm_head":
        return _fit(mesh, shape,
                    _resolve(("F", "M"), model_axis, fsdp, fsdp_axis))
    for frag, dims in _PARAM_RULES:
        if frag in path:
            spec = _resolve(dims, model_axis, fsdp, fsdp_axis)
            # right-align (block params carry a leading L dim)
            full = [None] * (len(shape) - len(spec)) + list(spec)
            return _fit(mesh, shape, full)
    return P(*([None] * len(shape)))


def _resolve(dims, model_axis, fsdp: bool = False, fsdp_axis="data"):
    out = []
    for d in dims:
        if d == "M":
            out.append(model_axis)
        elif d == "F":
            out.append(fsdp_axis if fsdp else None)
        else:
            out.append(None)
    return out


def _paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _paths(v, f"{prefix}{k}/")
    else:
        yield prefix[:-1], tree


def param_shardings(cfg: ArchConfig, mesh: Mesh, model_axis="model",
                    fsdp: Optional[bool] = None,
                    policy: Optional[str] = None):
    ap = abstract_params(cfg)
    if policy is None:
        policy = parallel_policy(cfg, mesh)
    if fsdp is None:
        fsdp = needs_fsdp(cfg, mesh, model_axis)

    def build(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: build(v, f"{prefix}{k}/") for k, v in tree.items()}
        if policy == "dp":
            return NamedSharding(mesh, P(*([None] * len(tree.shape))))
        return NamedSharding(mesh, _param_spec(mesh, prefix[:-1], tree,
                                               model_axis, fsdp))

    return build(ap)


def state_shardings(cfg: ArchConfig, mesh: Mesh, model_axis="model",
                    policy: Optional[str] = None):
    ps = param_shardings(cfg, mesh, model_axis, policy=policy)
    return {
        "params": ps,
        "opt": {"m": ps, "v": ps,
                "step": NamedSharding(mesh, P())},
    }


def batch_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    batch_ax=None):
    ba = batch_ax or (("pod", "data") if "pod" in mesh.axis_names else "data")
    specs = input_specs(cfg, shape)

    def shard_like(sds):
        dims = [ba] + [None] * (len(sds.shape) - 1)
        return NamedSharding(mesh, _fit(mesh, sds.shape, dims))

    return jax.tree.map(shard_like, specs["batch"])


def cache_shardings(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    model_axis="model", batch_ax=None):
    ba = batch_ax or (("pod", "data") if "pod" in mesh.axis_names else "data")
    cl = cache_len_for(cfg, shape)
    ac = abstract_cache(cfg, shape.global_batch, cl)

    def spec_for(name: str, sds) -> NamedSharding:
        shp = sds.shape
        if name in ("k", "v"):                     # (L,B,C,KV,D)
            kv_ok = shp[3] % _axis_size(mesh, model_axis) == 0
            dims = [None, ba, None if kv_ok else model_axis,
                    model_axis if kv_ok else None, None]
        elif name in ("k_scale", "v_scale"):       # (L,B,C,KV)
            kv_ok = shp[3] % _axis_size(mesh, model_axis) == 0
            dims = [None, ba, None if kv_ok else model_axis,
                    model_axis if kv_ok else None]
        elif name in ("c_kv", "k_rope"):           # (L,B,C,r)
            dims = [None, ba, model_axis, None]
        elif name == "wkv":                        # (L,B,H,D,D)
            dims = [None, ba, model_axis, None, None]
        elif name in ("shift", "cm_shift"):        # (L,B,d)
            dims = [None, ba, model_axis]
        elif name == "ssm":                        # (L,B,di,N)
            dims = [None, ba, model_axis, None]
        elif name == "conv":                       # (L,B,K-1,di)
            dims = [None, ba, None, model_axis]
        else:
            dims = [None] * len(shp)
        return NamedSharding(mesh, _fit(mesh, shp, dims))

    return {k: spec_for(k, v) for k, v in ac.items()}
