"""Loss + train step (donated params/optimiser state = DMO's in-place case)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ArchConfig
from repro.optim import adamw

TrainState = Dict[str, Any]   # {"params", "opt", ...}
Batch = Dict[str, jax.Array]  # {"inputs": (B,S) or (B,S,d), "targets": (B,S)}

MOE_AUX_WEIGHT = 0.01


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean token NLL, float32 logsumexp. The gold-logit term is a masked
    reduction (fuses; SPMD-friendly when the vocab dim is model-sharded,
    unlike take_along_axis which would gather across shards)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    vocab = lf.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(jnp.where(iota == targets[..., None], lf, 0.0), axis=-1)
    return jnp.mean(lse - gold)


#: sequence-chunked loss kicks in above this vocab size: the (S, V) logits
#: are never materialised — the head matmul + softmax run one seq chunk at a
#: time inside a scan (§Perf hillclimb 3, DP policy with unshardable vocab)
CHUNKED_CE_VOCAB = 32768
CE_CHUNK = 512


def chunked_cross_entropy(cfg: ArchConfig, params, x: jax.Array,
                          targets: jax.Array, chunk: int = CE_CHUNK
                          ) -> jax.Array:
    """x: (B,S,d) final hidden states; head+CE applied per seq chunk."""
    b, s, d = x.shape
    if s % chunk or s <= chunk:
        return cross_entropy(T.unembed(cfg, params, x), targets)
    nc = s // chunk
    xc = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, nc, chunk), 1, 0)

    def body(tot, xs):
        xx, tt = xs
        logits = T.unembed(cfg, params, xx)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
        gold = jnp.sum(jnp.where(iota == tt[..., None], lf, 0.0), axis=-1)
        return tot + jnp.sum(lse - gold), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, tc))
    return tot / (b * s)


def loss_fn(cfg: ArchConfig, params, batch: Batch, remat: bool = True):
    if cfg.vocab_size >= CHUNKED_CE_VOCAB:
        x, aux = T.forward_hidden(cfg, params, batch["inputs"], remat=remat)
        ce = chunked_cross_entropy(cfg, params, x, batch["targets"])
    else:
        logits, aux = T.forward_train(cfg, params, batch["inputs"],
                                      remat=remat)
        ce = cross_entropy(logits, batch["targets"])
    loss = ce + MOE_AUX_WEIGHT * aux if cfg.is_moe else ce
    return loss, {"ce": ce, "moe_aux": aux}


def opt_config_for(cfg: ArchConfig) -> adamw.OptConfig:
    """bf16 moments for >100B-param configs (state must fit 16GB/chip)."""
    mdt = "bfloat16" if cfg.param_count() > 1e11 else "float32"
    return adamw.OptConfig(moment_dtype=mdt)


def accum_dtype_for(cfg: ArchConfig) -> str:
    """bf16 gradient accumulation for >100B configs (see §Perf)."""
    return "bfloat16" if cfg.param_count() > 1e11 else "float32"


def init_state(cfg: ArchConfig, key,
               opt_cfg: Optional[adamw.OptConfig] = None) -> TrainState:
    params = T.init_params(cfg, key)
    mdt = opt_cfg.moment_dtype if opt_cfg else "float32"
    return {"params": params, "opt": adamw.init(params, mdt)}


def train_step(cfg: ArchConfig, opt_cfg: adamw.OptConfig, state: TrainState,
               batch: Batch, remat: bool = True, microbatches: int = 1,
               accum_dtype: str = "float32",
               ) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One SGD step, optionally with gradient accumulation over
    ``microbatches`` slices of the global batch (bounds peak activation /
    logits memory — large-vocab archs at 1M-token batches need it).
    Intended to be jit'ed with donate_argnums on ``state``."""
    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(cfg, p, b, remat), has_aux=True)
    if microbatches <= 1:
        (loss, parts), grads = grad_fn(state["params"], batch)
    else:
        mb = jax.tree.map(
            lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                *x.shape[1:]), batch)
        adt = jnp.dtype(accum_dtype)
        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, adt), state["params"])

        def acc(carry, b):
            g_acc, l_acc, a_acc = carry
            (l, parts), g = grad_fn(state["params"], b)
            g_acc = jax.tree.map(lambda a, x: a + x.astype(adt), g_acc, g)
            return (g_acc, l_acc + l, a_acc + parts["moe_aux"]), None

        (grads, loss, aux), _ = jax.lax.scan(
            acc, (zero, jnp.zeros((), jnp.float32),
                  jnp.zeros((), jnp.float32)), mb)
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * inv, grads)
        loss, parts = loss * inv, {"ce": loss * inv, "moe_aux": aux * inv}
    new_params, new_opt, om = adamw.update(opt_cfg, grads, state["opt"],
                                           state["params"])
    metrics = {"loss": loss, **parts, **om}
    return {"params": new_params, "opt": new_opt}, metrics


def default_microbatches(cfg: ArchConfig, global_batch: int, seq_len: int,
                         data_shards: int, token_budget: int = 4096) -> int:
    """Pick the accumulation factor so each device sees <= token_budget
    tokens per microbatch (keeps logits/activations inside HBM)."""
    per_device_tokens = global_batch * seq_len // max(1, data_shards)
    m = max(1, per_device_tokens // token_budget)
    # must divide the *global* batch
    while global_batch % m:
        m -= 1
    return m


def jit_train_step(cfg: ArchConfig, opt_cfg: adamw.OptConfig,
                   in_shardings=None, out_shardings=None, remat: bool = True,
                   microbatches: int = 1):
    """jit with state donation (in-place params/opt update — DMO O_s=|out|)."""
    fn = functools.partial(train_step, cfg, opt_cfg, remat=remat,
                           microbatches=microbatches)
    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
        kw["out_shardings"] = out_shardings
    return jax.jit(fn, donate_argnums=(0,), **kw)
