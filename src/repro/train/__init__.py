"""repro.train subpackage."""
