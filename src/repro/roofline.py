"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds:

  compute    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory     = HLO_bytes / (chips × HBM_bw)
  collective = collective_bytes / (chips × link_bw)

``cost_analysis`` provides FLOPs and bytes; collective bytes are summed from
the optimised HLO text (result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e per chip
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
LINK_BW = 50e9             # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %ar = bf16[16,4096]{1,0} all-reduce(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _tuple_bytes(inner: str) -> int:
    total = 0
    for part in inner.split(","):
        part = part.strip()
        m = re.match(r"(\w+)\[([\d,]*)\]", part)
        if m:
            total += _shape_bytes(m.group(1), m.group(2))
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective result bytes summed over the module ('-start' variants
    counted once, '-done' skipped)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        tup, dtype, dims, kind = m.groups()
        if m.group(0).rstrip("(").endswith("-done("):
            continue
        size = _tuple_bytes(tup) if tup else _shape_bytes(dtype, dims)
        out[kind] += size
    return out


@dataclasses.dataclass
class Roofline:
    name: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float
    per_device_hbm: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def row(self) -> str:
        return (f"{self.name:40s} comp={self.t_compute * 1e3:9.2f}ms "
                f"mem={self.t_memory * 1e3:9.2f}ms "
                f"coll={self.t_collective * 1e3:9.2f}ms "
                f"[{self.bottleneck:10s}] useful={self.useful_flops_ratio:5.2f}"
                + (f" hbm/dev={self.per_device_hbm / 2**30:6.2f}GiB"
                   if self.per_device_hbm else ""))


def analyse(name: str, compiled, lowered_text: Optional[str],
            model_flops: float, chips: int) -> Roofline:
    """NOTE: raw ``cost_analysis()`` counts while-loop bodies once; all three
    terms here come from the trip-count-aware HLO parser (repro.hlocost),
    scaled from per-device to global by × chips."""
    from repro import hlocost
    text = lowered_text if lowered_text is not None else compiled.as_text()
    mc = hlocost.module_cost(text)
    flops = mc.flops * chips          # per-device -> global
    nbytes = mc.bytes * chips
    cb = {k: v * chips for k, v in mc.coll.items()}
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = (ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes + ma.generated_code_size_in_bytes)
        # memory_analysis is already per device under SPMD
    except Exception:
        pass
    return Roofline(name, chips, flops, nbytes, float(sum(cb.values())), cb,
                    model_flops, mem)


def model_flops_for(cfg, shape) -> float:
    """6·N_active·D for training, 2·N_active·D for inference steps."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # one token per sequence
