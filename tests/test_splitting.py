"""Overlap-aware operation splitting: banded-op O_s, executable split-band
graphs, and the split_pair halo/padding + auto_split probe regressions."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import exec as X
from repro.core import pipeline, splitting, zoo
from repro.core.arena import run_reference
from repro.core.graph import Graph, band_range, conv_out_dim, op_pads
from repro.core.planner import legalise_for_blocks, plan_dmo, plan_original
from repro.core.splitting import auto_split, split_pair


def pair_graph(ih=16, iw=12, k=3, s=1, pad="same", kind="conv2d",
               dtype_bytes=4):
    """input -> conv(same) -> <kind>(k, s, pad) -> relu: the canonical
    splittable pair with a non-trivial SAME halo."""
    g = Graph(f"pair_{kind}_{pad}")
    x = g.tensor("x", (ih, iw, 3), dtype_bytes, "input")
    a = g.op("conv2d", [x], (ih, iw, 8),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    oh, ow = conv_out_dim(ih, k, s, pad), conv_out_dim(iw, k, s, pad)
    params = dict(kernel=(k, k), stride=(s, s), padding=pad)
    if kind == "pool":
        params["mode"] = "avg"
    oc = 8 if kind != "conv2d" else 4
    b = g.op(kind, [a], (oh, ow, oc), params)
    g.op("elementwise", [b], (oh, ow, oc), dict(fn="relu"), out_kind="output")
    g.validate()
    return g


# ---------------------------------------------------------------------------
# Bugfix regressions
# ---------------------------------------------------------------------------


def test_edge_bands_carry_explicit_pads_under_same_padding():
    """Regression: split_pair used to re-label band ops ``padding="valid"``
    while declaring edge-band output shapes as if SAME padding applied —
    the first/last bands were ph rows short. Bands now carry explicit
    ``band_pad`` and their declared shapes are geometrically consistent:
    pads + input rows exactly cover the band's output taps."""
    g = pair_graph(ih=16, k=3, s=1, pad="same")
    sg, _ = split_pair(g, 0, 4)
    sg.validate()
    bands = [op for op in sg.ops if band_range(op) is not None]
    assert len(bands) == 8
    for op in bands:
        r0, r1 = band_range(op)
        ph, pw = op_pads(op)
        assert r1 - r0 == op.output.shape[0]
        kh = op.params["kernel"][0]
        sh = op.params.get("stride", (1, 1))[0]
        dh = op.params.get("dilation", (1, 1))[0]
        in_rows = op.inputs[0].shape[0]
        # shape consistency: every declared output row has at least one
        # in-bounds input tap (the inconsistency the old valid re-labelling
        # produced — edge bands declared rows whose windows fell entirely
        # outside the declared input slice)
        for oy in range(r1 - r0):
            taps = [oy * sh - ph + fy * dh for fy in range(kh)]
            assert any(0 <= t < in_rows for t in taps), \
                f"{op.name}: output row {oy} reads pure padding"
    # the consumer's first band carries the pair's SAME top padding, the
    # interior bands none
    consumers = [op for op in bands if op.name.startswith("conv2d_1")]
    assert op_pads(consumers[0])[0] == 1     # kh=3, s=1 SAME: ph = 1
    assert all(op_pads(c)[0] == 0 for c in consumers[1:])
    # producer bands read *deeper* into the full input: negative ph
    producers = [op for op in bands if op.name.startswith("conv2d_0")]
    assert any(op_pads(p)[0] < 0 for p in producers[1:])


def test_recompute_counts_only_recomputed_rows():
    """Regression: the accounting subtracted the FULL intermediate, crediting
    rows no band ever produces. A valid-padded consumer whose window never
    reaches the last intermediate row must still charge the halo overlap."""
    # mid is 18 rows; b (k=3, s=2, valid) reads rows [0, 17) — row 17 never
    # used. Two bands read [0, 9) and [8, 17): exactly one row recomputed.
    g = Graph("valid_tail")
    x = g.tensor("x", (18, 6, 2), 4, "input")
    a = g.op("conv2d", [x], (18, 6, 4),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    b = g.op("conv2d", [a], (8, 2, 4),
             dict(kernel=(3, 3), stride=(2, 2), padding="valid"))
    g.op("elementwise", [b], (8, 2, 4), dict(fn="relu"), out_kind="output")
    sg, rc = split_pair(g, 0, 2)
    sg.validate()
    assert rc == 1 * 6 * 4  # one 6x4 intermediate row, not zero


def test_auto_split_guards_peak_at_step_zero():
    """Regression: when op 0 defines the peak, auto_split probed
    ``ia = -1`` and split_pair Python-wrapped to the bogus (last, first)
    pair. split_pair now rejects negative indices and auto_split skips
    them."""
    assert split_pair(pair_graph(), -1, 2) is None
    # op 0's live set (input + its output) dominates: peak_step == 0
    g = Graph("front_heavy")
    x = g.tensor("x", (32, 8, 4), 4, "input")
    h = g.op("conv2d", [x], (32, 8, 4),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    h = g.op("pool", [h], (4, 1, 4),
             dict(kernel=(8, 8), stride=(8, 8), padding="valid", mode="avg"))
    g.op("elementwise", [h], (4, 1, 4), dict(fn="relu"), out_kind="output")
    probed = []
    real = splitting.split_pair
    try:
        splitting.split_pair = lambda gr, ia, parts: probed.append(ia) or \
            real(gr, ia, parts)
        sg, rc, log = auto_split(g)
    finally:
        splitting.split_pair = real
    assert all(ia >= 0 for ia in probed)
    sg.validate()


def test_auto_split_dedupes_part_candidates():
    """Regression: ``parts in (2, 4, max_parts)`` re-planned the whole graph
    per duplicate when max_parts is 2 or 4."""
    g = pair_graph()
    tried = []
    real = splitting.split_pair
    try:
        splitting.split_pair = lambda gr, ia, parts: \
            tried.append((id(gr), ia, parts)) or real(gr, ia, parts)
        auto_split(g, max_parts=4, rounds=1)
    finally:
        splitting.split_pair = real
    assert len(tried) == len(set(tried)), f"duplicate candidates: {tried}"
    assert all(parts in (2, 4) for _, _, parts in tried)


# ---------------------------------------------------------------------------
# Executability + execution parity vs the unsplit reference
# ---------------------------------------------------------------------------


def test_band_gate_accepts_padded_bands_rejects_legacy():
    sg, _ = split_pair(pair_graph(), 0, 2)
    assert X.executability(sg) is None
    # legacy band op without band_pad: geometry unrecoverable, stays refused
    lg = Graph("legacy")
    x = lg.tensor("x", (8, 8, 4), 4, "input")
    lg.op("conv2d", [x], (4, 8, 4),
          dict(kernel=(3, 3), stride=(1, 1), padding="same",
               row_range=(0, 4)), out_kind="output")
    assert "split row bands" in X.executability(lg)
    # row_range on a non-row-streaming kind is meaningless
    eg = Graph("ew_band")
    y = eg.tensor("y", (8, 8, 4), 4, "input")
    eg.op("elementwise", [y], (8, 8, 4),
          dict(fn="relu", row_range=(0, 8), band_pad=(0, 0)),
          out_kind="output")
    assert "split row bands" in X.executability(eg)


@pytest.mark.parametrize("dtype_bytes", [4, 1], ids=["f32", "int8"])
def test_split_band_zoo_graph_executes_with_parity(dtype_bytes):
    """The acceptance shape: an auto_split-produced zoo graph passes the
    executor gate and reproduces its UNSPLIT reference on both backends —
    bit-exact on numpy (band ops share the source op's weight draw and
    pooled calibration), pallas at the shared tolerance."""
    g = zoo.mobilenet_v1(0.25, 64, dtype_bytes)
    sg, rc, log = auto_split(g)
    assert log and rc > 0, "auto_split must fire on this build"
    assert X.executability(sg) is None
    plan = plan_dmo(sg, method="algorithmic")
    plan.validate()
    assert plan.overlaps, "banded O_s must produce real overlaps"
    # split + overlap beats the conservative (O_s = 0 everywhere) route
    assert plan.peak_bytes < plan_original(sg).peak_bytes
    weights = X.synth_weights(sg)
    quant = X.calibrate(sg, 0, weights) if dtype_bytes == 1 else None
    inputs = (X.quant_inputs(sg, quant) if quant is not None
              else X.random_inputs(sg))
    w0 = X.synth_weights(g)
    q0 = X.calibrate(g, 0, w0) if dtype_bytes == 1 else None
    in0 = X.quant_inputs(g, q0) if q0 is not None else X.random_inputs(g)
    ref0 = run_reference(g, in0, weights=w0, quant=q0)
    got_np = X.get_backend("numpy").execute(plan, inputs, weights,
                                            quant=quant)
    for k in ref0:
        np.testing.assert_array_equal(got_np[k], ref0[k], err_msg=k)
    got_pl = X.get_backend("pallas").execute(plan, inputs, weights,
                                             quant=quant)
    X.compare_outputs(ref0, got_pl, exact=False,
                      label=f"pallas split bands vs unsplit ref ({dtype_bytes}B)")


def test_split_band_plan_legalises_for_blocks():
    """Banded tensors place on the row-blocked arena grid: every band gets
    its own (rows, rowlen) image layout and the legalised plan validates at
    row granularity."""
    sg, _ = split_pair(pair_graph(ih=16, iw=12), 0, 4)
    bp = legalise_for_blocks(plan_dmo(sg, method="algorithmic"))
    banded = [op for op in sg.ops if band_range(op) is not None]
    for op in banded:
        lay = bp.layout_of(op.output)
        h = op.output.shape[0]
        c, k = lay.cols_per_row, lay.row_span
        assert lay.rows == (-(-h // c) if c > 1 else h * k)
        assert lay.image_rowlen == op.output.shape[1] * op.output.shape[2]


def test_pipeline_split_winner_full_verify_chain():
    """compile() on a graph whose winner is a split-derived variant runs
    every verify tier: bit-exact arena execution, the split-vs-unsplit
    reference cross-check, and both pallas programs. Since the fuse pass the
    winner is normally the fused variant (same bands, lower peak)."""
    cp = pipeline.compile(zoo.mobilenet_v1(0.25, 64, 4), cache=False,
                          backend="pallas")
    assert cp.winner in ("split", "fuse") and cp.recompute_elems > 0
    assert cp.verified == "numeric+pallas"
    assert any("split-band execution matches the unsplit reference"
               in l for l in cp.log)
    assert cp.peak_bytes < cp.baseline_bytes


# ---------------------------------------------------------------------------
# Planner property: split + overlap never loses to the conservative route
# ---------------------------------------------------------------------------


def test_manual_mobilenet_pair_relaxation_strictly_improves():
    """Acceptance: on the paper's manual MobileNet pair the banded-O_s
    relaxation beats the conservative (O_s = 0 across splits) split plan
    strictly."""
    g = zoo.mobilenet_v1(0.25, 128, 1, external_input=True)
    mg, rc = split_pair(g, 2, 4)
    mg.validate()
    conservative = plan_original(mg).peak_bytes
    relaxed = plan_dmo(mg, method="algorithmic")
    relaxed.validate()
    assert conservative <= 66 * 1024          # paper: 96 -> 66 KB
    assert relaxed.peak_bytes < conservative  # composition wins
    assert 0 < rc <= 6144


split_geom = st.fixed_dictionaries({
    "ih": st.sampled_from([8, 12, 16, 17, 24]),
    "k": st.sampled_from([1, 3, 5]),
    "s": st.integers(1, 2),
    "pad": st.sampled_from(["same", "valid"]),
    "kind": st.sampled_from(["conv2d", "depthwise_conv2d", "pool"]),
    "parts": st.sampled_from([2, 4]),
})


@settings(max_examples=40, deadline=None)
@given(split_geom)
def test_split_plus_overlap_never_worse_than_conservative(p):
    """Property: a split-band graph planned WITH the banded O_s relaxation
    peaks no higher than the same graph planned conservatively."""
    if p["pad"] == "valid" and (p["ih"] < p["k"] or 12 < p["k"]):
        return
    oh = conv_out_dim(p["ih"], p["k"], p["s"], p["pad"])
    if oh < p["parts"] or oh % p["parts"]:
        return
    g = pair_graph(ih=p["ih"], k=p["k"], s=p["s"], pad=p["pad"],
                   kind=p["kind"])
    r = split_pair(g, 0, p["parts"])
    if r is None:
        return
    sg, _ = r
    sg.validate()
    relaxed = plan_dmo(sg, method="algorithmic")
    relaxed.validate()
    assert relaxed.peak_bytes <= plan_original(sg).peak_bytes
