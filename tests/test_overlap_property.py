"""Property-based invariants of the O_s calculators (hypothesis)."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.graph import Graph, conv_out_dim
from repro.core.overlap import (safe_overlap_algorithmic,
                                safe_overlap_analytic, safe_overlap_trace)

geom = st.fixed_dictionaries({
    "ih": st.integers(4, 14),
    "iw": st.integers(4, 14),
    "ic": st.integers(1, 5),
    "oc": st.integers(1, 6),
    "k": st.sampled_from([1, 2, 3, 5]),
    "s": st.integers(1, 3),
    "padding": st.sampled_from(["same", "valid"]),
    "kind": st.sampled_from(["conv2d", "depthwise_conv2d", "pool"]),
    "mult": st.integers(1, 2),
})


def build(p):
    ih, iw = p["ih"], p["iw"]
    k, s, padding = p["k"], p["s"], p["padding"]
    if padding == "valid" and (ih < k or iw < k):
        padding = "same"
    g = Graph("t")
    x = g.tensor("x", (ih, iw, p["ic"]), 4, "input")
    oh, ow = conv_out_dim(ih, k, s, padding), conv_out_dim(iw, k, s, padding)
    if oh <= 0 or ow <= 0:
        return None
    kind = p["kind"]
    od = p["oc"] if kind == "conv2d" else p["ic"] * (
        p["mult"] if kind == "depthwise_conv2d" else 1)
    params = dict(kernel=(k, k), stride=(s, s), padding=padding)
    if kind == "depthwise_conv2d":
        params["multiplier"] = p["mult"]
    g.op(kind, [x], (oh, ow, od), params, out_kind="output")
    return g.ops[0]


@settings(max_examples=60, deadline=None)
@given(geom)
def test_trace_algorithmic_agree_and_analytic_bounds(p):
    op = build(p)
    if op is None:
        return
    exact = safe_overlap_algorithmic(op)
    assert safe_overlap_trace(op) == exact
    est = safe_overlap_analytic(op)
    assert est is not None
    assert 0 <= est <= exact <= op.output.nbytes


@settings(max_examples=30, deadline=None)
@given(geom, st.integers(1, 4))
def test_overlap_scales_with_dtype(p, ts):
    """O_s in bytes scales linearly with the element width."""
    op = build(p)
    if op is None:
        return
    base = safe_overlap_algorithmic(op)
    op.inputs[0].dtype_bytes = ts
    op.output.dtype_bytes = ts
    assert safe_overlap_algorithmic(op) * 4 == base * ts or base == 0
