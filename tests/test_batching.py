"""Batch-aware plans (PR 10): the batch axis through planner -> legaliser
-> kernels -> backends -> pipeline.

Four layers under test:

- planner: the scaled batch-1 bound ``peak(B) <= B * peak(1)`` (the
  ``_plan_scaled_batch1`` candidate guarantees it for every strategy
  winner, fused chains included), batched plans validating at every swept
  batch, and :func:`repro.core.pipeline.peak_vs_batch` row shape;
- exec: batched execution equals B stacked batch-1 runs — f32 bit-exact,
  int8 <= 1 LSB under one shared QuantSpec — on the reference AND the
  arena; pallas route parity (flat / blocks / streaming) at batch > 1,
  including forced fused band chains (the op-major stage expansion);
- pipeline: ``batch`` in the content-addressed plan-cache key, and
  ``compile_many`` fanning a graphs x batches grid across worker processes
  that share the disk plan-cache (atomic ``os.replace`` writes survive
  same-key races — satellite (a));
- property form: the peak bound + stacked equality as a hypothesis
  property over random band graphs (skips cleanly when hypothesis is
  absent; the parametrized grid above keeps the acceptance tested).
"""
from __future__ import annotations

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import exec as X
from repro.core import zoo
from repro.core.exec.numpy_backend import run_in_arena, run_reference
from repro.core.exec.ops import QuantSpec
from repro.core.graph import Graph
from repro.core.pipeline import (cache_clear, cache_info,
                                 compile as compile_graph, compile_many,
                                 peak_vs_batch)


def band_graph(h: int = 12, c: int = 4, db: int = 4, depth: int = 2,
               branch: bool = True) -> Graph:
    """Small conv tower: enough structure to split/fuse, cheap to execute."""
    g = Graph(f"bg_{h}_{c}_{db}_{depth}_{int(branch)}")
    x = g.tensor("x", (h, h, c), db, "input")
    cur = g.op("conv2d", [x], (h, h, c),
               dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    for _ in range(depth):
        nxt = g.op("depthwise_conv2d", [cur], (h, h, c),
                   dict(kernel=(3, 3), stride=(1, 1), padding="same"))
        if branch:
            nxt = g.op("elementwise", [nxt, cur], (h, h, c), dict(fn="add"))
        cur = nxt
    p = g.op("pool", [cur], (h // 2, h // 2, c),
             dict(kernel=(2, 2), stride=(2, 2), padding="valid",
                  mode="max"))
    m = g.op("mean", [p], (c,), dict(axes=(0, 1)))
    g.op("fully_connected", [m], (8,), out_kind="output")
    g.validate()
    return g


_MODELS = {
    "mobilenet_v1_0.25_32_8bit": lambda: zoo.mobilenet_v1(0.25, 32, 1),
    "mobilenet_v2_0.35_32_f32": lambda: zoo.mobilenet_v2(0.35, 32, 4),
    "band_graph_f32": lambda: band_graph(),
    "band_graph_8bit": lambda: band_graph(db=1),
}


# ---------------------------------------------------------------------------
# planner: the scaled batch-1 peak bound + peak_vs_batch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(_MODELS))
def test_peak_bound_vs_batch1(name):
    """peak(B) <= B * peak(1) for every strategy winner (mobilenet_v2's
    fuse winner regressed this before the op-major fused stage expansion:
    atomic-chain liveness forced disjoint chain I/O)."""
    mk = _MODELS[name]
    peak1 = compile_graph(mk(), batch=1).peak_bytes
    for b in (2, 4, 8):
        cp = compile_graph(mk(), batch=b)
        assert cp.peak_bytes <= b * peak1, \
            f"{name} b={b}: {cp.peak_bytes} > {b}x{peak1}"
        assert cp.plan.peak_bytes == cp.peak_bytes
        cp.plan.validate()


def test_peak_vs_batch_rows():
    rows = peak_vs_batch(zoo.mobilenet_v1(0.25, 32, 1), batches=(1, 2, 4))
    assert [r["batch"] for r in rows] == [1, 2, 4]
    for r in rows:
        b = r["batch"]
        assert r["per_image_bytes"] == -(-r["peak_bytes"] // b)
        assert r["verified"]
        if b > 1:
            assert r["peak_ratio_vs_b1"] is not None
            assert r["peak_ratio_vs_b1"] <= 1.0 + 1e-9
        assert r["padded_peak_bytes"] is None \
            or r["padded_peak_bytes"] >= r["peak_bytes"]


# ---------------------------------------------------------------------------
# exec: batched == B stacked batch-1 runs (shared weights + QuantSpec)
# ---------------------------------------------------------------------------


def _remap_quant(q: QuantSpec, g1: Graph, gb: Graph) -> QuantSpec:
    """The batch-1 QuantSpec re-keyed for the positionally identical
    batched graph (activation params are by tensor name — shared as-is;
    weight tables are by ``id(op)``)."""
    assert len(g1.ops) == len(gb.ops)
    by_pos = dict(zip((id(o) for o in g1.ops), gb.ops))
    return QuantSpec(
        tensors=q.tensors,
        weight_scale={id(by_pos[k]): v for k, v in q.weight_scale.items()},
        weights_q={id(by_pos[k]): v for k, v in q.weights_q.items()})


def _check_stacked(mk, batch: int, split: str = "off") -> None:
    """Batched compile + numpy execution == ``batch`` stacked batch-1 runs
    (f32 bit-exact, int8 <= 1 LSB, one shared QuantSpec), reference AND
    planned arena."""
    cp1 = compile_graph(mk(), split=split)
    cpb = compile_graph(mk(), batch=batch, split=split)
    g1, gb = cp1.graph, cpb.graph
    assert [o.kind for o in g1.ops] == [o.kind for o in gb.ops]

    w1 = X.synth_weights(g1, 0)
    wb = {id(ob): w1[id(o1)] for o1, ob in zip(g1.ops, gb.ops)}
    q1 = qb = None
    if X.needs_quant(g1):
        q1 = X.calibrate(g1, 0, w1)
        qb = _remap_quant(q1, g1, gb)

    imgs = [(X.quant_inputs(g1, q1, seed=i) if q1 is not None
             else X.random_inputs(g1, seed=i)) for i in range(batch)]
    stacked = {k: np.stack([im[k] for im in imgs]) for k in imgs[0]}

    ref_b = run_reference(gb, stacked, weights=wb, quant=qb)
    for i, im in enumerate(imgs):
        ref_1 = run_reference(g1, im, weights=w1, quant=q1)
        for k, v in ref_1.items():
            got = ref_b[k][i]
            if v.dtype == np.int8:
                diff = np.abs(got.astype(np.int32) - v.astype(np.int32))
                assert diff.max(initial=0) <= 1, \
                    f"image {i} {k}: int8 diff {diff.max()}"
            else:
                assert np.array_equal(got, v), f"image {i} {k}"

    # the planned batched arena is bit-exact against its own reference
    arena = run_in_arena(gb, cpb.plan, stacked, weights=wb, quant=qb)
    for k, v in ref_b.items():
        assert np.array_equal(arena[k], v), f"arena {k}"


@pytest.mark.parametrize("name", ["band_graph_f32", "band_graph_8bit"])
@pytest.mark.parametrize("batch", [2, 4, 8])
def test_batched_equals_stacked_small(name, batch):
    _check_stacked(_MODELS[name], batch)


@pytest.mark.parametrize("name,batch", [
    ("mobilenet_v1_0.25_32_8bit", 4),
    ("mobilenet_v2_0.35_32_f32", 2),
])
def test_batched_equals_stacked_models(name, batch):
    _check_stacked(_MODELS[name], batch)


@given(h=st.sampled_from([8, 12, 16]), c=st.sampled_from([4, 8]),
       db=st.sampled_from([1, 4]), depth=st.integers(1, 3),
       branch=st.booleans(), batch=st.sampled_from([2, 4]))
@settings(max_examples=8, deadline=None)
def test_batching_property(h, c, db, depth, branch, batch):
    """Satellite (c): over random band graphs, the batched plan's byte
    peak stays <= B x the batch-1 peak AND the batched execution equals B
    stacked batch-1 runs."""
    mk = lambda: band_graph(h, c, db, depth, branch)   # noqa: E731
    peak1 = compile_graph(mk(), batch=1).peak_bytes
    cp = compile_graph(mk(), batch=batch)
    assert cp.peak_bytes <= batch * peak1
    cp.plan.validate()
    _check_stacked(mk, batch)


# ---------------------------------------------------------------------------
# kernels/backends: pallas route parity at batch > 1
# ---------------------------------------------------------------------------


_ROUTES = {
    "flat": dict(layout="flat"),
    "blocks": dict(layout="blocks"),
    "stream": dict(mode="streaming", interpret=True),
}


@pytest.mark.parametrize("route", list(_ROUTES))
def test_batched_pallas_parity_model(route):
    """Real-model batched parity on the 8-bit reduced flagship under the
    full strategy competition (its winner fuses band chains — this is the
    path that clobbered image >= 1 inputs before the op-major rework)."""
    cp = compile_graph(zoo.mobilenet_v1(0.25, 32, 1), batch=2)
    X.cross_check(cp, backends=(
        "numpy", X.get_backend("pallas", **_ROUTES[route])))


@pytest.mark.parametrize("route", ["flat", "stream"])
def test_batched_fused_forced_parity(route):
    """Forced fused band chains at batch > 1 (independent of which
    strategy wins the competition: split bands, then chain them by hand —
    small graphs never split, so this runs on the reduced flagship)."""
    from repro.core.planner import plan_dmo
    from repro.core.splitting import fuse_chains
    cp = compile_graph(zoo.mobilenet_v1(0.25, 32, 1), batch=2,
                       split="on", fuse="off", verify="constraints")
    gf = fuse_chains(cp.graph)
    assert gf is not None
    assert sum(1 for op in gf.ops if "fuse_chain" in op.params) > 0
    plan = plan_dmo(gf)
    plan.validate()
    X.cross_check(plan, backends=(
        "numpy", X.get_backend("pallas", **_ROUTES[route])))


@pytest.mark.parametrize("batch", [4])
def test_batched_pallas_parity_small_f32(batch):
    cp = compile_graph(band_graph(), batch=batch)
    for route in _ROUTES:
        X.cross_check(cp, backends=(
            "numpy", X.get_backend("pallas", **_ROUTES[route])))


# ---------------------------------------------------------------------------
# pipeline: batch in the cache key; compile_many; disk-store races
# ---------------------------------------------------------------------------


def test_batch_in_cache_key():
    cache_clear()
    c1 = compile_graph(band_graph(), batch=1)
    c2 = compile_graph(band_graph(), batch=2)
    assert not c2.cache_hit          # batch=2 is a different key
    assert c2.key != c1.key
    c2b = compile_graph(band_graph(), batch=2)
    assert c2b.cache_hit
    assert c2b.peak_bytes == c2.peak_bytes


def test_compile_many_shares_disk_cache(tmp_path, monkeypatch):
    """Two spawned workers over a graphs x batches grid; a second run after
    clearing the in-memory tier must be served entirely from the disk
    entries the first run's workers wrote."""
    monkeypatch.setenv("REPRO_DMO_CACHE_DIR", str(tmp_path))
    gs = [band_graph(), band_graph(db=1)]
    res1 = compile_many(gs, batches=(1, 2), workers=2)
    assert len(res1) == 4
    cache_clear()
    res2 = compile_many(gs, batches=(1, 2), workers=2)
    assert sum(r["disk_hits"] for r in res2) == len(res2), res2
    for a, b in zip(res1, res2):
        assert (a["graph"], a["batch"], a["peak_bytes"]) \
            == (b["graph"], b["batch"], b["peak_bytes"])


def test_disk_store_same_key_race(tmp_path, monkeypatch):
    """Satellite (a): concurrent same-key writers race benignly through
    the tmp-file + atomic-replace protocol — two workers compiling the
    SAME (graph, batch) job leave one loadable entry behind."""
    monkeypatch.setenv("REPRO_DMO_CACHE_DIR", str(tmp_path))
    res = compile_many([band_graph(), band_graph()], batches=(1,),
                       workers=2)
    assert res[0]["peak_bytes"] == res[1]["peak_bytes"]
    assert not list(tmp_path.glob("*.tmp.*"))    # no orphaned temp files
    cache_clear()
    cp = compile_graph(band_graph(), batch=1, disk_cache=True)
    assert cache_info()["disk_hits"] >= 1
    assert cp.peak_bytes == res[0]["peak_bytes"]


def test_disk_store_corrupt_entry_degrades(tmp_path, monkeypatch):
    """An unreadable persisted entry is a cold miss, never a crash."""
    monkeypatch.setenv("REPRO_DMO_CACHE_DIR", str(tmp_path))
    cache_clear()
    compile_graph(band_graph(), batch=2, disk_cache=True)
    entries = list(tmp_path.glob("*.pkl"))
    assert entries
    for p in entries:
        p.write_bytes(b"not a pickle")
    cache_clear()
    cp = compile_graph(band_graph(), batch=2, disk_cache=True)
    assert not cp.cache_hit
    assert cache_info()["disk_misses"] >= 1
