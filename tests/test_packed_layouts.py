"""Packed row-blocked layouts: the padding-aware legaliser (PR 9).

Five contracts:

- addressing: the per-tensor packed ``(cols_per_row, row_span)`` mapping
  ``addr``/``image_addr`` is a bijection between image ``(row, col)`` and
  arena ``(row, lane)`` coordinates — exhaustively for hand-picked
  geometries across every dtype tile, property-based under hypothesis;
- safety: a hand-built *packed* BlockPlan whose tensors share live arena
  rows beyond their O_s still fails the row-granular validate (the §I
  no-clobber verification survives packing);
- acceptance: the flagship 8-bit rows' blocked padding overhead drops
  from the legacy layout's +105% to <= +35%, without regressing the
  padded peak or the streaming window vs legacy;
- never-regress: where packing cannot strictly beat the legacy layout
  (exact-fit image rows, no row-streaming structure) ``packing="auto"``
  ships legacy;
- parity: the full-resolution flagship rows (f32 AND int8) execute
  through packed layouts on the blocked and streaming routes, bit-exact
  vs the flat byte program and within tolerance vs the numpy backend.
"""
from __future__ import annotations

import pytest

from _hypothesis_compat import given, settings, st
from repro.core import exec as X
from repro.core import planner as P
from repro.core import zoo
from repro.core.graph import Graph
from repro.core.pipeline import compile as compile_graph
from repro.core.planner import (BlockLayout, BlockPlan, TPU_TILES,
                                legalise_for_blocks, pack_geometry, plan_dmo)


# ---------------------------------------------------------------------------
# Addressing round-trip: (image_row, col) <-> (arena_row, lane)
# ---------------------------------------------------------------------------


def _layout(H: int, rl: int, L: int, db: int = 4) -> BlockLayout:
    """A block layout for an H x rl image in an L-element arena row, built
    with the legaliser's own conventions (pack -> rowlen c*rl, span ->
    rowlen L, rows ceil(H/c) / H*k)."""
    c, k = pack_geometry(rl, L)
    rows = -(-H // c) if c > 1 else H * k
    rowlen = c * rl if k == 1 else L
    return BlockLayout("t", (H, rl, 1), db, 0, rows, rowlen, c, k)


def _assert_roundtrip(H: int, rl: int, L: int, db: int = 4) -> None:
    lay = _layout(H, rl, L, db)
    c, k = lay.cols_per_row, lay.row_span
    assert (c > 1) + (k > 1) <= 1  # exactly one packing direction
    assert lay.image_rowlen == rl
    seen = set()
    for r in range(H):
        for col in range(rl):
            ar, lane = lay.addr(r, col)
            assert 0 <= ar < lay.rows, (r, col, ar)
            assert 0 <= lane < L, (r, col, lane)
            assert lay.image_addr(ar, lane) == (r, col)
            assert (ar, lane) not in seen  # injective
            seen.add((ar, lane))


#: (H, image rowlen, arena rowlen): narrow pack with/without remainder,
#: exact fit, wide span with/without remainder, degenerate single-column.
_GEOMETRIES = [
    (8, 36, 256),     # pack c=7, padded tail lane
    (16, 100, 384),   # pack c=3, H not a multiple of c
    (8, 128, 128),    # exact fit: c=k=1
    (8, 300, 128),    # span k=3, last arena row partially used
    (5, 256, 128),    # span k=2, exact
    (16, 1, 128),     # degenerate: 128 one-element rows per arena row
]


@pytest.mark.parametrize("db", sorted(TPU_TILES))
@pytest.mark.parametrize("geom", _GEOMETRIES)
def test_addr_roundtrip_sweep(db, geom):
    """Deterministic bijection check over hand-picked pack/span/exact
    geometries, for every dtype tile."""
    H, rl, L = geom
    _assert_roundtrip(H, rl, L, db)


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=1, max_value=20),
       st.integers(min_value=1, max_value=600),
       st.integers(min_value=1, max_value=8),
       st.sampled_from(sorted(TPU_TILES)))
def test_addr_roundtrip_property(H, rl, mult, db):
    """Property form: any (H, rowlen) image in any lane-multiple arena row
    round-trips through addr/image_addr without collisions."""
    _assert_roundtrip(H, rl, 128 * mult, db)


# ---------------------------------------------------------------------------
# Row-granular no-clobber validation survives packing
# ---------------------------------------------------------------------------


def _packable_conv_graph() -> Graph:
    g = Graph("packclash")
    x = g.tensor("x", (8, 8, 4), 4, "input")
    h = g.op("conv2d", [x], (8, 8, 8),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    g.op("elementwise", [h], (8, 8, 8), dict(fn="relu"), out_kind="output")
    g.validate()
    return g


def test_row_validate_catches_packed_clobber():
    """A hand-built *packed* BlockPlan that collapses every tensor onto row
    0 shares live arena rows beyond any recorded O_s — the row-granular
    validate must reject it at packed geometry too (packed rows hold
    several image rows, so a row-level clash clobbers more data than the
    legacy layout's)."""
    good = legalise_for_blocks(plan_dmo(_packable_conv_graph()),
                               packing="packed")
    assert good.packing == "packed"
    assert any(l.cols_per_row > 1 for l in good.layouts.values())
    layouts = {t: BlockLayout(l.name, l.shape, l.dtype_bytes, 0, l.rows,
                              l.rowlen, l.cols_per_row, l.row_span)
               for t, l in good.layouts.items()}
    bad = BlockPlan(good.graph, list(good.order),
                    {t: 0 for t in good.offsets}, {}, "bogus+packed",
                    source=good.source, tiling=good.tiling,
                    arena_rowlen=good.arena_rowlen,
                    total_rows=good.total_rows, layouts=layouts,
                    packing="packed")
    with pytest.raises(AssertionError):
        bad.validate()


# ---------------------------------------------------------------------------
# Acceptance + never-regress fallback
# ---------------------------------------------------------------------------


def test_flagship_packed_overhead_acceptance():
    """The PR's headline: the flagship 8-bit rows' plan_dmo blocked
    padding overhead drops from the legacy layout's ~+105% to <= +35%,
    and packing never regresses the padded peak or the streaming window
    vs the legacy legalisation of the same plan."""
    g = zoo.TABLE3_MODELS["mobilenet_v1_0.25_128_8bit"][0]()
    bp = legalise_for_blocks(plan_dmo(g))
    assert bp.packing == "packed"
    assert bp.padding_overhead_pct <= 35.0
    assert bp.legacy_padding_overhead_pct >= 100.0
    assert "packed rows:" in bp.report()
    leg = legalise_for_blocks(bp.source, packing="legacy")
    assert bp.padded_peak_bytes <= leg.padded_peak_bytes
    assert (bp.window_schedule().max_window_rows
            <= leg.window_schedule().max_window_rows)


def test_auto_packing_falls_back_to_legacy():
    """packing="auto" ships the legacy layout when packing cannot strictly
    improve (padded peak, streaming window): exact-fit image rows and
    graphs with no row-streaming structure."""
    g = Graph("exactfit")  # image rowlen 16*8 == the 128-lane tile exactly
    x = g.tensor("x", (8, 16, 8), 4, "input")
    h = g.op("conv2d", [x], (8, 16, 8),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    g.op("elementwise", [h], (8, 16, 8), dict(fn="relu"), out_kind="output")
    g.validate()
    bp = legalise_for_blocks(plan_dmo(g))
    assert bp.packing == "legacy"
    assert all(l.cols_per_row == 1 and l.row_span == 1
               for l in bp.layouts.values())

    g2 = Graph("denseonly")  # no conv/dw/pool: nothing to pack
    a = g2.tensor("a", (64, 64), 4, "input")
    b = g2.op("elementwise", [a], (64, 64), dict(fn="relu"))
    g2.op("elementwise", [b], (64, 64), dict(fn="relu"), name="e2",
          out_kind="output")
    g2.validate()
    assert legalise_for_blocks(plan_dmo(g2)).packing == "legacy"


# ---------------------------------------------------------------------------
# Full-resolution flagship parity through the packed routes
# ---------------------------------------------------------------------------


_FLAGSHIP = {
    "mobilenet_v1_0.25_128_f32": lambda: zoo.mobilenet_v1(0.25, 128, 4),
    "mobilenet_v1_0.25_128_8bit":
        zoo.TABLE3_MODELS["mobilenet_v1_0.25_128_8bit"][0],
}


@pytest.mark.parametrize("name", sorted(_FLAGSHIP))
def test_flagship_packed_parity_all_routes(name):
    """Full-resolution flagship rows execute through packed layouts on the
    blocked AND streaming routes: bit-exact vs the flat byte program
    (identical kernel bodies, repacked operands) and within tolerance
    (f32) / <= 1 LSB (int8, via compare_outputs) vs the numpy backend."""
    cp = compile_graph(_FLAGSHIP[name]())
    bp = cp.legalised()
    assert bp is not None and bp.packing == "packed"
    got_flat = X.get_backend("pallas", layout="flat").execute(cp)
    got_blk = X.get_backend("pallas", layout="blocks").execute(cp)
    got_st = X.get_backend("pallas", mode="streaming",
                           interpret=True).execute(cp)
    got_np = X.get_backend("numpy").execute(cp)
    X.compare_outputs(got_flat, got_blk, exact=True,
                      label=f"{name} packed blocked vs flat")
    X.compare_outputs(got_blk, got_st, exact=True,
                      label=f"{name} packed streaming vs blocked")
    X.compare_outputs(got_np, got_blk, exact=False,
                      label=f"{name} packed blocked vs numpy")


# ---------------------------------------------------------------------------
# Tooling: the packing metrics in the bench differ
# ---------------------------------------------------------------------------


def _load_script(name):
    import importlib.util
    import pathlib
    path = pathlib.Path(__file__).resolve().parents[1] / "scripts" / \
        f"{name}.py"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_diff_packed_metrics_and_series_hardening(tmp_path):
    """The v3 packing metrics gate regressions, old artifacts missing them
    diff cleanly, and --series prints "-" for missing or non-numeric
    values instead of crashing."""
    import json
    bd = _load_script("bench_diff")
    new = {"models": {"m": {"blocked_kb": 72.0, "packed_peak_kb": 72.0,
                            "padding_overhead_pct": 20.0,
                            "packing": "packed"}}}
    # pre-v3 artifact: new metrics absent -> skipped, not KeyError
    assert bd.diff({"models": {"m": {"blocked_kb": 72.0}}}, new) == ([], [])
    worse = {"models": {"m": {"blocked_kb": 72.0, "packed_peak_kb": 100.0,
                              "padding_overhead_pct": 40.0,
                              "packing": "legacy"}}}
    reg, _ = bd.diff(new, worse)
    assert any("packed_peak_kb" in r for r in reg)
    assert any("padding_overhead_pct" in r for r in reg)
    old_p = tmp_path / "BENCH_pr1.json"
    new_p = tmp_path / "BENCH_pr2.json"
    old_p.write_text(json.dumps({"models": {"m": {"blocked_kb": 100.0,
                                                  "packing": "legacy"}}}))
    new_p.write_text(json.dumps(new))
    lines = bd.series([str(old_p), str(new_p)], "padding_overhead_pct")
    assert any("-" in line and "20" in line for line in lines)
    # a non-numeric field (packing) renders "-" rather than crashing
    lines = bd.series([str(old_p), str(new_p)], "packing")
    assert all("legacy" not in line for line in lines)
