"""Streaming grid execution: live-window schedules + the streaming pallas
route (PR 6).

Three layers under test:

- planner: :meth:`BlockPlan.window_schedule` — zoo-wide containment (every
  row an op's streaming program touches stays inside its ``[lo, hi)``
  window and inside the arena; every *valid* kernel tap lands inside the
  fetched rolling window) and the flagship bound ``max_window_rows <
  total_rows`` (the acceptance: the VMEM ceiling is the window, not the
  arena);
- kernels/backend: ``mode="streaming"`` parity — bit-exact vs the
  row-blocked program (same kernel bodies, f32 AND int8) and vs the numpy
  backend (f32 tolerance / int8 <= 1 LSB);
- plumbing: mode validation, the flat-layout refusal, the interpret pin,
  and the VMEM-budget refusals (streaming gates on the window, compiled
  mode on the whole arena).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import exec as X
from repro.core import planner as P
from repro.core import zoo
from repro.core.graph import Graph, op_pads
from repro.core.pipeline import compile as compile_graph


def allops_graph() -> Graph:
    """Every streamable op kind once: rolling (conv/dw/pool) AND staged
    (elementwise, pad, concat, softmax, matmul, fully_connected, mean)."""
    g = Graph("stream_allops")
    x = g.tensor("x", (16, 16, 8), 4, "input")
    c = g.op("conv2d", [x], (16, 16, 8),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    d = g.op("depthwise_conv2d", [c], (16, 16, 8),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    e = g.op("elementwise", [d, c], (16, 16, 8), dict(fn="add"))
    p = g.op("pool", [e], (8, 8, 8),
             dict(kernel=(2, 2), stride=(2, 2), padding="valid", mode="max"))
    pd = g.op("pad", [p], (10, 10, 8),
              dict(paddings=((1, 1), (1, 1), (0, 0))))
    cc = g.op("concat", [pd, pd], (10, 10, 16), dict(axis=-1))
    m = g.op("mean", [cc], (16,), dict(axes=(0, 1)))
    f = g.op("fully_connected", [m], (12,))
    g.op("softmax", [f], (12,), out_kind="output")
    g.validate()
    return g


#: Executable models spanning both dtype tiers, reduced + flagship.
_MODELS = {
    "mobilenet_v1_0.25_32_f32": lambda: zoo.mobilenet_v1(0.25, 32, 4),
    "mobilenet_v2_0.35_32_f32": lambda: zoo.mobilenet_v2(0.35, 32, 4),
    "mobilenet_v1_0.25_32_8bit": lambda: zoo.mobilenet_v1(0.25, 32, 1),
    "mobilenet_v1_0.25_128_8bit":
        zoo.TABLE3_MODELS["mobilenet_v1_0.25_128_8bit"][0],
    "stream_allops": allops_graph,
}


def _bplan(build):
    cp = compile_graph(build())
    bp = cp.legalised()
    assert bp is not None, "model must legalise for the streaming tests"
    return cp, bp


# ---------------------------------------------------------------------------
# Planner layer: window schedule properties
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(_MODELS))
def test_window_containment(name):
    """Every row the streaming program touches stays inside the op's
    declared ``[lo, hi)`` window and inside the arena — and every tap the
    kernel reads on a *valid* input row lands inside the rolling window
    fetched for that tile (the property that makes streaming reads exact,
    not just by-construction extents)."""
    _, bp = _bplan(_MODELS[name])
    ws = bp.window_schedule()
    sub = bp.tiling[0]
    by_name = {op.name: op for op in bp.order}
    chains = {}
    for op in bp.order:
        cname = op.params.get("fuse_chain")
        if cname is not None:
            chains.setdefault(cname, []).append(op)
    # one window per fused chain, one per remaining non-reshape op
    assert len(ws.windows) == sum(
        1 for op in bp.order if op.kind != "reshape") - sum(
        len(m) - 1 for m in chains.values())
    for w in ws.windows:
        if w.kind == "fused":
            # fused chain: every arena-resident operand (ext inputs + the
            # terminal output) stays inside the declared window
            members = chains[w.op_name]
            internal = {op.output.storage() for op in members[:-1]}
            assert 0 <= w.lo < w.hi <= bp.total_rows
            assert w.lo % sub == 0 and w.hi % sub == 0
            for op in members:
                for t in op.inputs:
                    s = t.storage()
                    if s.kind == "weight" or s in internal:
                        continue
                    lay = bp.layout_of(t)
                    assert w.lo <= lay.row_offset
                    assert lay.row_offset + lay.rows <= w.hi
            out = bp.layout_of(members[-1].output)
            assert w.lo <= out.row_offset
            assert out.row_offset + out.rows <= w.hi
            continue
        op = by_name[w.op_name]
        ins = [t for t in op.inputs if t.storage().kind != "weight"]
        lays = [bp.layout_of(t) for t in ins]
        out = bp.layout_of(op.output)
        assert 0 <= w.lo < w.hi <= bp.total_rows
        assert w.lo % sub == 0 and w.hi % sub == 0
        # operand/output block extents stay inside the window
        for lay in lays + [out]:
            assert w.lo <= lay.row_offset
            assert lay.row_offset + lay.rows <= w.hi
        if not w.rolling:
            continue
        # rolling: fixed-size fetches inside window and arena — tile and
        # window geometry is in *arena* rows, taps come from *image* rows
        # mapped through the operands' packed (cols_per_row, row_span)
        xi = lays[0].row_offset
        ih = int(op.inputs[0].shape[-3])
        oh = int(op.output.shape[-3])
        ci, ki = lays[0].cols_per_row, lays[0].row_span
        tr = P.tile_rows(out.cols_per_row, out.row_span, sub)
        win_in = w.win_rows - P.tile_arena_rows(
            out.cols_per_row, out.row_span, sub)
        assert len(w.starts) == -(-oh // tr)
        for s in w.starts:
            assert w.lo <= s and s + win_in <= w.hi
            assert 0 <= s and s + win_in <= bp.total_rows
        # ... and every valid tap of every output row of tile t is
        # resident in tile t's fetched window
        kh, sh, dh, ph = P._roll_geometry(op)
        for t, s in enumerate(w.starts):
            for oy in range(t * tr, min((t + 1) * tr, oh)):
                for fy in range(kh):
                    iy = oy * sh - ph + fy * dh
                    if 0 <= iy < ih:
                        lo_ar = xi + P._ar_of(iy, ci, ki)
                        hi_ar = xi + P._ar_top(iy, ci, ki)
                        assert s <= lo_ar and hi_ar < s + win_in, \
                            f"{op.name}: tap rows [{lo_ar}, {hi_ar}] " \
                            f"outside fetch [{s}, {s + win_in}) at tile {t}"


@pytest.mark.parametrize("name", list(_MODELS))
def test_staged_slots_match_schedule(name):
    """Staged ops: the packed scratch slots are disjoint, ordered, and the
    total the kernel allocates equals the schedule's resident rows."""
    _, bp = _bplan(_MODELS[name])
    ws = bp.window_schedule()
    by_name = {op.name: op for op in bp.order}
    sub = bp.tiling[0]
    chains = {}
    for op in bp.order:
        cname = op.params.get("fuse_chain")
        if cname is not None:
            chains.setdefault(cname, []).append(op)
    for w in ws.windows:
        if w.rolling:
            out = bp.layout_of(by_name[w.op_name].output)
            tile_ar = P.tile_arena_rows(out.cols_per_row, out.row_span, sub)
            assert w.resident_rows == 2 * (w.win_rows - tile_ar) + tile_ar
            continue
        if w.kind == "fused":
            # fused chains stage the ext inputs + terminal output alongside
            # the chain scratch: the window is the include_io slot total
            # (chain_rows_of applies the packed geometry to scratch tensors
            # exactly as the planner's own _fused_window does)
            members = chains[w.op_name]
            _, total = P.fused_slots(members, P.chain_rows_of(bp),
                                     round_to=sub, include_io=True)
            assert total == w.win_rows == w.resident_rows
            continue
        op = by_name[w.op_name]
        ins = [t for t in op.inputs if t.storage().kind != "weight"]
        rows = [bp.layout_of(t).rows for t in ins]
        out_rows = bp.layout_of(op.output).rows
        offs, out_slot, total = P.staged_slots(rows, out_rows, sub)
        assert total == w.win_rows == w.resident_rows
        cur = 0
        for o, r in zip(offs, rows):
            assert o == cur
            cur += r
        assert out_slot == cur and cur + out_rows <= total


def test_flagship_window_strictly_below_arena():
    """Acceptance: on the paper's flagship 8-bit rows the streaming VMEM
    ceiling (max_resident_bytes) is strictly smaller than what the
    VMEM-resident blocked program needs — the whole arena plus any fused
    chain scratch. Packing can shrink the arena *below* the rolling
    double-buffer (the window/arena row comparison loses meaning there),
    so the strict window-below-arena bound is asserted on the legacy
    layout and packing is held to never raising the streaming ceiling."""
    from repro.core.exec.pallas_backend import PallasExecutor
    for name in zoo.TABLE3_8BIT_MODELS:
        _, bp = _bplan(zoo.TABLE3_MODELS[name][0])
        ws = bp.window_schedule()
        leg = P.legalise_for_blocks(bp.source, packing="legacy")
        ws_leg = leg.window_schedule()
        assert ws_leg.max_window_rows < ws_leg.total_rows, name
        assert ws.max_resident_bytes <= ws_leg.max_resident_bytes, name
        specs = PallasExecutor(layout="blocks",
                               interpret=True).lower_blocks(leg)
        scratch = max((s.scratch_rows for s in specs if s.kind == "fused"),
                      default=0)
        compiled_need = (leg.total_rows + scratch) * leg.row_bytes
        assert ws_leg.max_resident_bytes < compiled_need, name
        assert bp.report().count("streaming windows:") == 1


def test_window_schedule_memoised():
    _, bp = _bplan(_MODELS["mobilenet_v1_0.25_32_f32"])
    assert bp.window_schedule() is bp.window_schedule()


# ---------------------------------------------------------------------------
# Kernel + backend layer: streaming parity
# ---------------------------------------------------------------------------


_PARITY = ("mobilenet_v1_0.25_32_f32", "mobilenet_v1_0.25_32_8bit",
           "mobilenet_v1_0.25_128_8bit", "stream_allops")


@pytest.mark.parametrize("name", _PARITY)
def test_streaming_parity(name):
    """mode="streaming" executes the zoo: bit-exact vs the row-blocked
    program (identical kernel bodies, DMA'd operands) and within tolerance
    vs the numpy arena backend (int8 <= 1 LSB via compare_outputs)."""
    cp, _ = _bplan(_MODELS[name])
    got_blk = X.get_backend("pallas", layout="blocks").execute(cp)
    got_st = X.get_backend("pallas", mode="streaming",
                           interpret=True).execute(cp)
    got_np = X.get_backend("numpy").execute(cp)
    X.compare_outputs(got_blk, got_st, exact=True,
                      label=f"{name} streaming vs blocked")
    X.compare_outputs(got_np, got_st, exact=False,
                      label=f"{name} streaming vs numpy")


def test_lower_stream_grafts_window_statics():
    from repro.core.exec.pallas_backend import PallasExecutor
    _, bp = _bplan(_MODELS["mobilenet_v1_0.25_32_8bit"])
    be = PallasExecutor(mode="streaming", interpret=True)
    specs = be.lower_stream(bp)
    ws = bp.window_schedule()
    assert len(specs) == len(ws.windows)
    for s, w in zip(specs, ws.windows):
        assert s.win_rows == w.win_rows > 0
        assert s.win_lo == w.lo
        assert s.win_starts == w.starts
        if s.kind in ("conv2d", "depthwise_conv2d", "pool"):
            assert s.win_starts, f"{s.kind} should roll"
    # the blocked lowering stays streaming-free
    assert all(s.win_rows == 0 for s in be.lower_blocks(bp))


# ---------------------------------------------------------------------------
# Plumbing: modes, layouts, budgets
# ---------------------------------------------------------------------------


def test_streaming_mode_plumbing(monkeypatch):
    from repro.core.exec.pallas_backend import PallasExecutor
    with pytest.raises(ValueError, match="unknown pallas mode"):
        PallasExecutor(mode="stream")
    with pytest.raises(ValueError, match="row-blocked"):
        PallasExecutor(mode="streaming", layout="flat")
    # interpret-ness: pinned beats the env switch, else the switch decides
    assert PallasExecutor(mode="streaming", interpret=True).interpret
    monkeypatch.setenv("REPRO_DMO_INTERPRET", "0")
    assert not PallasExecutor(mode="streaming").interpret
    assert not PallasExecutor(mode="streaming", interpret=False).interpret
    monkeypatch.setenv("REPRO_DMO_INTERPRET", "1")
    assert PallasExecutor(mode="streaming").interpret


def test_streaming_refuses_over_budget_window():
    """The streaming gate is the *window*, not the arena: a budget between
    the two refuses compiled-style whole-arena residency but admits
    streaming; a budget below the window refuses streaming too."""
    from repro.core.exec.pallas_backend import PallasExecutor
    # 96px v2 build: big enough that the double-buffered resident scratch
    # is strictly below the compiled-mode need — the packed layouts shrink
    # the mobilenet_v1 arenas to the point where the two tie
    cp, bp = _bplan(lambda: zoo.mobilenet_v2(0.35, 96, 1))
    ws = bp.window_schedule()
    # compiled mode must keep the whole arena plus any fused chain scratch
    # resident; streaming only the largest window
    specs = PallasExecutor(layout="blocks", interpret=True).lower_blocks(bp)
    scratch = max((s.scratch_rows for s in specs if s.kind == "fused"),
                  default=0)
    compiled_need = (bp.total_rows + scratch) * bp.row_bytes
    assert ws.max_resident_bytes < compiled_need
    with pytest.raises(ValueError, match="does not fit VMEM"):
        PallasExecutor(mode="streaming", interpret=True,
                       vmem_budget=ws.max_resident_bytes - 1).execute(cp)
    with pytest.raises(ValueError, match="streaming"):
        PallasExecutor(mode="compiled",
                       vmem_budget=compiled_need - 1).execute(cp)
    # between window and compiled need: streaming executes where compiled
    # refuses
    out = PallasExecutor(mode="streaming", interpret=True,
                         vmem_budget=compiled_need - 1).execute(cp)
    ref = X.get_backend("numpy").execute(cp)
    X.compare_outputs(ref, out, exact=False, label="budget-admitted stream")


def test_budget_env_knob(monkeypatch):
    from repro.core.exec import pallas_backend as PB
    be = PB.PallasExecutor(mode="streaming", interpret=True)
    assert be._resolve_budget() == PB.DEFAULT_VMEM_BUDGET
    monkeypatch.setenv("REPRO_DMO_VMEM_BUDGET", "4096")
    assert be._resolve_budget() == 4096
    assert PB.PallasExecutor(vmem_budget=99)._resolve_budget() == 99


def test_verify_pass_covers_streaming_tier():
    """Compiling for backend="pallas" now cross-checks the streaming tier
    too (the acceptance path CPU CI runs)."""
    cp = compile_graph(_MODELS["mobilenet_v1_0.25_32_8bit"](),
                      backend="pallas", verify="numeric")
    assert any("streaming" in line for line in cp.log), cp.log
    assert cp.verified == "numeric+pallas"
