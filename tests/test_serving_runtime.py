"""Plan-routed serving runtime (PR 10): FastExec + PlanServer.

- FastExec: the vectorised batched executor matches the reference backend
  per image — f32 within the shared fp32 tolerance (BLAS may reassociate
  the accumulations), int8 <= 1 LSB (its float64 accumulation reproduces
  the reference int32 accumulation exactly);
- PlanServer: batch-variant compilation, arena-budget admission and
  rejection, deadline batching + forced drain with tail padding, correct
  per-request outputs, timing spans and the stats surface;
- throughput_demo: the closed-loop demo the benchmark harness embeds.
"""
from __future__ import annotations

import numpy as np
import pytest

from test_batching import band_graph
from repro.core import exec as X
from repro.core import zoo
from repro.core.exec.numpy_backend import run_reference
from repro.core.pipeline import compile as compile_graph
from repro.serve import FastExec, PlanServer, throughput_demo


def _images(graph, n, quant=None):
    """n per-image input dicts (int8 tensors pre-quantised when a spec is
    given — FastExec also accepts raw floats and quantises itself)."""
    return [(X.quant_inputs(graph, quant, seed=i) if quant is not None
             else X.random_inputs(graph, seed=i)) for i in range(n)]


# ---------------------------------------------------------------------------
# FastExec parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("db", [4, 1])
@pytest.mark.parametrize("batch", [1, 3, 8])
def test_fastexec_matches_reference(db, batch):
    g = band_graph(db=db)
    fx = FastExec(g, seed=0)
    imgs = _images(g, batch, fx.quant)
    stacked = {k: np.stack([im[k] for im in imgs]) for k in imgs[0]}
    got = fx.run(stacked)
    for i, im in enumerate(imgs):
        ref = run_reference(g, im, weights=fx.weights, quant=fx.quant)
        for k, v in ref.items():
            if v.dtype == np.int8:
                diff = np.abs(got[k][i].astype(np.int32)
                              - v.astype(np.int32))
                assert diff.max(initial=0) <= 1, f"img {i} {k}"
            else:
                np.testing.assert_allclose(got[k][i], v, rtol=X.FP32_RTOL,
                                           atol=X.FP32_ATOL)


def test_fastexec_quantises_float_inputs():
    from repro.core.exec.ops import quantise
    g = band_graph(db=1)
    fx = FastExec(g, seed=0)
    floats = X.random_inputs(g, seed=0)
    out_f = fx.run(floats)
    out_q = fx.run({k: quantise(v, fx.quant.tensors[k])
                    for k, v in floats.items()})
    for k in out_f:
        assert np.array_equal(out_f[k], out_q[k])


def test_fastexec_flagship_model():
    g = zoo.mobilenet_v1(0.25, 32, 1)
    fx = FastExec(g, seed=0)
    imgs = _images(g, 2, fx.quant)
    stacked = {k: np.stack([im[k] for im in imgs]) for k in imgs[0]}
    got = fx.run(stacked)
    for i, im in enumerate(imgs):
        ref = run_reference(g, im, weights=fx.weights, quant=fx.quant)
        for k, v in ref.items():
            diff = np.abs(got[k][i].astype(np.int32) - v.astype(np.int32))
            assert diff.max(initial=0) <= 1


# ---------------------------------------------------------------------------
# PlanServer
# ---------------------------------------------------------------------------


def test_server_routes_to_largest_variant():
    srv = PlanServer(band_graph(), batches=(1, 2, 4), max_delay_s=10.0)
    for im in _images(srv.graph, 4):
        srv.submit(im)
    assert srv.step() == 4                 # full largest variant: no wait
    st = srv.stats()
    assert st["batches_run"] == {1: 0, 2: 0, 4: 1}
    assert st["requests_served"] == 4 and st["queued"] == 0
    assert st["throughput_inf_s"] is None or st["throughput_inf_s"] > 0


def test_server_deadline_and_padded_tail():
    srv = PlanServer(band_graph(), batches=(2, 4), max_delay_s=10.0)
    srv.submit(_images(srv.graph, 1)[0])
    assert srv.step() == 0                 # deadline not reached: hold
    assert srv.drain() == 1                # forced: pad up to the b=2 plan
    r = srv.done[0]
    assert r.batch == 2 and r.output is not None


def test_server_outputs_match_reference():
    g = band_graph(db=1)
    srv = PlanServer(g, batches=(1, 2, 4), max_delay_s=0.0)
    imgs = _images(g, 5, srv._exec.quant)
    for im in imgs:
        srv.submit(im)
    srv.drain()
    assert len(srv.done) == 5
    by_rid = {r.rid: r for r in srv.done}
    for i, im in enumerate(imgs):
        ref = run_reference(g, im, weights=srv._exec.weights,
                            quant=srv._exec.quant)
        for k, v in ref.items():
            diff = np.abs(by_rid[i].output[k].astype(np.int32)
                          - v.astype(np.int32))
            assert diff.max(initial=0) <= 1


def test_server_budget_admission():
    mk = lambda: band_graph(db=1)          # noqa: E731
    p1 = compile_graph(mk(), batch=1).peak_bytes
    p4 = compile_graph(mk(), batch=4).peak_bytes
    assert p1 < p4
    srv = PlanServer(mk(), arena_budget=(p1 + p4) // 2, batches=(1, 4))
    assert sorted(srv.variants) == [1]
    assert 4 in srv.rejected and srv.rejected[4] == p4
    st = srv.stats()
    assert st["per_batch_peak_bytes"] == {1: p1}
    assert st["rejected_batches"] == {4: p4}


def test_server_no_variant_fits():
    with pytest.raises(ValueError, match="admits no batch variant"):
        PlanServer(band_graph(), arena_budget=1, batches=(1, 2))


def test_server_spans_and_cache_stats():
    srv = PlanServer(band_graph(), batches=(1, 2), max_delay_s=0.0)
    for im in _images(srv.graph, 3):
        srv.submit(im)
        srv.step(force=True)
    spans = srv.spans()
    assert len(spans) == 3
    for s in spans:
        assert set(s) == {"rid", "batch", "t_submit", "queue_wait_s",
                          "assemble_s", "execute_s"}
        assert s["queue_wait_s"] >= 0 and s["execute_s"] > 0
    st = srv.stats()
    assert st["plan_cache"]["hits"] + st["plan_cache"]["misses"] >= 2
    assert st["plan_cache"]["hit_rate"] is not None
    # a second server over the same graph is served from the plan cache
    srv2 = PlanServer(band_graph(), batches=(1, 2), max_delay_s=0.0)
    assert srv2.stats()["plan_cache"]["hit_rate"] == 1.0


def test_throughput_demo_smoke():
    st = throughput_demo(band_graph(db=1), n_requests=32,
                         batches=(1, 2, 4, 8))
    assert st["requests_served"] == 32
    assert st["queued"] == 0
    assert st["throughput_inf_s"] and st["throughput_inf_s"] > 0
    assert sum(b * n for b, n in st["batches_run"].items()) >= 32
