"""O_s calculators: bottom-up trace == algorithmic, analytic is a lower
bound, and the paper's Table I/II values are reproduced exactly."""
import numpy as np
import pytest

from repro.core.graph import Graph, conv_out_dim
from repro.core.overlap import (safe_overlap, safe_overlap_algorithmic,
                                safe_overlap_analytic, safe_overlap_trace)
from repro.core.overlap.analytic import (_conv_family_constants,
                                         _min_diff_piecewise,
                                         paper_closed_form)


def conv_graph(ih, iw, ic, oc, k, s, padding="same", kind="conv2d", mult=1):
    g = Graph("t")
    x = g.tensor("x", (ih, iw, ic), 4, "input")
    oh, ow = conv_out_dim(ih, k, s, padding), conv_out_dim(iw, k, s, padding)
    od = oc if kind == "conv2d" else ic * mult
    params = dict(kernel=(k, k), stride=(s, s), padding=padding)
    if kind == "depthwise_conv2d":
        params["multiplier"] = mult
    g.op(kind, [x], (oh, ow, od), params, out_kind="output")
    return g.ops[0]


CASES = [
    ("conv2d", dict(ih=12, iw=10, ic=3, oc=8, k=3, s=2)),
    ("conv2d", dict(ih=9, iw=9, ic=4, oc=4, k=3, s=1, padding="valid")),
    ("conv2d", dict(ih=14, iw=7, ic=2, oc=6, k=1, s=1)),
    ("conv2d", dict(ih=8, iw=8, ic=3, oc=12, k=5, s=1)),
    ("depthwise_conv2d", dict(ih=12, iw=10, ic=3, oc=None, k=3, s=2, mult=2)),
    ("depthwise_conv2d", dict(ih=10, iw=10, ic=4, oc=None, k=3, s=1)),
    ("pool", dict(ih=8, iw=8, ic=4, oc=None, k=2, s=2)),
    ("pool", dict(ih=9, iw=9, ic=2, oc=None, k=3, s=1)),
]


@pytest.mark.parametrize("kind,args", CASES)
def test_trace_equals_algorithmic(kind, args):
    kw = dict(args)
    op = conv_graph(kw.pop("ih"), kw.pop("iw"), kw.pop("ic"), kw.pop("oc"),
                    kw.pop("k"), kw.pop("s"), kw.pop("padding", "same"),
                    kind, kw.pop("mult", 1))
    assert safe_overlap_trace(op) == safe_overlap_algorithmic(op)


@pytest.mark.parametrize("kind,args", CASES)
def test_analytic_is_lower_bound(kind, args):
    kw = dict(args)
    op = conv_graph(kw.pop("ih"), kw.pop("iw"), kw.pop("ic"), kw.pop("oc"),
                    kw.pop("k"), kw.pop("s"), kw.pop("padding", "same"),
                    kind, kw.pop("mult", 1))
    exact = safe_overlap_algorithmic(op)
    est = safe_overlap_analytic(op)
    assert est is not None
    assert 0 <= est <= exact <= op.output.nbytes


def test_paper_table1_table2_exact():
    """dwconv (112,112,96)->(56,56,96) k3 s2: exact 1204224, est 1193376."""
    op = conv_graph(112, 112, 96, None, 3, 2, "same", "depthwise_conv2d")
    assert safe_overlap_algorithmic(op) == 1204224
    assert safe_overlap_analytic(op) == 1193376
    # paper quotes the 10848-byte underestimate as 0.18 % of the model's
    # (MobileNet v2 1.0 224) original peak memory of 5880 KB
    err = 100 * (1204224 - 1193376) / (5880 * 1024)
    assert err == pytest.approx(0.18, abs=1e-2)


def test_paper_closed_form_matches_piecewise():
    for kind, args in CASES:
        kw = dict(args)
        op = conv_graph(kw.pop("ih"), kw.pop("iw"), kw.pop("ic"),
                        kw.pop("oc"), kw.pop("k"), kw.pop("s"),
                        kw.pop("padding", "same"), kind, kw.pop("mult", 1))
        a, b, ic = _conv_family_constants(op)
        got = min(0.0, _min_diff_piecewise(a, b, ic))
        paper = min(0.0, paper_closed_form(a, b, ic))
        assert got == pytest.approx(paper)


def test_elementwise_in_place_and_matmul_zero():
    g = Graph("e")
    x = g.tensor("x", (32, 16), 4, "input")
    o = g.op("elementwise", [x], (32, 16), dict(fn="relu"))
    assert safe_overlap(g.ops[0], method="algorithmic") == o.nbytes
    assert safe_overlap(g.ops[0], method="analytic") == o.nbytes
    assert safe_overlap(g.ops[0], method="trace") == o.nbytes

    g2 = Graph("m")
    y = g2.tensor("y", (64,), 4, "input")
    g2.op("fully_connected", [y], (32,))
    assert safe_overlap(g2.ops[0], method="analytic") == 0
    # algorithmic: one trailing element of slack at most
    assert safe_overlap(g2.ops[0], method="algorithmic") <= 4


def test_softmax_and_mean_full_overlap():
    g = Graph("s")
    x = g.tensor("x", (10, 50), 4, "input")
    o = g.op("softmax", [x], (10, 50))
    assert safe_overlap(g.ops[0], method="algorithmic") == o.nbytes
    g2 = Graph("mn")
    y = g2.tensor("y", (6, 6, 8), 4, "input")
    o2 = g2.op("mean", [y], (8,), dict(axes=(0, 1)))
    assert safe_overlap(g2.ops[0], method="algorithmic") == o2.nbytes


def test_paper_profile_restricts_kinds():
    g = Graph("c")
    a = g.tensor("a", (4, 4, 8), 4, "input")
    b = g.tensor("b", (4, 4, 8), 4, "input")
    g.op("concat", [a, b], (4, 4, 16), dict(axis=-1))
    assert safe_overlap(g.ops[0], 0, profile="paper") == 0
    assert safe_overlap(g.ops[0], 1, profile="extended") > 0
