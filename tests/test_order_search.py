"""Joint execution-order x overlap search (linearisation-aware DMO).

Covers: the Kahn ready-queue serialisation rewrites (bit-identical to the
historical quadratic rescans), the ``OrderMoves`` legality oracle, the
incremental ``LivePeakEstimator``, ``plan_joint`` (the product-space ILS —
including a trap graph where order moves strictly beat every serialise
heuristic), the ``order_search`` pipeline pass with its never-regress
fallback, search-parameter cache-key correctness, and the hypothesis
property that ANY dependency-respecting linearisation plans safely at byte
and row granularity.
"""
import random

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import pipeline, zoo
from repro.core.graph import Graph
from repro.core.planner import (LivePeakEstimator, legalise_for_blocks,
                                live_bytes_profile, plan_dmo, plan_joint)
from repro.core.serialise import (OrderMoves, _deps, candidate_orders,
                                  eager_order, lazy_order,
                                  memory_greedy_order)
from repro.core.splitting import order_pinned


# ---------------------------------------------------------------------------
# Reference copies of the historical O(V^2 * E) serialisation loops — the
# Kahn rewrites must stay bit-identical to these
# ---------------------------------------------------------------------------


def _eager_reference(graph):
    deps = _deps(graph)
    done, order, pending = set(), [], list(graph.ops)
    while pending:
        for op in pending:
            if deps[op] <= done:
                order.append(op)
                done.add(op)
                pending.remove(op)
                break
        else:
            raise ValueError("cycle")
    return order


def _greedy_reference(graph):
    deps = _deps(graph)
    remaining = {}
    for op in graph.ops:
        for t in op.inputs:
            s = t.storage()
            if s.kind != "weight":
                remaining[s] = remaining.get(s, 0) + 1
    live = {t.storage() for t in graph.tensors if t.kind == "input"}
    done, order, pending = set(), [], list(graph.ops)
    while pending:
        ready = [op for op in pending if deps[op] <= done]

        def after(op):
            uses, nxt = dict(remaining), set(live)
            for t in op.outputs:
                s = t.storage()
                if s.kind != "weight":
                    nxt.add(s)
            for t in op.inputs:
                s = t.storage()
                if s in uses:
                    uses[s] -= 1
                    if uses[s] == 0 and s.kind not in ("input", "output"):
                        nxt.discard(s)
            return sum(t.nbytes for t in nxt)

        best = min(ready, key=lambda op: (after(op), pending.index(op)))
        order.append(best)
        done.add(best)
        pending.remove(best)
        for t in best.outputs:
            s = t.storage()
            if s.kind != "weight":
                live.add(s)
        for t in best.inputs:
            s = t.storage()
            if s in remaining:
                remaining[s] -= 1
                if remaining[s] == 0 and s.kind not in ("input", "output"):
                    live.discard(s)
    return order


@pytest.mark.parametrize("build", [
    zoo.squeezenet, zoo.inception_v4,
    lambda: zoo.mobilenet_v2(0.35, 96, 1),
])
def test_kahn_orders_bit_identical_to_quadratic_rescan(build):
    g = build()
    assert eager_order(g) == _eager_reference(g)
    assert memory_greedy_order(g) == _greedy_reference(g)


def test_kahn_orders_bit_identical_on_removal_views():
    """Aggregated-view writer graphs (§II.C removal) are where _deps is
    subtle — the rewrites must agree there too."""
    from repro.core.removal import remove_concats
    rg = remove_concats(zoo.squeezenet())
    assert eager_order(rg) == _eager_reference(rg)
    assert memory_greedy_order(rg) == _greedy_reference(rg)


# ---------------------------------------------------------------------------
# Move legality oracle
# ---------------------------------------------------------------------------


def _trap_graph():
    """Asymmetric diamond where every serialise heuristic (construction /
    eager / lazy / memory-greedy) picks a strictly suboptimal order for
    plan_dmo: the best linearisation interleaves the fat branch inside the
    thin one, which no myopic heuristic does."""
    conv = lambda k: dict(kernel=(k, k), stride=(1, 1), padding="same")
    g = Graph("order_trap")
    x = g.tensor("x", (8, 8, 8), 4, "input")
    a1 = g.op("conv2d", [x], (8, 8, 48), conv(3), name="a1")
    a2 = g.op("conv2d", [a1], (8, 8, 8), conv(1), name="a2")
    b1 = g.op("conv2d", [x], (8, 8, 2), conv(3), name="b1")
    b2 = g.op("conv2d", [b1], (8, 8, 40), conv(3), name="b2")
    b3 = g.op("conv2d", [b2], (8, 8, 8), conv(1), name="b3")
    c = g.op("concat", [a2, b3], (8, 8, 16), dict(axis=-1), name="cat")
    g.op("elementwise", [c], (8, 8, 16), dict(fn="relu"), name="out",
         out_kind="output")
    g.validate()
    return g


def test_order_moves_legality_oracle():
    g = _trap_graph()
    m = OrderMoves(g)
    order = list(g.ops)  # a1 a2 b1 b2 b3 cat out
    assert m.is_topological(order)
    # a2 and b1 are independent: swapping them is legal and stays topological
    assert m.legal_swap(order, 1)
    assert m.is_topological(m.swap(order, 1))
    # a1 -> a2 is a producer edge: the swap is illegal
    assert not m.legal_swap(order, 0)
    assert not m.is_topological(m.swap(order, 0))
    # block move: a1 may not hop past its consumer a2
    assert not m.legal_block_move(order, 0, 1)
    # b1 may move to the front (depends only on x)
    assert m.legal_block_move(order, 2, 0)
    assert m.is_topological(m.block_move(order, 2, 0))
    # cat may not move before its producers
    assert not m.legal_block_move(order, 5, 3)


def test_block_move_legality_matches_is_topological_exhaustively():
    g = _trap_graph()
    m = OrderMoves(g)
    order = list(g.ops)
    n = len(order)
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            assert m.legal_block_move(order, i, j) == \
                m.is_topological(m.block_move(order, i, j)), (i, j)


def test_adjacent_swap_legality_matches_is_topological():
    g = zoo.squeezenet()
    m = OrderMoves(g)
    order = eager_order(g)
    for i in range(len(order) - 1):
        assert m.legal_swap(order, i) == m.is_topological(m.swap(order, i))


def test_random_topological_respects_deps():
    g = zoo.squeezenet()
    m = OrderMoves(g)
    rng = random.Random(7)
    sigs = set()
    for _ in range(10):
        o = m.random_topological(rng)
        assert m.is_topological(o)
        sigs.add(m.signature(o))
    assert len(sigs) > 1, "sampler collapsed to one order"


# ---------------------------------------------------------------------------
# Incremental live-peak estimator
# ---------------------------------------------------------------------------


def test_estimator_incremental_matches_full_recompute():
    g = zoo.squeezenet()
    m = OrderMoves(g)
    order = eager_order(g)
    est = LivePeakEstimator(g, order)
    assert est._bytes_at == live_bytes_profile(g, order)
    rng = random.Random(3)
    for step in range(300):
        legal = m.legal_swaps(order)
        if not legal:
            break
        i = legal[rng.randrange(len(legal))]
        order = m.swap(order, i)
        est.swap(i)
    ref = live_bytes_profile(g, order)
    assert est._bytes_at == ref
    assert est.peak == max(ref)


def test_estimator_swap_is_its_own_inverse():
    g = _trap_graph()
    m = OrderMoves(g)
    order = list(g.ops)
    est = LivePeakEstimator(g, order)
    before = list(est._bytes_at)
    i = m.legal_swaps(order)[0]
    est.swap(i)
    est.swap(i)
    assert est._bytes_at == before


# ---------------------------------------------------------------------------
# plan_joint: the product-space ILS
# ---------------------------------------------------------------------------


def _fixed_best(g):
    return min(plan_dmo(g, o, method="algorithmic").peak_bytes
               for o in [list(g.ops)] + candidate_orders(g))


def test_joint_beats_every_heuristic_order_on_trap_graph():
    """The order axis is real: on the trap graph the joint search finds a
    linearisation whose planned peak is strictly below the best fixed-order
    plan_dmo over construction + eager + lazy + memory-greedy orders."""
    g = _trap_graph()
    fixed = _fixed_best(g)
    plan, stats = plan_joint(g, method="algorithmic", budget_s=2.0, seed=0,
                             max_rounds=400)
    plan.validate()
    assert plan.peak_bytes < fixed
    assert stats["order_changed"]
    assert stats["order_accepts"] > 0


def test_joint_degenerates_to_placement_ils_on_sequential_graph():
    """No legal swap -> the loop must spend its whole budget on placement
    moves (exactly plan_search's neighbourhood)."""
    g = zoo.mobilenet_v1(0.25, 96)
    assert not OrderMoves(g).legal_swaps(eager_order(g))
    plan, stats = plan_joint(g, method="algorithmic", budget_s=0.5, seed=0,
                             max_rounds=150)
    plan.validate()
    assert stats["order_moves"] == 0
    assert stats["placement_moves"] == stats["rounds"]
    assert not stats["order_changed"]


def test_joint_is_deterministic_under_fixed_rounds():
    g = _trap_graph()
    runs = [plan_joint(g, method="algorithmic", budget_s=30.0, seed=5,
                       max_rounds=200) for _ in range(2)]
    (p1, s1), (p2, s2) = runs
    assert [op.name for op in p1.order] == [op.name for op in p2.order]
    assert {t.name: x for t, x in p1.offsets.items()} == \
        {t.name: x for t, x in p2.offsets.items()}
    assert s1["evals"] == s2["evals"]


def test_joint_winner_legalises_at_row_granularity():
    g = _trap_graph()
    plan, _ = plan_joint(g, method="algorithmic", budget_s=1.0, seed=0,
                         max_rounds=300)
    plan.validate()
    bp = legalise_for_blocks(plan)
    bp.validate()


# ---------------------------------------------------------------------------
# Pipeline wiring: the order_search pass
# ---------------------------------------------------------------------------


def test_order_search_pass_runs_and_never_regresses():
    g = _trap_graph()
    # with the full pipeline the search may not beat the split variant, but
    # it must never regress past the fixed-order winner
    cp = pipeline.compile(g, budget_s=1.5, cache=False)
    assert cp.order_stats is not None
    assert any("order_search: joint ILS" in line for line in cp.log)
    assert cp.peak_bytes <= cp.order_stats["fixed_peak"]
    # split disabled: the trap is live and the order-axis win is strict
    cp = pipeline.compile(g, budget_s=1.5, split="off", cache=False)
    assert cp.peak_bytes < cp.order_stats["fixed_peak"]
    assert cp.plan.strategy.startswith("joint")
    assert cp.order_stats["order_changed"]


def test_order_search_off_restores_placement_only_pipeline():
    g = _trap_graph()
    cp = pipeline.compile(g, budget_s=0.5, order_search="off", cache=False)
    assert cp.order_stats is None
    assert any("order_search: disabled" in line for line in cp.log)
    assert any("plan: ILS search" in line for line in cp.log)


def test_order_search_skipped_without_budget():
    cp = pipeline.compile(_trap_graph(), budget_s=0.0, cache=False)
    assert cp.order_stats is None
    assert any("order_search: skipped" in line for line in cp.log)


def test_order_search_forced_on_gets_floor_budget():
    cp = pipeline.compile(_trap_graph(), budget_s=0.0, order_search="on",
                          cache=False)
    assert cp.order_stats is not None
    assert cp.order_stats["budget_s"] == 1.0


def test_order_search_tie_falls_back_to_fixed_order_plan():
    """A sequential 8-bit model where search finds nothing better: the
    winner must be the fixed-order plan (not a joint re-plan of equal
    peak), keeping plans stable when the search contributes nothing."""
    g = zoo.mobilenet_v1(0.25, 128, dtype_bytes=1)
    cp = pipeline.compile(g, budget_s=0.3, split="off", cache=False)
    assert cp.order_stats is not None
    if cp.peak_bytes == cp.order_stats["fixed_peak"]:
        assert not cp.plan.strategy.startswith("joint")


def test_order_pinned_detection():
    g = _trap_graph()
    assert not order_pinned(g)
    g.ops[0].params["fuse_chain"] = "c0"
    assert order_pinned(g)


def test_unknown_order_search_mode_rejected():
    with pytest.raises(ValueError, match="order_search"):
        pipeline.compile(_trap_graph(), order_search="maybe")


# ---------------------------------------------------------------------------
# Cache-key correctness: search parameters are part of the plan-cache key
# ---------------------------------------------------------------------------


def test_search_parameters_are_cache_keyed():
    g = _trap_graph
    pipeline.cache_clear()
    base = dict(budget_s=0.2)
    assert not pipeline.compile(g(), **base).cache_hit  # cold
    assert pipeline.compile(g(), **base).cache_hit      # warm repeat
    # different seed: a different stochastic search -> cold compile
    assert not pipeline.compile(g(), budget_s=0.2, seed=1).cache_hit
    # different budget tier: cold
    assert not pipeline.compile(g(), budget_s=0.3).cache_hit
    # order search toggled off: cold
    assert not pipeline.compile(g(), budget_s=0.2,
                                order_search="off").cache_hit
    # and each of those is itself cached on repeat
    assert pipeline.compile(g(), budget_s=0.2, seed=1).cache_hit
    assert pipeline.compile(g(), budget_s=0.2, order_search="off").cache_hit


# ---------------------------------------------------------------------------
# Parity: the joint-search winner executes identically on both backends
# ---------------------------------------------------------------------------


def test_joint_winner_executes_with_parity_on_both_backends():
    """f32: numpy arena vs pallas flat/blocked must match bit-for-bit /
    within fp32 tolerance on the joint-search winner (order changed!) —
    VerifyPass's pallas tier asserts exactly that during compile, and we
    re-execute on both backends to compare outputs directly."""
    import numpy as np

    g = _trap_graph()
    cp = pipeline.compile(g, budget_s=1.5, split="off", backend="pallas",
                          verify="numeric", cache=False)
    assert cp.verified == "numeric+pallas"
    assert cp.plan.strategy.startswith("joint")  # the searched plan won
    assert cp.order_stats["order_changed"]  # with a genuinely new order
    out_np = cp.execute(backend="numpy")
    out_pl = cp.execute(backend="pallas")
    assert set(out_np) == set(out_pl)
    for k in out_np:
        np.testing.assert_allclose(out_np[k], out_pl[k], rtol=1e-5,
                                   atol=1e-5)


def test_joint_search_int8_winner_parity():
    """int8 tier: the searched plan of an 8-bit graph still verifies on the
    numpy arena (bit-exact vs reference) through the same pipeline."""
    g = zoo.mobilenet_v1(0.25, 96, dtype_bytes=1)
    cp = pipeline.compile(g, budget_s=0.5, split="off", verify="numeric",
                          cache=False)
    assert cp.verified == "numeric"
    assert cp.order_stats is not None


# ---------------------------------------------------------------------------
# Property: ANY dependency-respecting linearisation plans safely
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_any_linearisation_plans_safely_at_byte_and_row_granularity(seed):
    """The §II.D safety argument is order-independent: whatever
    dependency-respecting linearisation the search visits, the planned
    overlaps must survive Plan.validate() at byte granularity AND the
    row-blocked legaliser's exact row-extent check."""
    g = zoo.squeezenet()
    order = OrderMoves(g).random_topological(random.Random(seed))
    plan = plan_dmo(g, order, method="algorithmic")
    plan.validate()
    legalise_for_blocks(plan).validate()
