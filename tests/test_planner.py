"""Planners: constraint validation, numeric arena verification, and the
paper's MobileNet numbers."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import zoo
from repro.core.arena import verify_plan
from repro.core.graph import Graph
from repro.core.planner import (plan_dmo, plan_greedy_size,
                                plan_modified_heap, plan_naive,
                                plan_original, plan_search)


def mini_net(seed=0, depth=4):
    rng = np.random.default_rng(seed)
    g = Graph("mini")
    h = g.tensor("x", (10, 10, 3), 4, "input")
    c = 3
    for i in range(depth):
        kind = rng.choice(["conv2d", "depthwise_conv2d", "elementwise", "pool"])
        ih, iw, _ = h.shape
        if kind == "conv2d":
            c2 = int(rng.integers(2, 8))
            s = int(rng.integers(1, 3))
            h = g.op("conv2d", [h],
                     (-(-ih // s), -(-iw // s), c2),
                     dict(kernel=(3, 3), stride=(s, s), padding="same"),
                     name=f"op{i}")
            c = c2
        elif kind == "depthwise_conv2d":
            h = g.op("depthwise_conv2d", [h], (ih, iw, c),
                     dict(kernel=(3, 3), stride=(1, 1), padding="same"),
                     name=f"op{i}")
        elif kind == "pool" and ih >= 2 and iw >= 2:
            h = g.op("pool", [h], (ih // 2, iw // 2, c),
                     dict(kernel=(2, 2), stride=(2, 2), padding="valid",
                          mode="avg"), name=f"op{i}")
        else:
            h = g.op("elementwise", [h], h.shape, dict(fn="relu"),
                     name=f"op{i}")
    g.op("softmax", [g.op("fully_connected",
                          [g.op("reshape", [h], (h.elems,), name="flat")],
                          (7,), name="fc")], (7,), name="sm",
         out_kind="output")
    g.validate()
    return g


@pytest.mark.parametrize("seed", range(6))
def test_plans_validate_and_execute_bit_exact(seed):
    g = mini_net(seed)
    for plan in (plan_naive(g), plan_greedy_size(g),
                 plan_dmo(g, method="algorithmic")):
        plan.validate()
        verify_plan(g, plan)   # numeric: arena exec == private buffers


@pytest.mark.parametrize("seed", range(4))
def test_dmo_never_worse(seed):
    g = mini_net(seed)
    assert plan_dmo(g).peak_bytes <= plan_original(g).peak_bytes


def test_modified_heap_directions():
    g = mini_net(1)
    for d in ("forward", "backward"):
        p = plan_modified_heap(g, direction=d)
        p.validate()


def test_mobilenet_v1_edge_paper_numbers():
    """The paper's flagship: MobileNet v1 0.25 128 8-bit, 96 KB -> 64 KB."""
    g = zoo.mobilenet_v1(0.25, 128, 1)
    orig = plan_original(g)
    assert orig.peak_bytes == 96 * 1024
    opt = plan_search(g, method="algorithmic", budget_s=8.0)
    opt.validate()
    # ILS is stochastic under a small time budget: allow <=1.6 % slack over
    # the paper's 64 KB (benchmarks/table3 reproduces 64.0 exactly at 12 s)
    assert opt.peak_bytes <= 65 * 1024


def test_mobilenet_v2_paper_numbers():
    g = zoo.mobilenet_v2(0.35, 224, 4)
    orig = plan_original(g)
    assert orig.peak_bytes == 2940 * 1024
    opt = plan_dmo(g, method="algorithmic")
    opt.validate()
    assert opt.peak_bytes <= 2353 * 1024  # paper: 2352 KB (+1 KB tolerance)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_search_plans_always_safe(seed):
    g = mini_net(seed % 7, depth=3)
    p = plan_search(g, budget_s=0.3, seed=seed)
    p.validate()
    verify_plan(g, p)


def test_serialisation_orders_are_valid_and_help():
    """Eager/lazy/memory-greedy orders are topologically valid; planning over
    candidate orders never hurts (paper §II.B)."""
    from repro.core.serialise import candidate_orders
    from repro.core.planner import best_plan
    from repro.core import zoo
    g = zoo.inception_resnet_v2(299, 4)
    orders = candidate_orders(g)
    assert len(orders) >= 2
    for order in orders:
        seen = set()
        avail = {t.storage() for t in g.tensors if t.kind in ("input", "weight")}
        for op in order:
            for t in op.inputs:
                assert t.storage() in avail, f"{op.name} before producer"
            for t in op.outputs:
                avail.add(t.storage())
            seen.add(op)
        assert len(seen) == len(g.ops)


def test_extended_profile_never_worse_than_paper_profile():
    from repro.core import zoo
    g = zoo.mobilenet_v2(0.35, 224, 4)
    a = plan_dmo(g, method="algorithmic", profile="paper").peak_bytes
    b = plan_dmo(g, method="algorithmic", profile="extended").peak_bytes
    assert b <= a * 1.01  # extended adds overlap options (heuristics may tie)
