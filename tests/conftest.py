import os
import sys

# tests see the single real CPU device (the 512-device override lives ONLY
# in repro.launch.dryrun; subprocess tests set their own XLA_FLAGS)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root: lets tests import the benchmark modules (benchmarks.table3_...)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
