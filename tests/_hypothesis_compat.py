"""Import-or-shim for ``hypothesis`` so tier-1 collection works offline.

When hypothesis is installed, this re-exports the real ``given`` /
``settings`` / ``strategies``. When it is not (air-gapped CI image), the
shims keep module import working — strategy constructors become no-ops and
``@given`` replaces the test with a clean skip — so the *non-property* tests
in the same module still collect and run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _NullStrategies:
        """Stand-in for ``hypothesis.strategies``: every strategy
        constructor accepts anything and returns None (the values are never
        drawn — the test body is replaced by a skip)."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            strategy.__name__ = name
            return strategy

    st = _NullStrategies()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed (offline environment)")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco
