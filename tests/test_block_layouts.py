"""Row-blocked arena layouts: the block-granular plan legaliser
(`legalise_for_blocks`), its tiling invariants over the zoo, row-blocked
Pallas execution parity against the flat program and the numpy backend
(f32 + int8), unsafe-overlap negatives at row granularity, and the
compiled-mode / interpret-mode plumbing."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import exec as X
from repro.core import planner as P
from repro.core import zoo
from repro.core.arena import run_reference
from repro.core.graph import Graph
from repro.core.planner import (BlockLayout, BlockPlan, TPU_TILES,
                                legalise_for_blocks, plan_dmo, plan_greedy_size)

pytestmark = pytest.mark.filterwarnings("ignore:.*donated.*")


def small_conv_graph(dtype_bytes=4):
    g = Graph("smallconv")
    x = g.tensor("x", (8, 8, 4), dtype_bytes, "input")
    h = g.op("conv2d", [x], (8, 8, 6),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    h = g.op("pool", [h], (4, 4, 6),
             dict(kernel=(2, 2), stride=(2, 2), padding="valid", mode="max"))
    g.op("elementwise", [h], (4, 4, 6), dict(fn="relu"), out_kind="output")
    g.validate()
    return g


def _assert_block_invariants(bp: BlockPlan):
    sub, lanes = bp.tiling
    assert bp.arena_rowlen % lanes == 0       # lane-tiled arena row
    assert bp.total_rows % sub == 0           # sublane-tiled arena height
    for t, lay in bp.layouts.items():
        assert isinstance(lay, BlockLayout)
        assert lay.row_offset % bp.row_align == 0, \
            f"{lay.name}: row offset {lay.row_offset} not " \
            f"{bp.row_align}-aligned"
        assert lay.row_offset + lay.rows <= bp.total_rows
        assert 0 < lay.rowlen <= bp.arena_rowlen
        assert lay.rows * lay.rowlen >= lay.elems
        # byte plan view stays consistent with the block view
        assert bp.offsets[t] == lay.row_offset * bp.row_bytes
    assert bp.padded_peak_bytes >= (bp.source or bp).peak_bytes
    bp.validate()  # byte-level + row-granular no-clobber


# ---------------------------------------------------------------------------
# The legaliser over the whole zoo (acceptance: every f32 and int8 zoo model
# legalises to a row-blocked layout)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(zoo.TABLE3_MODELS))
def test_zoo_legalises_row_blocked(name):
    g = zoo.TABLE3_MODELS[name][0]()
    # one DMO strategy keeps the sweep affordable on the big connected
    # graphs; the flagship tests below use the full plan_dmo
    plan = plan_greedy_size(g, overlap_fn=P._default_overlap("algorithmic"))
    bp = legalise_for_blocks(plan)
    sub, lanes = TPU_TILES[g.tensors[0].dtype_bytes]
    assert bp.tiling == (sub, lanes)
    _assert_block_invariants(bp)
    assert bp.strategy.endswith("+blocks")
    # the padding-overhead line the report states
    assert f"+{bp.padding_overhead_pct:.1f}%" in bp.report()


@pytest.mark.parametrize("name", zoo.TABLE3_8BIT_MODELS)
def test_flagship_8bit_rows_legalise_with_bounded_padding(name):
    """Both flagship Table III rows legalise under the int8 (32, 128) tile
    with padding overhead within the bound table3_memory_savings states."""
    from benchmarks.table3_memory_savings import padding_bound_pct
    g = zoo.TABLE3_MODELS[name][0]()
    bp = legalise_for_blocks(plan_dmo(g))
    assert bp.tiling == TPU_TILES[1]
    _assert_block_invariants(bp)
    assert bp.padding_overhead_pct <= padding_bound_pct(name), \
        f"{name}: +{bp.padding_overhead_pct:.1f}% over stated bound"


@settings(max_examples=15, deadline=None)
@given(st.integers(5, 16), st.integers(5, 16), st.sampled_from([1, 2, 4]),
       st.sampled_from([3, 5]), st.integers(1, 2),
       st.sampled_from(["same", "valid"]), st.sampled_from([1, 4]))
def test_legalise_property_conv_chain(ih, iw, c, k, stride, padding, db):
    """Hypothesis-style: random small conv chains legalise with tile-aligned
    offsets and a row-granular validate pass, in both dtype tiers."""
    from repro.core.graph import conv_out_dim
    if ih + (2 if padding == "same" else 0) < k:
        return
    oh = conv_out_dim(ih, k, stride, padding)
    ow = conv_out_dim(iw, k, stride, padding)
    if oh < 1 or ow < 1:
        return
    g = Graph("prop")
    x = g.tensor("x", (ih, iw, c), db, "input")
    h = g.op("conv2d", [x], (oh, ow, c + 2),
             dict(kernel=(k, k), stride=(stride, stride), padding=padding))
    g.op("elementwise", [h], (oh, ow, c + 2), dict(fn="relu"),
         out_kind="output")
    g.validate()
    bp = legalise_for_blocks(plan_dmo(g))
    _assert_block_invariants(bp)


def test_legalise_rejects_mixed_dtype():
    g = Graph("mixed")
    a = g.tensor("a", (4, 4), 1, "input")
    b = g.tensor("b", (4, 4), 4, "input")
    g.op("elementwise", [a], (4, 4), dict(fn="relu"), out_kind="output")
    g.op("elementwise", [b], (4, 4), dict(fn="relu"), name="e2",
         out_kind="output")
    g.validate()
    with pytest.raises(ValueError, match="mixed-dtype"):
        legalise_for_blocks(plan_dmo(g))
    # and the pallas backend refuses blocks explicitly but auto-falls back
    with pytest.raises(ValueError, match="mixed-dtype"):
        X.get_backend("pallas", layout="blocks").execute(plan_dmo(g))
    X.cross_check(plan_dmo(g))  # auto layout falls back to the flat program


def test_legalise_rejects_aggregated_views():
    from repro.core.removal import remove_concats
    g = Graph("cat")
    x = g.tensor("x", (4, 4, 2), 4, "input")
    a = g.op("conv2d", [x], (4, 4, 2),
             dict(kernel=(1, 1), stride=(1, 1), padding="same"), name="a")
    b = g.op("conv2d", [x], (4, 4, 2),
             dict(kernel=(1, 1), stride=(1, 1), padding="same"), name="b")
    c = g.op("concat", [a, b], (4, 4, 4), dict(axis=-1))
    g.op("elementwise", [c], (4, 4, 4), dict(fn="relu"), out_kind="output")
    g.validate()
    rg = remove_concats(g)
    with pytest.raises(ValueError, match="views"):
        legalise_for_blocks(plan_dmo(rg))


def test_legalise_refuses_unsafe_source_plan():
    """The legaliser re-places tensors, so it must never silently repair a
    clobbering byte plan — verify_plan's negative contract survives the
    row-blocked path on both backends (see test_executors negatives)."""
    g = Graph("bad")
    x = g.tensor("x", (8, 8, 4), 4, "input")
    y = g.op("conv2d", [x], (8, 8, 8),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"),
             out_kind="output")
    bad = P.Plan(g, list(g.ops), {x.storage(): 0, y.storage(): 0}, {}, "bogus")
    with pytest.raises(AssertionError):
        legalise_for_blocks(bad)


def test_row_granular_validate_catches_shared_live_rows():
    """A hand-built BlockPlan whose tensors share live rows (beyond any
    recorded O_s) fails the row-granular validate and mis-executes on the
    row-blocked program — the §I verification at block granularity."""
    g = small_conv_graph()
    good = legalise_for_blocks(plan_dmo(g))
    # clone the good block plan but collapse every tensor onto row 0
    layouts = {t: BlockLayout(l.name, l.shape, l.dtype_bytes, 0, l.rows,
                              l.rowlen)
               for t, l in good.layouts.items()}
    bad = BlockPlan(good.graph, list(good.order),
                    {t: 0 for t in good.offsets}, {}, "bogus+blocks",
                    source=good.source, tiling=good.tiling,
                    arena_rowlen=good.arena_rowlen,
                    total_rows=good.total_rows, layouts=layouts)
    with pytest.raises(AssertionError):
        bad.validate()
    # executing the clobbering block layout yields wrong outputs
    inputs = X.random_inputs(g)
    weights = X.synth_weights(g)
    ref = run_reference(g, inputs, bad.order, weights=weights)
    got = X.get_backend("pallas").execute(bad, inputs, weights)
    with pytest.raises(AssertionError):
        X.compare_outputs(ref, got, exact=False, label="bogus blocks")
    # ... and the numpy backend clobbers at the same (byte-view) offsets
    got_np = X.get_backend("numpy").execute(bad, inputs, weights)
    with pytest.raises(AssertionError):
        X.compare_outputs(ref, got_np, exact=True, label="bogus blocks np")


def test_row_validate_checks_block_footprints_not_padded_bytes():
    """Image-layout tensors reserve H arena rows but pack fewer *bytes*
    than those rows hold, so a byte-granularity check under-counts them: a
    layout that is byte-disjoint yet interleaves reserved rows must still
    fail the block-footprint validate (the regression behind the
    ``_validate_rows`` override)."""
    g = Graph("rowclash")
    x = g.tensor("x", (8, 8, 4), 4, "input")
    g.op("conv2d", [x], (8, 8, 8),
         dict(kernel=(3, 3), stride=(1, 1), padding="same"),
         out_kind="output")
    good = legalise_for_blocks(plan_dmo(g))
    rb = good.row_bytes
    y = g.ops[0].output.storage()
    # y at rows [0, 8); x at rows [4, 12): rows 4..7 are shared while the
    # *byte* extents are disjoint (y's 2048 data bytes end exactly at x's
    # 4*rb = 2048 byte offset) — only the row-footprint walk can see it
    lay = dict(good.layouts)
    lay[y] = BlockLayout(y.name, y.shape, 4, 0, lay[y].rows, lay[y].rowlen)
    lay[x.storage()] = BlockLayout(x.name, x.shape, 4, 4,
                                   lay[x.storage()].rows,
                                   lay[x.storage()].rowlen)
    bad = BlockPlan(g, list(good.order), {y: 0, x.storage(): 4 * rb}, {},
                    "bogus+blocks", source=good.source, tiling=good.tiling,
                    arena_rowlen=good.arena_rowlen,
                    total_rows=good.total_rows + 8, layouts=lay)
    assert y.nbytes <= 4 * rb  # byte extents genuinely disjoint
    P.Plan.validate(bad)       # the byte-granular check cannot see it
    with pytest.raises(AssertionError, match="rows"):
        bad.validate()


# ---------------------------------------------------------------------------
# Row-blocked execution parity: blocked pallas vs flat pallas vs numpy
# ---------------------------------------------------------------------------

_PARITY_SWEEP = {
    "mobilenet_v1_0.25_32_f32": lambda: zoo.mobilenet_v1(0.25, 32, 4),
    "mobilenet_v1_0.25_32_8bit": lambda: zoo.mobilenet_v1(0.25, 32, 1),
    "mobilenet_v2_0.35_32_8bit": lambda: zoo.mobilenet_v2(0.35, 32, 1),
}


@pytest.mark.parametrize("name", list(_PARITY_SWEEP))
def test_row_blocked_parity_reduced_zoo(name):
    """Blocked program == flat program == numpy backend on reduced-res zoo
    builds, both dtype tiers (bit-exact numpy reference; <= 1 LSB int8 /
    fp32 tol on pallas)."""
    g = _PARITY_SWEEP[name]()
    plan = plan_dmo(g)
    assert plan.overlaps, "expected O_s overlaps to stress the layout"
    weights = X.synth_weights(g)
    quant = X.calibrate(g, 0, weights) if X.needs_quant(g) else None
    inputs = (X.quant_inputs(g, quant) if quant is not None
              else X.random_inputs(g))
    ref = run_reference(g, inputs, plan.order, weights=weights, quant=quant)
    blocked = X.get_backend("pallas", layout="blocks").execute(
        plan, inputs, weights, quant=quant)
    flat = X.get_backend("pallas", layout="flat").execute(
        plan, inputs, weights, quant=quant)
    numpy_ = X.get_backend("numpy").execute(plan, inputs, weights,
                                            quant=quant)
    X.compare_outputs(ref, numpy_, exact=True, label="numpy vs reference")
    X.compare_outputs(numpy_, flat, exact=False, label="flat vs numpy")
    X.compare_outputs(numpy_, blocked, exact=False, label="blocked vs numpy")
    X.compare_outputs(flat, blocked, exact=False, label="blocked vs flat")


@pytest.mark.parametrize("name", zoo.TABLE3_8BIT_MODELS)
def test_flagship_8bit_rows_blocked_parity(name):
    """Acceptance: both flagship 8-bit Table III rows (full resolution)
    execute the row-blocked Pallas program (interpret mode on CPU) and match
    the numpy backend to <= 1 LSB."""
    g = zoo.TABLE3_MODELS[name][0]()
    plan = plan_dmo(g)
    weights = X.synth_weights(g)
    quant = X.calibrate(g, 0, weights)
    inputs = X.quant_inputs(g, quant)
    got_np = X.get_backend("numpy").execute(plan, inputs, weights,
                                            quant=quant)
    got_blk = X.get_backend("pallas", layout="blocks").execute(
        plan, inputs, weights, quant=quant)
    for k in got_np:
        assert got_np[k].dtype == np.int8
        np.testing.assert_allclose(got_blk[k].astype(np.int32),
                                   got_np[k].astype(np.int32),
                                   rtol=0, atol=X.INT8_ATOL, err_msg=k)


def test_blocked_specs_lowering():
    """lower_blocks emits row-granular specs: row offsets + (rows, used)
    blocks, shared rowlen, no byte offsets."""
    g = small_conv_graph()
    bp = legalise_for_blocks(plan_dmo(g))
    be = X.get_backend("pallas", layout="blocks")
    specs = be.lower_blocks(bp)
    assert specs and all(s.rowlen == bp.arena_rowlen for s in specs)
    for s in specs:
        assert len(s.in_rows) == len(s.in_off)
        assert s.out_rows
        assert s.out_off + s.out_rows[0] <= bp.total_rows
        for off, (rows, used) in zip(s.in_off, s.in_rows):
            assert off + rows <= bp.total_rows
            assert used <= bp.arena_rowlen


# ---------------------------------------------------------------------------
# Mode plumbing: interpret vs compiled, REPRO_DMO_INTERPRET
# ---------------------------------------------------------------------------


def test_default_interpret_env_switch(monkeypatch):
    from repro.kernels.runtime import default_interpret, resolve_interpret
    monkeypatch.delenv("REPRO_DMO_INTERPRET", raising=False)
    assert default_interpret() is True
    monkeypatch.setenv("REPRO_DMO_INTERPRET", "0")
    assert default_interpret() is False
    monkeypatch.setenv("REPRO_DMO_INTERPRET", "compiled")
    assert default_interpret() is False
    monkeypatch.setenv("REPRO_DMO_INTERPRET", "1")
    assert default_interpret() is True
    assert resolve_interpret(False) is False  # explicit beats env


def test_pallas_mode_plumbing(monkeypatch):
    from repro.core.exec.pallas_backend import PallasExecutor
    assert PallasExecutor().mode == "interpret"
    assert PallasExecutor(mode="compiled").interpret is False
    with pytest.raises(ValueError, match="unknown pallas mode"):
        PallasExecutor(mode="warp")
    with pytest.raises(ValueError, match="unknown pallas layout"):
        PallasExecutor(layout="diagonal")
    # compiled mode cannot address a flat byte arena
    with pytest.raises(ValueError, match="row-blocked"):
        PallasExecutor(mode="compiled", layout="flat")
    # the env switch retargets the default-constructed backend
    monkeypatch.setenv("REPRO_DMO_INTERPRET", "0")
    assert PallasExecutor().mode == "compiled"
    monkeypatch.delenv("REPRO_DMO_INTERPRET")
    assert PallasExecutor().mode == "interpret"
    # compiled + a non-legalisable plan must refuse rather than fall back
    g = Graph("mixed")
    a = g.tensor("a", (4, 4), 1, "input")
    b = g.tensor("b", (4, 4), 4, "input")
    g.op("elementwise", [a], (4, 4), dict(fn="relu"), out_kind="output")
    g.op("elementwise", [b], (4, 4), dict(fn="relu"), name="e2",
         out_kind="output")
    g.validate()
    with pytest.raises(ValueError, match="mixed-dtype"):
        PallasExecutor(mode="compiled").execute(plan_dmo(g))


def test_compile_backend_pallas_verifies_blocked_tier():
    from repro.core import pipeline
    cp = pipeline.compile(small_conv_graph(), backend="pallas",
                          verify="numeric", cache=False)
    assert cp.verified == "numeric+pallas"
    assert any("flat + row-blocked" in l for l in cp.log)
    # the report states the legalised (row-blocked) peak + padding overhead
    assert "row-blocked" in cp.report()
    bp = cp.legalised()
    assert bp is not None and bp.padded_peak_bytes >= cp.peak_bytes
