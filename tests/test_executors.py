"""Executor backend layer: numpy vs pallas arena parity (f32 and the
quantised int8 tier), the pluggable registry, the compile(backend=...)
verify tier, unsafe-overlap detection on both backends, byte-arena layout
alignment, the legacy arena API wrappers, and the disk plan cache."""
import numpy as np
import pytest

from repro.core import exec as X
from repro.core import pipeline, zoo
from repro.core.arena import run_in_arena, run_reference, verify_plan
from repro.core.exec.numpy_backend import NumpyExecutor
from repro.core.graph import Graph
from repro.core.planner import Plan, plan_dmo, plan_original


def mini_graph():
    """conv2d + depthwise + pool + fully_connected (the four acceptance op
    kinds) plus softmax/reshape — small enough to cross-check in CI."""
    g = Graph("mini")
    h = g.tensor("x", (12, 12, 3), 4, "input")
    h = g.op("conv2d", [h], (6, 6, 8),
             dict(kernel=(3, 3), stride=(2, 2), padding="same"))
    h = g.op("depthwise_conv2d", [h], (6, 6, 8),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    h = g.op("pool", [h], (3, 3, 8),
             dict(kernel=(2, 2), stride=(2, 2), padding="valid", mode="avg"))
    g.op("softmax", [g.op("fully_connected",
                          [g.op("reshape", [h], (h.elems,))], (10,))],
         (10,), out_kind="output")
    g.validate()
    return g


def allops_graph():
    """Every remaining supported kind: max pool, pad, concat, mean, matmul,
    binary/unary elementwise, with two model outputs."""
    g = Graph("allops")
    a = g.tensor("a", (8, 8, 4), 4, "input")
    b2 = g.tensor("b", (8, 2), 4, "input")
    p = g.op("pool", [a], (4, 4, 4),
             dict(kernel=(3, 3), stride=(2, 2), padding="same", mode="max"))
    q = g.op("pad", [p], (6, 6, 4), dict(paddings=((1, 1), (1, 1), (0, 0))))
    c = g.op("concat", [p, p], (4, 4, 8), dict(axis=-1))
    m = g.op("mean", [q], (4,), dict(axes=(0, 1)))
    r1 = g.op("reshape", [c], (16, 8))
    mm = g.op("matmul", [r1, b2], (16, 2))
    s = g.op("elementwise", [mm], (16, 2), dict(fn="relu6"))
    ss = g.op("elementwise", [s, mm], (16, 2), dict(fn="add"))
    g.op("softmax", [ss], (16, 2), name="out", out_kind="output")
    g.op("elementwise", [m], (4,), dict(fn="sigmoid"), name="out2",
         out_kind="output")
    g.validate()
    return g


# ---------------------------------------------------------------------------
# Registry / protocol
# ---------------------------------------------------------------------------


def test_elementwise_tables_stay_in_sync():
    """executability() promises 'every arena backend can execute' — which
    only holds if the pallas jnp fn table mirrors the shared numpy one."""
    from repro.kernels import arena_ops
    assert set(arena_ops._ELEMENTWISE) == set(X.ELEMENTWISE)


def test_backend_registry():
    assert set(X.available_backends()) >= {"numpy", "pallas"}
    be = X.get_backend("numpy")
    assert be.name == "numpy" and isinstance(be, NumpyExecutor)
    assert X.get_backend("numpy") is be  # default instances are cached
    assert X.get_backend("pallas").name == "pallas"
    with pytest.raises(ValueError, match="unknown executor backend"):
        X.get_backend("tfmicro")


def test_unwrap_plan_accepts_plan_and_compiled():
    g = mini_graph()
    plan = plan_dmo(g)
    assert X.unwrap_plan(plan)[0] is plan
    cp = pipeline.compile(mini_graph(), cache=False)
    p2, g2 = X.unwrap_plan(cp)
    assert p2 is cp.plan and g2 is cp.graph
    with pytest.raises(TypeError):
        X.unwrap_plan("not a plan")


# ---------------------------------------------------------------------------
# Parity: pallas backend == numpy backend == private-buffer reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("build", [mini_graph, allops_graph],
                         ids=["mini", "allops"])
def test_pallas_matches_numpy_and_reference(build):
    g = build()
    plan = plan_dmo(g)
    plan.validate()
    inputs = X.random_inputs(g)
    weights = X.synth_weights(g)
    ref = run_reference(g, inputs, plan.order, weights=weights)
    got_np = X.get_backend("numpy").execute(plan, inputs, weights)
    got_pl = X.get_backend("pallas").execute(plan, inputs, weights)
    for k in ref:
        np.testing.assert_array_equal(got_np[k], ref[k], err_msg=k)
        np.testing.assert_allclose(got_pl[k], ref[k], rtol=1e-4, atol=1e-4,
                                   err_msg=k)


def test_pallas_executes_at_overlapped_offsets():
    """The acceptance shape: a DMO plan with real input/output overlap (O_s
    cascades) must execute correctly in ONE flat arena on the pallas
    backend — i.e. strictly below the non-overlapping baseline peak."""
    g = mini_graph()
    plan = plan_dmo(g)
    assert plan.peak_bytes < plan_original(g).peak_bytes
    assert plan.overlaps, "expected at least one O_s overlap in the plan"
    X.cross_check(plan)


#: Zoo sweep: paper models at paper resolution are skipped here (too large
#: for the row-by-row interpreters in CI), so reduced-resolution builds of
#: the same architectures carry the actual execution parity load. The int8
#: flagship rows get their own quantised sweep below.
_ZOO_SWEEP = {name: build for name, (build, _, _) in zoo.TABLE3_MODELS.items()}
_ZOO_SWEEP.update({
    "mobilenet_v1_0.25_32_f32": lambda: zoo.mobilenet_v1(0.25, 32, 4),
    "mobilenet_v2_0.35_32_f32": lambda: zoo.mobilenet_v2(0.35, 32, 4),
})


@pytest.mark.parametrize("name", list(_ZOO_SWEEP))
def test_zoo_executor_parity(name):
    g = _ZOO_SWEEP[name]()
    reason = X.executability(g)
    if reason is not None:
        pytest.skip(f"not lowerable: {reason}")
    if sum(t.elems for t in g.arena_tensors()) > 100_000:
        pytest.skip("too large for the interpret-mode parity sweep")
    # plan the input graph only: this sweep measures unsplit parity (split
    # bands execute too — tests/test_splitting.py covers them — but here
    # the winner must be the graph the reference below runs)
    cp = pipeline.compile(g, cache=False, split="off",
                          passes=("baseline", "plan", "verify"))
    inputs = X.random_inputs(cp.graph)
    weights = X.synth_weights(cp.graph)
    ref = run_reference(cp.graph, inputs, cp.plan.order, weights=weights)
    got_np = cp.execute(inputs, weights)                    # numpy default
    got_pl = cp.execute(inputs, weights, backend="pallas")
    for k in ref:
        np.testing.assert_array_equal(got_np[k], ref[k], err_msg=k)
        np.testing.assert_allclose(got_pl[k], ref[k], rtol=1e-4, atol=1e-4,
                                   err_msg=k)


# ---------------------------------------------------------------------------
# Quantised (int8) tier: zoo parity sweep, mixed dtypes, layout alignment
# ---------------------------------------------------------------------------

#: Reduced-resolution int8 builds of the paper's 8-bit architectures — small
#: enough for the interpret-mode cross-check, same topology/dtype as the
#: flagship Table III rows.
_INT8_SWEEP = {
    "mobilenet_v1_0.25_32_8bit": lambda: zoo.mobilenet_v1(0.25, 32, 1),
    "mobilenet_v1_0.25_64_8bit": lambda: zoo.mobilenet_v1(0.25, 64, 1),
    "mobilenet_v2_0.35_32_8bit": lambda: zoo.mobilenet_v2(0.35, 32, 1),
}


@pytest.mark.parametrize("name", list(_INT8_SWEEP))
def test_int8_zoo_parity(name):
    """The acceptance shape for the paper's flagship scenario: an 8-bit zoo
    model compiles for both backends, executes inside the overlapped byte
    arena, and matches the quantised private-buffer reference — bit-exact on
    numpy, <= 1 LSB on pallas."""
    g = _INT8_SWEEP[name]()
    assert X.needs_quant(g) and X.executability(g) is None
    cp = pipeline.compile(g, cache=False, split="off",
                          passes=("baseline", "plan", "verify"),
                          backend="pallas")
    assert cp.verified == "numeric+pallas"  # int8 numeric verify tier ran
    assert cp.plan.overlaps, "expected O_s overlaps on the int8 plan"
    assert cp.peak_bytes < cp.baseline_bytes  # nonzero DMO saving
    weights = X.synth_weights(cp.graph)
    quant = X.calibrate(cp.graph, 0, weights)
    inputs = X.quant_inputs(cp.graph, quant)
    ref = run_reference(cp.graph, inputs, cp.plan.order, weights=weights,
                        quant=quant)
    got_np = cp.execute(inputs, weights, backend="numpy", quant=quant)
    got_pl = cp.execute(inputs, weights, backend="pallas", quant=quant)
    for k in ref:
        assert ref[k].dtype == np.int8
        np.testing.assert_array_equal(got_np[k], ref[k], err_msg=k)
        np.testing.assert_allclose(got_pl[k].astype(np.int32),
                                   ref[k].astype(np.int32),
                                   rtol=0, atol=X.INT8_ATOL, err_msg=k)


def mixed_graph():
    """An int8 chain and an f32 chain sharing ONE byte arena. The int8 chain
    has odd byte sizes (75-byte input), so without dtype_bytes-aware
    placement the f32 chain would land unaligned."""
    g = Graph("mixed")
    a = g.tensor("a", (5, 5, 3), 1, "input")
    q = g.op("conv2d", [a], (5, 5, 5),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    q = g.op("pool", [q], (3, 3, 5),
             dict(kernel=(2, 2), stride=(2, 2), padding="same", mode="max"))
    g.op("elementwise", [q], (3, 3, 5), dict(fn="relu"), name="qout",
         out_kind="output")
    x = g.tensor("x", (6, 6, 2), 4, "input")
    y = g.op("conv2d", [x], (6, 6, 4),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    g.op("softmax", [y], (6, 6, 4), name="fout", out_kind="output")
    g.validate()
    return g


def test_mixed_dtype_plan_executes_on_both_backends():
    g = mixed_graph()
    assert X.executability(g) is None
    plan = plan_dmo(g)
    plan.validate()
    for lay in plan.op_layouts():
        for tl in (*[l for l in lay.inputs if l is not None], lay.output):
            assert tl.byte_offset % tl.dtype_bytes == 0
    X.cross_check(plan)   # int8 output <= 1 LSB, f32 output at fp32 tol
    outs = X.get_backend("numpy").execute(plan)
    assert outs["qout_out"].dtype == np.int8
    assert outs["fout_out"].dtype == np.float32


@pytest.mark.parametrize("name", list(zoo.TABLE3_MODELS))
def test_zoo_plan_offsets_dtype_aligned(name):
    """Placement invariant: every planned byte offset is dtype_bytes-aligned
    for every zoo model and planning strategy (the property op_layouts and
    the byte-arena backends rely on)."""
    g = zoo.TABLE3_MODELS[name][0]()
    for plan in (plan_dmo(g), plan_original(g)):
        for t, off in plan.offsets.items():
            assert off % t.dtype_bytes == 0, \
                f"{plan.strategy}: {t.name} at {off} ({t.dtype_bytes}B)"


def test_mixed_graph_alignment_is_forced():
    """The mixed graph's odd-sized int8 tensors force at least one f32
    placement to round up — the alignment logic is actually exercised."""
    plan = plan_dmo(mixed_graph())
    for t, off in plan.offsets.items():
        assert off % t.dtype_bytes == 0
    # sanity: some int8 tensor has a size that is not a multiple of 4, so
    # f32 alignment cannot fall out of packing for free
    assert any(t.nbytes % 4 for t in plan.offsets if t.dtype_bytes == 1)


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_unsafe_overlap_caught_int8(backend):
    """The §I verification catches a clobbering layout on the quantised tier
    too: input fully on top of the output of an int8 conv."""
    g = Graph("bad8")
    x = g.tensor("x", (8, 8, 4), 1, "input")
    y = g.op("conv2d", [x], (8, 8, 8),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"),
             out_kind="output")
    bad = Plan(g, list(g.ops), {x.storage(): 0, y.storage(): 0}, {}, "bogus")
    with pytest.raises(AssertionError):
        bad.validate()
    with pytest.raises(AssertionError):
        verify_plan(g, bad, backend=backend)
    verify_plan(g, plan_dmo(g), backend=backend)  # safe int8 plan passes


def test_paper_8bit_rows_are_executable():
    """The flagship Table III rows (where the paper's headline savings are
    measured) must pass the executor gate — the regression this PR exists
    to prevent."""
    ex = zoo.executable_models()
    for name in zoo.TABLE3_8BIT_MODELS:
        assert name in ex, f"{name} no longer executable"


def test_quantise_dequantise_roundtrip():
    qp = X.QParams(scale=0.05, zero_point=-12)
    v = np.linspace(-3.0, 3.0, 101, dtype=np.float32)
    q = X.ops.quantise(v, qp)
    back = X.ops.dequantise(q, qp)
    # within half a step everywhere the range did not saturate
    lo, hi = X.ops.dequantise(np.int8(-128), qp), X.ops.dequantise(np.int8(127), qp)
    mask = (v > lo) & (v < hi)
    assert np.abs(back[mask] - v[mask]).max() <= qp.scale / 2 + 1e-6


# ---------------------------------------------------------------------------
# compile(backend="pallas") verify tier
# ---------------------------------------------------------------------------


def test_compile_backend_pallas_cross_checks():
    cp = pipeline.compile(mini_graph(), backend="pallas", verify="numeric",
                          cache=False)
    assert cp.backend == "pallas"
    assert cp.verified == "numeric+pallas"
    assert any("pallas arena execution matches numpy" in l for l in cp.log)
    outs = cp.execute()  # runs on the compiled-for backend (pallas)
    assert set(outs) == {t.name for t in cp.graph.tensors
                         if t.kind == "output"}


def test_compile_backend_rejected():
    with pytest.raises(ValueError, match="unknown executor backend"):
        pipeline.compile(mini_graph(), backend="tfmicro")


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_backends_refuse_non_executable_graphs(backend):
    g = mini_graph()
    plan = plan_dmo(g)
    for t in g.tensors:  # flip dtype after planning: f16 has no kernel tier
        t.dtype_bytes = 2
    with pytest.raises(ValueError, match="unsupported arena dtype"):
        X.get_backend(backend).execute(plan)
    # split row bands execute as ordinary convs over band shapes ONLY when
    # they carry explicit band pads (band_pad); a legacy row_range without
    # them has unrecoverable geometry — executing it as a plain conv would
    # be silently wrong, so both backends still refuse it
    sg = Graph("banded")
    x = sg.tensor("x", (8, 8, 4), 4, "input")
    sg.op("conv2d", [x], (4, 8, 4),
          dict(kernel=(3, 3), stride=(1, 1), padding="same",
               row_range=(0, 4)), out_kind="output")
    with pytest.raises(ValueError, match="split row bands"):
        X.get_backend(backend).execute(plan_dmo(sg))
    # an op mixing int8 and f32 arena tensors has no cast kernel
    mg = Graph("mixed_op")
    a = mg.tensor("a", (4, 4), 1, "input")
    b = mg.tensor("b", (4, 4), 4, "input")
    mg.op("elementwise", [a, b], (4, 4), dict(fn="add"), out_kind="output",
          dtype_bytes=4)
    with pytest.raises(ValueError, match="mixes arena dtypes"):
        X.get_backend(backend).execute(plan_dmo(mg))


def test_executability_reports_all_reasons_joined():
    """A graph broken in several ways reports every reason, not just the
    first — actionable diagnostics for mixed int8 + split-band graphs."""
    g = Graph("multibroken")
    x = g.tensor("x", (8, 8, 2), 1, "input")
    h = g.op("conv2d", [x], (4, 8, 2),
             dict(kernel=(3, 3), stride=(1, 1), padding="same",
                  row_range=(0, 4)))
    g.op("elementwise", [h], (4, 8, 2), dict(fn="gelu"), out_kind="output")
    f16 = g.tensor("h16", (4, 4), 2, "input")
    g.op("elementwise", [f16], (4, 4), dict(fn="relu"), name="half",
         out_kind="output")
    reason = X.executability(g)
    assert "split row bands" in reason
    assert "unknown elementwise fn 'gelu'" in reason
    assert "unsupported arena dtype" in reason
    assert reason.count(";") >= 2  # joined, not first-only


# ---------------------------------------------------------------------------
# Negative: a deliberately unsafe overlap is caught on BOTH backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_unsafe_overlap_caught(backend):
    g = Graph("bad")
    x = g.tensor("x", (8, 8, 4), 4, "input")
    y = g.op("conv2d", [x], (8, 8, 8),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"),
             out_kind="output")
    # input fully on top of the output: row-ascending writes clobber input
    # rows the next output row still needs — way beyond any safe O_s
    bad = Plan(g, list(g.ops), {x.storage(): 0, y.storage(): 0}, {}, "bogus")
    with pytest.raises(AssertionError):
        bad.validate()
    with pytest.raises(AssertionError):
        verify_plan(g, bad, backend=backend)
    good = plan_dmo(g)
    verify_plan(g, good, backend=backend)  # sanity: safe plan passes


# ---------------------------------------------------------------------------
# Legacy arena API stays a thin wrapper over the numpy backend
# ---------------------------------------------------------------------------


def test_legacy_arena_api_wrappers():
    g = mini_graph()
    plan = plan_dmo(g)
    inputs = X.random_inputs(g)
    ref = run_reference(g, inputs, plan.order)
    got = run_in_arena(g, plan, inputs)
    for k in ref:
        np.testing.assert_array_equal(got[k], ref[k])
    # and the exec-layer numpy backend is the same machinery
    got2 = X.get_backend("numpy").execute(plan, inputs)
    for k in ref:
        np.testing.assert_array_equal(got2[k], ref[k])


# ---------------------------------------------------------------------------
# Disk plan cache + budget autoscaling satellites
# ---------------------------------------------------------------------------


def test_disk_cache_warm_start(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_DMO_CACHE_DIR", str(tmp_path))
    pipeline.cache_clear()
    first = pipeline.compile(mini_graph(), disk_cache=True)
    assert not first.cache_hit
    info = pipeline.cache_info()
    assert info["disk_misses"] == 1 and info["disk_dir"] == str(tmp_path)
    assert list(tmp_path.glob("*.pkl")), "plan not persisted"

    pipeline.cache_clear()  # simulate a fresh process (memory tier gone)
    warm = pipeline.compile(mini_graph(), disk_cache=True)
    assert warm.cache_hit and pipeline.cache_info()["disk_hits"] == 1
    assert warm.peak_bytes == first.peak_bytes
    warm.plan.validate()
    # the disk-loaded plan is executable (its graph/tensors round-tripped)
    X.get_backend("numpy").execute(warm)

    pipeline.cache_clear(disk=True)
    assert not list(tmp_path.glob("*.pkl"))
    cold = pipeline.compile(mini_graph(), disk_cache=True)
    assert not cold.cache_hit


def test_disk_cache_off_by_default(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_DMO_CACHE_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_DMO_DISK_CACHE", raising=False)
    pipeline.cache_clear()
    pipeline.compile(mini_graph())
    assert not list(tmp_path.glob("*.pkl"))
    assert pipeline.cache_info()["disk_misses"] == 0
    with pytest.raises(ValueError, match="disk_cache"):
        pipeline.compile(mini_graph(), cache=False, disk_cache=True)


def test_disk_cache_tolerates_corrupt_entries(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_DMO_CACHE_DIR", str(tmp_path))
    pipeline.cache_clear()
    pipeline.compile(mini_graph(), disk_cache=True)
    (path,) = tmp_path.glob("*.pkl")
    path.write_bytes(b"not a pickle")
    pipeline.cache_clear()
    cp = pipeline.compile(mini_graph(), disk_cache=True)  # must not crash
    assert not cp.cache_hit and pipeline.cache_info()["disk_misses"] == 1


def test_auto_budget_scales_with_graph_size():
    small = pipeline.auto_budget_s(zoo.mobilenet_v1(0.25, 128, 1))
    big = pipeline.auto_budget_s(zoo.nasnet_mobile())
    assert 1.0 <= big < small <= 12.0
    # and compile accepts it as a budget mode (0-cost path: tiny graph)
    cp = pipeline.compile(mini_graph(), budget_s="auto", cache=False,
                          split="off", passes=("baseline", "plan"))
    assert any("autoscaled" in l for l in cp.log)
    with pytest.raises(ValueError, match="budget_s"):
        pipeline.compile(mini_graph(), budget_s="fast")
