"""Sharding specs for all archs + a subprocess dry-run on a tiny virtual
mesh (keeps the main test process at 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import registry
from repro.models.config import SHAPES


def test_param_shardings_cover_all_archs():
    """Specs build for every arch on a (2,2) host-style mesh shape without
    touching devices (uses the real 1-CPU mesh)."""
    from repro.launch import specs as SP
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for name, cfg in registry().items():
        sh = SP.param_shardings(cfg, mesh)
        leaves = jax.tree.leaves(sh)
        assert leaves, name


def test_input_specs_shapes():
    from repro.launch import specs as SP
    for name, cfg in registry().items():
        for sname, shape in SHAPES.items():
            specs = SP.input_specs(cfg, shape)
            if shape.kind == "train":
                assert specs["batch"]["targets"].shape == (
                    shape.global_batch, shape.seq_len)
            elif shape.kind == "prefill":
                assert specs["inputs"].shape[0] == shape.global_batch
            else:
                assert specs["tokens"].shape == (shape.global_batch, 1)
                cl = SP.cache_len_for(cfg, shape)
                if shape.kind == "long_decode":
                    assert cl <= cfg.sliding_window or cfg.attn_free


def test_cache_len_long_decode_is_sub_quadratic():
    from repro.launch import specs as SP
    long = SHAPES["long_500k"]
    for name, cfg in registry().items():
        cl = SP.cache_len_for(cfg, long)
        assert cl < long.seq_len, f"{name}: long_500k must not keep 512k KV"


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, functools, json
    import jax, jax.numpy as jnp
    from repro import sharding as SH
    from repro.configs import registry
    from repro.launch import specs as SP
    from repro.train import steps as TS
    from repro.models import transformer as T

    cfg = dataclasses.replace(registry()["{arch}"].reduced(), dtype="float32")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh, SH.axis_env(mesh, batch=("data",)):
        st_sh = SP.state_shardings(cfg, mesh)
        state = jax.eval_shape(lambda: TS.init_state(cfg, jax.random.PRNGKey(0)))
        batch = {{"inputs": jax.ShapeDtypeStruct((4, 16), jnp.int32),
                 "targets": jax.ShapeDtypeStruct((4, 16), jnp.int32)}}
        from jax.sharding import NamedSharding, PartitionSpec as P
        b_sh = {{k: NamedSharding(mesh, P("data", None)) for k in batch}}
        fn = functools.partial(TS.train_step, cfg, TS.opt_config_for(cfg))
        jitted = jax.jit(fn, donate_argnums=(0,),
                         in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))
        compiled = jitted.lower(state, batch).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # older jax: one dict per device
            ca = ca[0] if ca else {{}}
        print(json.dumps({{"ok": True, "flops": ca.get("flops", 0)}}))
""")


@pytest.mark.parametrize("arch", ["yi-6b", "olmoe-1b-7b", "rwkv6-1.6b",
                                  "hymba-1.5b", "minicpm3-4b"])
def test_subprocess_tiny_mesh_train_lowers(arch):
    """Real SPMD compile of a reduced config on an 8-device virtual mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    # the forced 8-device host platform only exists on the CPU backend; an
    # accelerator plugin on the machine would otherwise win auto-selection
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", _SUBPROC.format(arch=arch)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"]
