"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

R = np.random.default_rng(42)


def arr(shape, dtype=jnp.float32):
    return jnp.asarray(R.standard_normal(shape), dtype)


DWCONV_CASES = [
    (16, 16, 8, 3, 1, 1), (17, 13, 4, 3, 2, 0), (20, 20, 16, 3, 2, 1),
    (12, 12, 8, 5, 1, 2), (8, 24, 2, 3, 1, 0), (15, 15, 1, 3, 3, 1),
]


@pytest.mark.parametrize("ih,iw,c,k,stride,pad", DWCONV_CASES)
def test_dmo_dwconv_matches_ref(ih, iw, c, k, stride, pad):
    x, w = arr((ih, iw, c)), arr((k, k, c))
    got = ops.dmo_dwconv2d(x, w, stride=stride, pad=pad)
    want = ref.dwconv2d(x, w, stride=stride, pad=pad)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("ih,iw,c,k,stride,pad", DWCONV_CASES)
def test_dmo_dwconv_arena_smaller_than_two_buffers(ih, iw, c, k, stride, pad):
    arena_b, two_b = ops.dmo_dwconv2d_footprint(ih, iw, c, k, stride, pad)
    assert arena_b < two_b


@settings(max_examples=25, deadline=None)
@given(st.integers(6, 20), st.integers(6, 20), st.sampled_from([1, 2, 4, 8]),
       st.sampled_from([3, 5]), st.integers(1, 2), st.integers(0, 2))
def test_dmo_dwconv_property(ih, iw, c, k, stride, pad):
    if ih + 2 * pad < k or iw + 2 * pad < k:
        return
    x, w = arr((ih, iw, c)), arr((k, k, c))
    got = ops.dmo_dwconv2d(x, w, stride=stride, pad=pad)
    want = ref.dwconv2d(x, w, stride=stride, pad=pad)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,d", [(64, 32), (256, 64), (128, 200), (8, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_inplace_rmsnorm(n, d, dtype):
    x, g, r = arr((n, d), dtype), arr((d,), dtype), arr((n, d), dtype)
    got = ops.rmsnorm_residual(x, g, r)
    want = ref.rmsnorm_scale_residual(x, g, r)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("s,t,h,d", [
    (128, 128, 4, 64), (256, 256, 2, 32), (64, 256, 3, 16), (32, 32, 1, 128),
])
def test_flash_attention_matches_ref(s, t, h, d):
    q, k, v = arr((s, h, d)), arr((t, h, d)), arr((t, h, d))
    got = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_non_causal():
    q, k, v = arr((64, 2, 32)), arr((128, 2, 32)), arr((128, 2, 32))
    got = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    want = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from([32, 48, 64, 96]), st.sampled_from([32, 64, 128]),
       st.integers(1, 4), st.sampled_from([16, 32, 64]))
def test_flash_attention_property(s, t, h, d):
    if t < s:
        t = s
    q, k, v = arr((s, h, d)), arr((t, h, d)), arr((t, h, d))
    got = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_flash_matches_model_sdpa_blockwise():
    """The pure-JAX blockwise path used in the dry-run lowering is the same
    algorithm — cross-check kernel vs model-level implementation."""
    from repro.models.layers import _sdpa_blockwise
    s, h, d = 96, 2, 32
    q, k, v = arr((1, s, h, d)), arr((1, s, h, d)), arr((1, s, h, d))
    a = _sdpa_blockwise(q, k, v, offset=0, window=0, block=32)[0]
    b = ops.flash_attention(q[0], k[0], v[0], block_q=32, block_k=32)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("s,h,d,q", [(128, 2, 64, 32), (256, 4, 64, 64),
                                     (192, 1, 64, 64)])
def test_wkv_chunk_kernel_matches_sequential(s, h, d, q):
    """Fused Pallas WKV (HC1 next lever) vs the sequential recurrence."""
    from repro.kernels.wkv_chunk import wkv_chunk_kernel
    from repro.models import ssm as S
    key = jax.random.PRNGKey(s + h)
    ks = jax.random.split(key, 4)
    b = 2
    r = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, d)) * 0.5))
    u = jax.random.normal(key, (h, d), jnp.float32) * 0.1
    state0 = jnp.zeros((b, h, d, d), jnp.float32)

    def step(st, t):
        return S._rwkv_step(st, t, u)

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    st_ref, outs = jax.lax.scan(step, state0, xs)
    y_ref = jnp.moveaxis(outs, 0, 1)
    y_k, st_k = wkv_chunk_kernel(r, k, v, jnp.log(w), u, q=q)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_k),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(st_ref), np.asarray(st_k),
                               rtol=3e-4, atol=3e-4)
