"""Per-architecture smoke tests (deliverable f): every assigned arch, a
reduced variant of the same family, one forward/train step on CPU, output
shapes + finiteness asserted. Plus decode-vs-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer as T
from repro.optim.adamw import OptConfig
from repro.train import steps as TS

ARCHS = list(registry().items())


def _inputs(cfg, b, s, key):
    if cfg.frontend != "none":
        return jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (b, s), 0, cfg.vocab_size)


@pytest.mark.parametrize("name,cfg", ARCHS, ids=[n for n, _ in ARCHS])
def test_smoke_forward_and_train_step(name, cfg):
    r = cfg.reduced()
    assert r.num_layers == 2 and r.d_model <= 512
    if r.is_moe:
        assert r.num_experts <= 4
    key = jax.random.PRNGKey(0)
    b, s = 2, 16
    state = TS.init_state(r, key)
    batch = {
        "inputs": _inputs(r, b, s, key),
        "targets": jax.random.randint(key, (b, s), 0, r.vocab_size),
    }
    new_state, metrics = jax.jit(
        lambda st, ba: TS.train_step(r, OptConfig(), st, ba, remat=False)
    )(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    logits, aux = T.forward_train(r, new_state["params"], batch["inputs"],
                                  remat=False)
    assert logits.shape == (b, s, r.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("name,cfg", ARCHS, ids=[n for n, _ in ARCHS])
def test_decode_matches_forward(name, cfg):
    """Prefill s tokens then decode one-by-one: each decode step's logits
    must match the full-sequence forward at that position (validates KV ring
    buffers, RoPE offsets, SSM/token-shift states across all families)."""
    r = cfg.reduced()
    if r.is_moe:
        # capacity-dropping differs between a 16-token prefill and a 1-token
        # decode step by design; ample capacity makes both paths exact
        r = dataclasses.replace(r, capacity_factor=16.0)
    key = jax.random.PRNGKey(1)
    params = T.init_params(r, key)
    b, s, extra = 2, 8, 4
    if r.frontend != "none":
        # frontend archs decode token ids after an embedded prompt; check
        # the pure-token path via embeddings of tokens for comparability
        toks = jax.random.randint(key, (b, s + extra), 0, r.vocab_size)
        full_inputs = params["embed"][toks]
    else:
        toks = jax.random.randint(key, (b, s + extra), 0, r.vocab_size)
        full_inputs = toks
    full_logits, _ = T.forward_train(r, params, full_inputs, remat=False)

    cache_len = s + extra
    logits, cache = T.prefill(r, params, full_inputs[:, :s], cache_len)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, s - 1]),
                               rtol=2e-3, atol=2e-3)
    for i in range(extra):
        tok = toks[:, s + i][:, None]
        logits, cache = T.decode_step(r, params, cache, tok,
                                      jnp.int32(s + i))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full_logits[:, s + i]),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"{name} decode step {i}")


def test_sliding_window_decode_bounded_cache():
    """Dense arch through the sub-quadratic path: ring cache of window size
    must equal full-cache attention restricted to the window."""
    cfg = registry()["yi-6b"].reduced()
    cfg = dataclasses.replace(cfg, sliding_window=8)
    key = jax.random.PRNGKey(2)
    params = T.init_params(cfg, key)
    b, total = 1, 20
    toks = jax.random.randint(key, (b, total), 0, cfg.vocab_size)
    # reference: full forward with window masking
    from repro.models import layers as L
    ref_logits, _ = T.prefill(cfg, params, toks, total, window=8)
    # ring-buffer decode with cache_len = window
    w = 8
    logits, cache = T.prefill(cfg, params, toks[:, :w], w, window=w)
    for i in range(w, total):
        logits, cache = T.decode_step(cfg, params, cache, toks[:, i][:, None],
                                      jnp.int32(i), window=w)
    full_ref, _ = T.forward_train(cfg, params, toks, remat=False)
    del full_ref, ref_logits, L
    # decode after the loop corresponds to position total-1 logits;
    # compare with a windowed full pass
    ref2, _ = T.prefill(cfg, params, toks, total, window=w)
    np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(ref2[:, 0]),
                               rtol=2e-3, atol=2e-3)


def test_int8_kv_cache_decode():
    """int8 KV cache (per-slot, per-kv-head scales): decode matches the fp
    forward within quantisation tolerance; cache tensors really are int8."""
    cfg = dataclasses.replace(registry()["yi-6b"].reduced(), kv_quant=True)
    key = jax.random.PRNGKey(5)
    params = T.init_params(cfg, key)
    b, s, extra = 2, 8, 4
    toks = jax.random.randint(key, (b, s + extra), 0, cfg.vocab_size)
    full, _ = T.forward_train(cfg, params, toks, remat=False)
    logits, cache = T.prefill(cfg, params, toks[:, :s], s + extra)
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, s - 1]),
                               rtol=0.1, atol=0.1)
    for i in range(extra):
        logits, cache = T.decode_step(cfg, params, cache,
                                      toks[:, s + i][:, None],
                                      jnp.int32(s + i))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, s + i]),
                                   rtol=0.12, atol=0.12, err_msg=f"step {i}")
