"""The unified compile pipeline: pass chain, plan cache, zoo-wide safety,
and the training/differentiation regression (custom_vjp identity barrier)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import pipeline, zoo
from repro.core.graph import Graph
from repro.core.planner import plan_original
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# Training / differentiation regression (the seed-red bug): the bare
# optimization_barrier primitive has no VJP in jax 0.4.x — the identity
# barrier must pass gradients straight through under both remat settings.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("remat", [False, True], ids=["noremat", "remat"])
def test_forward_train_differentiable(remat):
    cfg = registry()["yi-6b"].reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)

    def loss(p):
        logits, aux = T.forward_train(cfg, p, toks, remat=remat)
        return logits.astype(jnp.float32).mean() + aux

    grads = jax.grad(loss)(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert flat and all(np.isfinite(np.asarray(g)).all() for g in flat)
    # the embedding gradient flows through every scan layer's barrier
    assert float(jnp.abs(grads["embed"]).max()) > 0.0


@pytest.mark.parametrize("remat", [False, True], ids=["noremat", "remat"])
def test_forward_hidden_differentiable(remat):
    cfg = registry()["yi-6b"].reduced()
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)

    def loss(p):
        hidden, aux = T.forward_hidden(cfg, p, toks, remat=remat)
        return hidden.astype(jnp.float32).mean() + aux

    grads = jax.grad(loss)(params)
    assert all(np.isfinite(np.asarray(g)).all()
               for g in jax.tree_util.tree_leaves(grads))


def test_identity_barrier_is_identity_with_straight_through_grad():
    x = jnp.arange(6.0).reshape(2, 3)
    np.testing.assert_array_equal(np.asarray(T.identity_barrier(x)),
                                  np.asarray(x))
    g = jax.grad(lambda v: (T.identity_barrier(v) ** 2).sum())(x)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(x))


# ---------------------------------------------------------------------------
# Pipeline: cache behaviour
# ---------------------------------------------------------------------------


def test_cache_hit_returns_identical_plan_without_rerunning_passes():
    pipeline.cache_clear()
    g1 = zoo.mobilenet_v1(1.0, 224, 4)
    t0 = time.perf_counter()
    first = pipeline.compile(g1)
    t_first = time.perf_counter() - t0
    runs = pipeline.PIPELINE_RUNS

    g2 = zoo.mobilenet_v1(1.0, 224, 4)  # fresh build, same content
    t0 = time.perf_counter()
    second = pipeline.compile(g2)
    t_second = time.perf_counter() - t0

    assert not first.cache_hit and second.cache_hit
    assert pipeline.PIPELINE_RUNS == runs, "cache hit re-ran the pipeline"
    assert second.plan is first.plan, "hit must return the memoised plan"
    assert second.peak_bytes == first.peak_bytes
    assert pipeline.cache_info()["hits"] >= 1
    assert t_second * 10 <= t_first, (
        f"repeat compile not >=10x faster: {t_first:.4f}s vs {t_second:.4f}s")


def test_cache_distinguishes_options_and_content():
    pipeline.cache_clear()
    g = zoo.mobilenet_v1(0.25, 128, 1)
    a = pipeline.compile(g)
    b = pipeline.compile(g, profile="extended")
    assert not b.cache_hit, "different options must not collide"
    c = pipeline.compile(zoo.mobilenet_v1(0.25, 224, 1))
    assert not c.cache_hit, "different graph content must not collide"
    d = pipeline.compile(zoo.mobilenet_v1(0.25, 128, 1))
    assert d.cache_hit and d.plan is a.plan


def test_graph_signature_ignores_names_but_not_structure():
    def build(name, ch):
        g = Graph(name)
        x = g.tensor(f"{name}_x", (8, 8, 3), 4, "input")
        g.op("conv2d", [x], (8, 8, ch),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"),
             name=f"{name}_c", out_kind="output")
        return g

    assert (pipeline.graph_signature(build("a", 4))
            == pipeline.graph_signature(build("b", 4)))
    assert (pipeline.graph_signature(build("a", 4))
            != pipeline.graph_signature(build("a", 5)))


# ---------------------------------------------------------------------------
# Pipeline: pass chain
# ---------------------------------------------------------------------------


def test_unknown_pass_rejected():
    with pytest.raises(ValueError, match="unknown pass"):
        pipeline.compile(zoo.mobilenet_v1(0.25, 128, 1), passes=("nope",))


def test_passes_individually_toggleable():
    g = zoo.mobilenet_v1(0.25, 128, 1, external_input=True)
    plain = pipeline.compile(g, passes=("baseline", "plan", "verify"))
    split = pipeline.compile(g, split="on",
                             passes=("baseline", "split", "serialise",
                                     "plan", "verify"))
    assert plain.passes == ("baseline", "plan", "verify")
    assert split.winner == "split" and split.recompute_elems > 0
    # §II.A paper numbers: 96 KB -> <=66 KB via splitting alone
    assert plain.baseline_bytes == 96 * 1024
    assert split.peak_bytes <= 66 * 1024


def test_numeric_verification_runs_on_small_f32_graphs():
    g = Graph("mini")
    h = g.tensor("x", (12, 12, 3), 4, "input")
    h = g.op("conv2d", [h], (6, 6, 8),
             dict(kernel=(3, 3), stride=(2, 2), padding="same"))
    h = g.op("depthwise_conv2d", [h], (6, 6, 8),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    g.op("softmax", [g.op("fully_connected",
                          [g.op("reshape", [h], (h.elems,))], (10,))],
         (10,), out_kind="output")
    cp = pipeline.compile(g, verify="numeric")
    assert cp.verified == "numeric"
    assert cp.peak_bytes <= cp.baseline_bytes
    assert "verify: arena execution bit-exact" in "\n".join(cp.log)


def test_alias_plus_splittable_pair_compiles():
    """Regression: a graph mixing a reshape alias with a profitable conv
    split used to crash — split's tensor remapping collapses the alias into
    a self-producing op and serialisation saw a cycle."""
    g = Graph("alias_split")
    h = g.tensor("x", (12, 12, 3), 4, "input")
    h = g.op("conv2d", [h], (12, 12, 8),
             dict(kernel=(3, 3), stride=(1, 1), padding="same"))
    h = g.op("conv2d", [h], (6, 6, 8),
             dict(kernel=(3, 3), stride=(2, 2), padding="same"))
    g.op("softmax", [g.op("fully_connected",
                          [g.op("reshape", [h], (h.elems,))], (10,))],
         (10,), out_kind="output")
    cp = pipeline.compile(g, cache=False)
    assert cp.peak_bytes <= cp.baseline_bytes
    assert "split: skipped (aliased tensors)" in cp.log


def test_compile_log_mutations_do_not_poison_cache():
    pipeline.cache_clear()
    g = zoo.mobilenet_v1(0.25, 128, 1)
    first = pipeline.compile(g)
    first.log.append("poison-miss")
    hit = pipeline.compile(g)
    assert "poison-miss" not in hit.log
    hit.log.append("poison-hit")
    again = pipeline.compile(g)
    assert "poison-hit" not in again.log


def test_cache_hit_offsets_reachable_by_name():
    """A hit's plan references the memoised graph's tensors; names are the
    stable correlation key for callers holding their own build."""
    pipeline.cache_clear()
    pipeline.compile(zoo.mobilenet_v1(0.25, 128, 1))
    hit = pipeline.compile(zoo.mobilenet_v1(0.25, 128, 1))
    assert hit.cache_hit
    offs = hit.offsets_by_name()
    assert offs and all(isinstance(k, str) for k in offs)
    assert max(offs.values()) < hit.peak_bytes


def test_split_ops_limit_is_configurable():
    g = zoo.mobilenet_v1(0.25, 128, 1, external_input=True)
    cp = pipeline.compile(g, split_ops_limit=1, cache=False)
    assert any("split: skipped (30 ops > 1)" in line for line in cp.log)


def test_report_is_unified():
    cp = pipeline.compile(zoo.mobilenet_v1(0.25, 128, 1))
    r = cp.report()
    assert "passes:" in r and "baseline" in r and "# plan" in r
    assert f"{cp.peak_bytes}" in r


# ---------------------------------------------------------------------------
# Pipeline: zoo-wide safety — every model compiles to a verification-clean
# plan no worse than the non-overlapping plan_original baseline.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(zoo.TABLE3_MODELS))
def test_compile_zoo_clean_and_no_worse_than_original(name):
    g = zoo.TABLE3_MODELS[name][0]()
    cp = pipeline.compile(g)
    assert cp.verified in ("numeric", "constraints")
    cp.plan.validate()  # independent re-check of the no-clobber constraints
    assert cp.peak_bytes <= cp.baseline_bytes
    # the pipeline baseline IS plan_original of the input graph
    assert cp.baseline_bytes == plan_original(g).peak_bytes


# ---------------------------------------------------------------------------
# §II.B x §II.C: view-aware serialisation of concat-removal variants
# ---------------------------------------------------------------------------


def _branchy_concat_graph():
    """Two two-op branches built *interleaved* feeding a removable concat:
    depth-first re-serialisation must differ from construction order."""
    g = Graph("branchy")
    x = g.tensor("x", (8, 8, 4), 4, "input")
    conv = dict(kernel=(3, 3), stride=(1, 1), padding="same")
    a1 = g.op("conv2d", [x], (8, 8, 4), conv, name="a1")
    b1 = g.op("conv2d", [x], (8, 8, 4), conv, name="b1")
    a2 = g.op("conv2d", [a1], (8, 8, 4),
              dict(kernel=(1, 1), stride=(1, 1), padding="same"), name="a2")
    b2 = g.op("conv2d", [b1], (8, 8, 4),
              dict(kernel=(1, 1), stride=(1, 1), padding="same"), name="b2")
    c = g.op("concat", [a2, b2], (8, 8, 8), dict(axis=-1), name="cat")
    g.op("elementwise", [c], (8, 8, 8), dict(fn="relu"), name="out",
         out_kind="output")
    g.validate()
    return g


def test_removal_variant_reorders():
    """serialise._deps is view-aware: a concat-removal graph (branch ops
    writing into aggregated views) admits candidate orders beyond the
    construction order, every order respects the writers-before-readers
    contract, and the pipeline serialises the removal variant instead of
    pinning construction order (the ROADMAP strided-view item)."""
    from repro.core.removal import removable, remove_concats
    from repro.core.serialise import _deps, candidate_orders

    g = _branchy_concat_graph()
    assert any(removable(g, op) for op in g.ops)
    rg = remove_concats(g)
    assert any(t.alias_of is not None for t in rg.tensors)  # real views
    orders = candidate_orders(rg)
    assert len(orders) >= 2
    assert any([op.name for op in o] != [op.name for op in rg.ops]
               for o in orders), "removal variant still pinned"
    deps = _deps(rg)
    # the aggregate reader depends on EVERY view writer, not just the last
    out = next(op for op in rg.ops if op.name == "out")
    assert {d.name for d in deps[out]} == {"a2", "b2"}
    for o in orders:  # writers-before-readers in every candidate
        done = set()
        for op in o:
            assert deps[op] <= done, f"{op.name} ran before a dependency"
            done.add(op)
    cp = pipeline.compile(g, cache=False)
    assert any("serialise[remove_concats]" in line for line in cp.log)
    assert cp.peak_bytes <= cp.baseline_bytes
