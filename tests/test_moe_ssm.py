"""MoE dispatch and SSM mixers: correctness against dense oracles."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import moe as M
from repro.models import ssm as S


def moe_cfg(**kw):
    base = registry()["olmoe-1b-7b"].reduced()
    return dataclasses.replace(base, **kw)


def test_moe_matches_dense_oracle_when_capacity_ample():
    """With capacity_factor high enough that nothing drops, the capacity
    dispatch must equal the brute-force weighted sum over top-k experts."""
    cfg = moe_cfg(capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = M.moe_init(cfg, key)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32)
    out, aux = M._moe_ffn_local(p, x, cfg)

    # dense oracle: every token through its top-k experts
    t = 16
    xf = x.reshape(t, cfg.d_model)
    logits = (xf @ p["router"]["w"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gw, gi = jax.lax.top_k(probs, cfg.experts_per_token)
    gw = gw / gw.sum(-1, keepdims=True)
    want = np.zeros((t, cfg.d_model), np.float32)
    for i in range(t):
        for j in range(cfg.experts_per_token):
            e = int(gi[i, j])
            h = jax.nn.silu(xf[i] @ p["w_gate"][e]) * (xf[i] @ p["w_up"][e])
            want[i] += float(gw[i, j]) * np.asarray(h @ p["w_down"][e])
    np.testing.assert_allclose(out.reshape(t, -1), want, rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_are_bounded():
    cfg = moe_cfg(capacity_factor=1.0)
    key = jax.random.PRNGKey(1)
    p = M.moe_init(cfg, key)
    x = jax.random.normal(key, (4, 16, cfg.d_model), jnp.float32)
    out, _ = M._moe_ffn_local(p, x, cfg)
    assert np.isfinite(np.asarray(out)).all()


def test_rwkv_forward_equals_stepwise_decode():
    cfg = registry()["rwkv6-1.6b"].reduced()
    key = jax.random.PRNGKey(2)
    p = S.rwkv_init(cfg, key)
    b, s = 2, 10
    x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    y_full, state_full = S.rwkv_forward(p, x, cfg)

    state = {"wkv": jnp.zeros_like(state_full["wkv"]),
             "shift": jnp.zeros((b, cfg.d_model), jnp.float32)}
    ys = []
    for i in range(s):
        y, state = S.rwkv_decode(p, x[:, i:i + 1], state, cfg)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_full["wkv"]),
                               np.asarray(state["wkv"]), rtol=2e-4, atol=2e-4)


def test_mamba_forward_equals_stepwise_decode():
    cfg = registry()["hymba-1.5b"].reduced()
    key = jax.random.PRNGKey(3)
    p = S.mamba_init(cfg, key)
    b, s = 2, 9
    x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    y_full, st_full = S.mamba_forward(p, x, cfg)
    di = cfg.d_model * cfg.ssm_expand
    state = {"ssm": jnp.zeros((b, di, cfg.ssm_state), jnp.float32),
             "conv": jnp.zeros((b, cfg.conv_kernel - 1, di), jnp.float32)}
    ys = []
    for i in range(s):
        y, state = S.mamba_decode(p, x[:, i:i + 1], state, cfg)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_full["ssm"]),
                               np.asarray(state["ssm"]), rtol=2e-4, atol=2e-4)


def test_rwkv_state_is_input_size_independent():
    """The O(1)-state property that makes long_500k native for SSMs."""
    cfg = registry()["rwkv6-1.6b"].reduced()
    p = S.rwkv_init(cfg, jax.random.PRNGKey(4))
    for s in (4, 32):
        x = jax.random.normal(jax.random.PRNGKey(s), (1, s, cfg.d_model))
        _, st = S.rwkv_forward(p, x, cfg)
        assert st["wkv"].shape == (1, cfg.d_model // 64, 64, 64)


def test_wkv_chunked_equals_sequential():
    """The §Perf chunked closed form is exactly the sequential recurrence."""
    cfg = registry()["rwkv6-1.6b"].reduced()
    p = S.rwkv_init(cfg, jax.random.PRNGKey(7))
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 256, cfg.d_model),
                          jnp.float32)
    y_seq, st_seq = S.rwkv_forward(p, x, cfg, chunked=False)
    y_chk, st_chk = S.rwkv_forward(p, x, cfg, chunked=True)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chk),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_seq["wkv"]),
                               np.asarray(st_chk["wkv"]),
                               rtol=2e-4, atol=2e-4)


def test_chunked_ce_equals_plain():
    """Sequence-chunked cross-entropy (§Perf HC3) is exact."""
    import dataclasses
    from repro.models import transformer as T
    from repro.train import steps as TS
    cfg = dataclasses.replace(registry()["qwen2.5-3b"].reduced(),
                              vocab_size=40000)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 1024
    batch = {
        "inputs": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                     cfg.vocab_size),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                      cfg.vocab_size),
    }
    l1, _ = TS.loss_fn(cfg, params, batch, remat=False)  # chunked (V>=32k)
    logits, _ = T.forward_train(cfg, params, batch["inputs"], remat=False)
    l2 = TS.cross_entropy(logits, batch["targets"])
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
