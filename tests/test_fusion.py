"""Fused band-chain super-kernels (the FusePass + one-Pallas-call-per-chain
execution path).

Covers: chain discovery on split graphs, the fused-graph rewrite (scratch
re-kinding, provenance markers), planner behaviour (intermediates drop out of
placement, fused peak below the split peak), zoo-wide fused-vs-unfused parity
on both backends, streaming window containment for fused chains, the
VMEM-budget refusal with unfused fallback, the launch-count acceptance
numbers, and the per-signature lowering cache.
"""
import numpy as np
import pytest

from repro.core import pipeline, planner as P, zoo
from repro.core import splitting as S
from repro.core.exec import compare_outputs, get_backend
from repro.core.exec import ops as X
from repro.core.graph import band_range
from repro.core.planner import plan_dmo


def _flagship():
    return zoo.TABLE3_MODELS["mobilenet_v1_0.25_128_8bit"][0]()


def _split_flagship():
    sg, rc, _ = S.auto_split(_flagship())
    assert rc > 0, "flagship must split"
    return sg


# ---------------------------------------------------------------------------
# Chain discovery + fused-graph rewrite
# ---------------------------------------------------------------------------


def test_find_band_chains_flagship():
    """The flagship split graph holds one chain: every band pair plus the
    reassembling concat, contiguous in op order."""
    sg = _split_flagship()
    chains = S.find_band_chains(sg)
    assert len(chains) == 1
    ch = chains[0]
    assert ch[-1].kind == "concat"
    assert all(band_range(op) is not None for op in ch[:-1])
    idx = [sg.ops.index(op) for op in ch]
    assert idx == list(range(idx[0], idx[0] + len(ch)))
    assert len(ch) >= 3


def test_find_band_chains_empty_on_unsplit():
    assert S.find_band_chains(_flagship()) == []


def test_fuse_chains_rewrites_scratch_and_markers():
    sg = _split_flagship()
    chains = S.find_band_chains(sg)
    fg = S.fuse_chains(sg, chains)
    assert fg is not None and fg.name.endswith("_fused")
    members = S.chain_members(fg)
    assert len(members) == 1
    (cname, ops), = members.items()
    assert len(ops) == len(chains[0])
    # internal tensors became scratch; the terminal output did not
    internal = {op.output.storage() for op in ops[:-1]}
    assert all(s.kind == "scratch" for s in internal)
    assert ops[-1].output.storage().kind != "scratch"
    # provenance markers: chain name + ascending stage index
    assert ops[-1].name == cname
    assert [op.params["fuse_stage"] for op in ops] == list(range(len(ops)))
    # scratch never reaches arena placement or scopes
    assert not any(s.kind == "scratch" for s in fg.arena_tensors())
    assert not any(s.kind == "scratch" for s in fg.scopes())


def test_fused_peak_below_split_peak():
    """Tentpole acceptance: dropping chain intermediates out of placement
    pushes the banded arena peak below the O_s-only split peak — and on the
    flagship below the 53 KB relaxed split peak of the previous release."""
    sg = _split_flagship()
    fg = S.fuse_chains(sg)
    split_peak = plan_dmo(sg).peak_bytes
    fused_peak = plan_dmo(fg).peak_bytes
    assert fused_peak < split_peak
    assert fused_peak <= 53 * 1024


def test_fused_slots_pack_tight_and_round_total():
    """fused_slots packs member-local liveness tightly (slots byte/row
    granular) and only rounds the total."""
    sg = _split_flagship()
    fg = S.fuse_chains(sg)
    (_, members), = S.chain_members(fg).items()
    rows_of = lambda s: int(s.shape[-3])
    slots, total = P.fused_slots(members, rows_of, round_to=8)
    internal = {op.output.storage() for op in members[:-1]}
    assert set(slots) == internal
    assert total % 8 == 0
    assert max(slots[s] + rows_of(s) for s in internal) <= total
    # liveness overlap => strictly better than sum of sizes
    assert total < sum(rows_of(s) for s in internal) + 8


# ---------------------------------------------------------------------------
# Parity: fused vs unfused, both backends, both dtype tiers
# ---------------------------------------------------------------------------


_PARITY_MODELS = {
    "mobilenet_v1_0.25_64_f32": lambda: zoo.mobilenet_v1(0.25, 64, 4),
    "mobilenet_v1_0.25_64_8bit": lambda: zoo.mobilenet_v1(0.25, 64, 1),
    "mobilenet_v2_0.35_32_f32": lambda: zoo.mobilenet_v2(0.35, 32, 4),
    "mobilenet_v1_0.25_128_8bit": _flagship,
}


@pytest.mark.parametrize("name", list(_PARITY_MODELS))
def test_fused_parity_zoo(name):
    """Fused execution matches the unfused split execution on every backend
    route: numpy bit-exact per tier (f32 exact, int8 <= 1 LSB via
    compare_outputs), pallas blocked + streaming within the same tolerance."""
    g = _PARITY_MODELS[name]()
    sg, _, _ = S.auto_split(g)
    if not S.find_band_chains(sg):
        pytest.skip(f"{name} has no fusable band chain")
    fg = S.fuse_chains(sg)
    assert fg is not None
    sp, fp = plan_dmo(sg), plan_dmo(fg)
    ref = get_backend("numpy").execute(sp)
    f32 = not X.needs_quant(sg)
    for label, got in [
        ("numpy", get_backend("numpy").execute(fp)),
        ("pallas-blocked",
         get_backend("pallas", layout="blocks").execute(fp)),
        ("pallas-streaming",
         get_backend("pallas", mode="streaming", interpret=True).execute(fp)),
    ]:
        exact = f32 and label == "numpy"
        compare_outputs(ref, got, exact=exact,
                        label=f"{name} fused {label} vs unfused numpy")


def test_fused_streaming_window_containment():
    """The fused streaming window stages exactly the include_io slot total
    (ext inputs + chain scratch + terminal output) and stays inside the
    arena extents of its external operands."""
    cp = pipeline.compile(_flagship(), cache=False)
    assert cp.winner == "fuse"
    bp = cp.legalised()
    ws = bp.window_schedule()
    fused = [w for w in ws.windows if w.kind == "fused"]
    assert len(fused) == 1
    w = fused[0]
    members = [op for op in bp.order
               if op.params.get("fuse_chain") == w.op_name]
    internal = {op.output.storage() for op in members[:-1]}

    # chain_rows_of applies the packed (cols_per_row, row_span) geometry to
    # chain-scratch tensors exactly as the planner's _fused_window does
    _, total = P.fused_slots(members, P.chain_rows_of(bp),
                             round_to=bp.tiling[0], include_io=True)
    assert w.win_rows == w.resident_rows == total
    for op in members:
        for t in list(op.inputs) + [op.output]:
            s = t.storage()
            if s.kind == "weight" or s in internal:
                continue
            lay = bp.layout_of(t)
            assert w.lo <= lay.row_offset
            assert lay.row_offset + lay.rows <= w.hi


# ---------------------------------------------------------------------------
# Pipeline: FusePass, budget refusal, winner selection
# ---------------------------------------------------------------------------


def test_pipeline_fuse_winner_and_log():
    cp = pipeline.compile(_flagship(), cache=False)
    assert cp.winner == "fuse"
    assert cp.recompute_elems > 0
    assert any("-> 1 fused kernel" in l for l in cp.log), cp.log
    assert cp.peak_bytes <= 53 * 1024
    assert cp.peak_bytes < cp.baseline_bytes


def test_pipeline_fuse_off_restores_split():
    cp = pipeline.compile(_flagship(), cache=False, fuse="off")
    assert cp.winner == "split"
    assert any("fuse: disabled" in l for l in cp.log)


def test_over_budget_chain_refused_with_fallback():
    """Negative: a VMEM budget below the chain's scratch estimate leaves the
    chain unfused — the pipeline logs the refusal and falls back to the
    plain split variant."""
    cp = pipeline.compile(_flagship(), cache=False, fuse_vmem_budget=1024)
    assert cp.winner == "split"
    assert any("refused" in l and "VMEM budget" in l for l in cp.log), cp.log
    ref = get_backend("numpy").execute(
        pipeline.compile(_flagship(), cache=False))
    got = get_backend("numpy").execute(cp)
    compare_outputs(ref, got, exact=False,
                    label="over-budget fallback vs fused")


def test_fuse_option_validation():
    with pytest.raises(ValueError, match="fuse"):
        pipeline.compile(_flagship(), cache=False, fuse="maybe")


# ---------------------------------------------------------------------------
# Launch counts + lowering cache
# ---------------------------------------------------------------------------


def test_flagship_launch_count_collapse():
    """Acceptance: the split-band region that PR 5 executed as one
    pallas_call per band op becomes ONE fused call — a >= 4x drop — and the
    whole-graph launch count falls accordingly."""
    from repro.core.exec.pallas_backend import PallasExecutor
    cp = pipeline.compile(_flagship(), cache=False)
    bp = cp.legalised()
    specs = PallasExecutor(layout="blocks", interpret=True).lower_blocks(bp)
    fused = [s for s in specs if s.kind == "fused"]
    assert len(fused) == 1
    chain_len = len(fused[0].stages)
    assert chain_len >= 4 * len(fused), \
        f"region launch drop {chain_len} -> {len(fused)} below 4x"
    n_ops = sum(1 for op in bp.order if op.kind != "reshape")
    assert len(specs) == n_ops - (chain_len - 1)


def test_fused_spec_stage_wiring():
    """The fused OpSpec carries per-stage scratch routing: intermediates
    read/write scratch, ext inputs and the terminal concat hit the arena."""
    from repro.core.exec.pallas_backend import PallasExecutor
    cp = pipeline.compile(_flagship(), cache=False)
    bp = cp.legalised()
    specs = PallasExecutor(layout="blocks", interpret=True).lower_blocks(bp)
    spec = next(s for s in specs if s.kind == "fused")
    assert spec.scratch_rows > 0
    stages = spec.stages
    assert not any(stages[0].in_scratch)
    assert all(st.out_scratch for st in stages[:-1])
    assert not stages[-1].out_scratch
    assert all(stages[-1].in_scratch)


# ---------------------------------------------------------------------------
# Tooling: bench differ + trace routes
# ---------------------------------------------------------------------------


def _load_script(name):
    import importlib.util
    import pathlib
    path = pathlib.Path(__file__).resolve().parents[1] / "scripts" / \
        f"{name}.py"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_diff_gates_regressions():
    bd = _load_script("bench_diff")
    old = {"models": {"m": {"dmo_kb": 100.0, "launches": 20,
                            "baseline_kb": 96.0, "saving_pct": 50.0}}}

    def with_m(**kw):
        entry = dict(old["models"]["m"])
        entry.update(kw)
        return {"models": {"m": entry}}

    reg, imp = bd.diff(old, with_m(dmo_kb=110.0, launches=10))
    assert any("dmo_kb" in r for r in reg) and len(reg) == 1
    assert any("launches" in i for i in imp)
    # within the 5% default threshold: clean
    reg, _ = bd.diff(old, with_m(dmo_kb=104.0))
    assert not reg
    # --skip silences a documented trade-off
    reg, _ = bd.diff(old, with_m(dmo_kb=110.0), skip=("dmo_kb",))
    assert not reg
    # baseline_kb drift fails in BOTH directions (graph-derived invariant)
    reg, imp = bd.diff(old, with_m(baseline_kb=80.0))
    assert any("baseline_kb" in r for r in reg) and not imp
    # timing metrics only gate under timing=True
    old_t = {"models": {}, "exec_us_per_call": {"i8/pallas_blocks": 100.0}}
    new_t = {"models": {}, "exec_us_per_call": {"i8/pallas_blocks": 200.0}}
    assert bd.diff(old_t, new_t) == ([], [])
    reg, _ = bd.diff(old_t, new_t, timing=True)
    assert reg


def test_export_trace_pallas_routes():
    """The pallas trace routes emit one span per *launch* (not per op) and
    the fused route refuses graphs without fused chains."""
    et = _load_script("export_trace")
    cp = pipeline.compile(zoo.mobilenet_v1(0.25, 32, 1), cache=False)
    ev = et.trace_pallas_events(cp, "blocked")
    spans = [e for e in ev if e["ph"] == "X"]
    n_ops = sum(1 for op in cp.plan.order if op.kind != "reshape")
    assert 0 < len(spans) <= n_ops
    assert all(e["args"]["route"] == "blocked" for e in spans)
    counters = [e for e in ev if e["name"] == "pallas_launches"]
    assert counters[-1]["args"]["launches"] == len(spans)
    cp_nosplit = pipeline.compile(zoo.mobilenet_v1(0.25, 32, 1),
                                  cache=False, split="off")
    with pytest.raises(SystemExit, match="no fused band chains"):
        et.trace_pallas_events(cp_nosplit, "fused")


def test_lowering_cache_hits_across_executes():
    """Satellite: lowered specs are cached per (plan, route, quant)
    signature — a second execute() of the same plan reuses them."""
    from repro.core.exec.pallas_backend import PallasExecutor
    cp = pipeline.compile(zoo.mobilenet_v1(0.25, 32, 1), cache=False)
    be = PallasExecutor(layout="blocks", interpret=True)
    a = be.execute(cp)
    info1 = be.lowering_cache_info()
    b = be.execute(cp)
    info2 = be.lowering_cache_info()
    assert info1["misses"] == 1 and info1["hits"] == 0
    assert info2["misses"] == 1 and info2["hits"] == 1
    assert info2["size"] >= 1
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
