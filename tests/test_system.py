"""End-to-end system tests: data -> train (loss falls) -> checkpoint ->
restore -> serve. Plus pipeline/checkpoint/hlocost units."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import transformer as T
from repro.optim.adamw import OptConfig
from repro.serve.engine import Engine, ServeConfig
from repro.train import steps as TS


def tiny_cfg():
    import dataclasses
    r = registry()["qwen2.5-3b"].reduced()
    return dataclasses.replace(r, vocab_size=128, d_ff=128, num_heads=2,
                               num_kv_heads=1, d_model=64, head_dim=32)


def test_training_loss_decreases():
    cfg = tiny_cfg()
    data = SyntheticCorpus(DataConfig(cfg.vocab_size, seq_len=32,
                                      global_batch=8, mean_doc_len=64))
    it = data.packed_batches()
    opt = OptConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    state = TS.init_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(lambda st, b: TS.train_step(cfg, opt, st, b, remat=False),
                   donate_argnums=(0,))
    losses = []
    for i in range(30):
        b = next(it)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_cfg()
    state = TS.init_state(cfg, jax.random.PRNGKey(1))
    path = store.save(str(tmp_path / "ckpt"), state, step=7)
    like = jax.eval_shape(lambda: TS.init_state(cfg, jax.random.PRNGKey(0)))
    restored = store.restore(path, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert store.latest(str(tmp_path / "ckpt")).endswith("step_00000007.npz")


def test_engine_generates_deterministically():
    cfg = tiny_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    eng = Engine(cfg, params, ServeConfig(cache_len=64, max_new_tokens=8))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (3, 16))
    a = eng.generate(prompts.astype(np.int32))
    b = eng.generate(prompts.astype(np.int32))
    assert a.shape == (3, 8)
    np.testing.assert_array_equal(a, b)  # greedy = deterministic


def test_engine_decode_consistent_with_forward():
    """Greedy generation must follow the argmax chain of full forwards."""
    cfg = tiny_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(3))
    eng = Engine(cfg, params, ServeConfig(cache_len=64, max_new_tokens=4))
    prompt = np.random.default_rng(1).integers(0, cfg.vocab_size, (1, 12))
    out = eng.generate(prompt.astype(np.int32))
    seq = list(prompt[0])
    for i in range(4):
        logits, _ = T.forward_train(cfg, params,
                                    jnp.asarray([seq]), remat=False)
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == int(out[0, i]), f"step {i}"
        seq.append(nxt)


def test_pipeline_packing_shapes_and_determinism():
    dc = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    a = list(zip(range(3), SyntheticCorpus(dc).packed_batches()))
    b = list(zip(range(3), SyntheticCorpus(dc).packed_batches()))
    for (_, x), (_, y) in zip(a, b):
        assert x["inputs"].shape == (4, 16) and x["targets"].shape == (4, 16)
        np.testing.assert_array_equal(x["inputs"], y["inputs"])
        # next-token alignment
        np.testing.assert_array_equal(x["inputs"][:, 1:], x["targets"][:, :-1])


def test_hlocost_counts_scan_trips():
    from repro.hlocost import module_cost

    def g(a, b):
        def body(x, _):
            return x @ b, None
        y, _ = jax.lax.scan(body, a, None, length=10)
        return y

    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(g).lower(a, a).compile()
    cost = module_cost(c.as_text())
    assert cost.flops == pytest.approx(10 * 2 * 128 ** 3, rel=0.01)


def test_roofline_terms():
    from repro.roofline import Roofline
    r = Roofline("x", 256, hlo_flops=1e15, hlo_bytes=1e12, coll_bytes=1e11,
                 coll_breakdown={}, model_flops=5e14)
    assert r.t_compute == pytest.approx(1e15 / (256 * 197e12))
    assert r.bottleneck in ("compute", "memory", "collective")
    assert r.useful_flops_ratio == pytest.approx(0.5)
