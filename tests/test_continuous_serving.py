"""Continuous batching: ragged decode correctness + slot recycling."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer as T
from repro.serve.continuous import ContinuousConfig, ContinuousEngine, Request


def tiny_cfg():
    r = registry()["qwen2.5-3b"].reduced()
    return dataclasses.replace(r, vocab_size=96, d_model=64, num_heads=2,
                               num_kv_heads=1, head_dim=32, d_ff=96)


def greedy_reference(cfg, params, prompt, n):
    """Argmax chain via full forwards."""
    seq = list(prompt)
    out = []
    for _ in range(n):
        logits, _ = T.forward_train(cfg, params, jnp.asarray([seq]),
                                    remat=False)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        seq.append(nxt)
    return out


def test_ragged_batch_matches_per_request_reference():
    """Different prompt lengths decoded in ONE batch must equal per-request
    greedy decoding (exercises the vector-position ring caches)."""
    cfg = tiny_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 11, 8)]
    eng = ContinuousEngine(cfg, params,
                           ContinuousConfig(slots=3, cache_len=64))
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=50)
    for r, p in zip(reqs, prompts):
        assert r.done
        want = greedy_reference(cfg, params, p, 6)
        assert r.out == want, (r.rid, r.out, want)


def test_slot_recycling_serves_more_requests_than_slots():
    cfg = tiny_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    eng = ContinuousEngine(cfg, params,
                           ContinuousConfig(slots=2, cache_len=48))
    reqs = [Request(i, rng.integers(1, cfg.vocab_size, 4 + i % 3)
                    .astype(np.int32), max_new_tokens=3 + i % 2)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=80)
    assert all(r.done for r in reqs)
    for r in reqs:
        assert len(r.out) == r.max_new_tokens


def test_recycled_slot_is_isolated_from_previous_request():
    """A request admitted into a recycled slot must produce exactly the
    per-request reference output (no leakage from the dead cache)."""
    cfg = tiny_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    first = rng.integers(1, cfg.vocab_size, 9).astype(np.int32)
    second = rng.integers(1, cfg.vocab_size, 6).astype(np.int32)
    eng = ContinuousEngine(cfg, params,
                           ContinuousConfig(slots=1, cache_len=48))
    r1, r2 = (Request(0, first, max_new_tokens=4),
              Request(1, second, max_new_tokens=4))
    eng.submit(r1)
    eng.submit(r2)
    eng.run(max_steps=40)
    assert r1.done and r2.done
    assert r2.out == greedy_reference(cfg, params, second, 4)
