"""Zoo graphs build + arch activation-arena DMO plans."""
import pytest

from repro.configs import registry
from repro.core import zoo
from repro.core.activation_planner import plan_block
from repro.core.planner import plan_dmo, plan_original


@pytest.mark.parametrize("name", list(zoo.TABLE3_MODELS))
def test_zoo_builds_and_validates(name):
    g = zoo.TABLE3_MODELS[name][0]()
    g.validate()
    assert len(g.ops) >= 25
    assert g.peak_bytes_lower_bound() > 0


def test_mobilenet_originals_match_paper():
    for name in ("mobilenet_v1_1.0_224", "mobilenet_v1_0.25_224",
                 "mobilenet_v2_0.35_224", "mobilenet_v2_1.0_224",
                 "mobilenet_v1_0.25_128_8bit"):
        build, orig_kb, _ = zoo.TABLE3_MODELS[name]
        assert plan_original(build()).peak_bytes == orig_kb * 1024, name


@pytest.mark.parametrize("arch", list(registry()))
def test_block_activation_dmo_saves(arch):
    cfg = registry()[arch]
    orig, dmo = plan_block(cfg, batch=1, seq=64)
    orig.validate()
    dmo.validate()
    assert dmo.peak_bytes <= orig.peak_bytes
    # every family has elementwise chains: DMO must find real savings
    assert dmo.peak_bytes < orig.peak_bytes, arch


def test_operation_splitting_paper_example():
    """§II.A: splitting the (conv, dwconv) pair of MobileNet v1 0.25 128
    cuts the peak from 96 KB to <=66 KB at a bounded recompute cost."""
    from repro.core.splitting import auto_split, split_pair
    g = zoo.mobilenet_v1(0.25, 128, 1, external_input=True)
    assert plan_original(g).peak_bytes == 96 * 1024
    ng, rc = split_pair(g, 2, 4)
    ng.validate()
    assert plan_original(ng).peak_bytes <= 66 * 1024
    assert 0 < rc <= 6144  # paper: 6144 (coarser halo convention)
    ag, arc, log = auto_split(g)
    assert plan_original(ag).peak_bytes <= 66 * 1024
    assert log, "auto_split must find the paper's pair"


def test_operation_removal_squeezenet():
    """§II.C: concat elision turns branch outputs into views; the
    concat-dominated fire-module footprint shrinks and plans stay safe."""
    from repro.core.removal import remove_concats
    from repro.core.zoo import squeezenet
    g = squeezenet()
    g2 = remove_concats(g)
    assert len(g2.ops) == len(g.ops) - 8          # 8 fire concats elided
    g2.validate()
    p = plan_dmo(g2, method="algorithmic")
    p.validate()
    assert p.peak_bytes <= plan_original(g).peak_bytes
